import os

# Tests run on the single real CPU device; only the dry-run uses 512
# placeholder devices (and only tests/test_dryrun.py spawns subprocesses for
# that).  Keep numerics deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
