import os

# Tests run on the single real CPU device; only the dry-run uses 512
# placeholder devices (and only tests/test_dryrun.py spawns subprocesses for
# that).  Keep numerics deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def hypothesis_or_stubs():
    """(given, settings, st) — real hypothesis if installed, else stubs.

    The stubs keep modules importable without hypothesis (it is a dev-only
    dependency, see requirements-dev.txt): strategy expressions evaluate to
    None and ``@given``-decorated tests collect as skipped, so the plain
    pytest tests in the same module still run.
    """
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        class _StrategyStub:
            def __getattr__(self, name):
                return lambda *a, **k: None

        def given(*a, **k):
            return pytest.mark.skip(reason="hypothesis not installed")

        def settings(*a, **k):
            return lambda f: f

        return given, settings, _StrategyStub()
