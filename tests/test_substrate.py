"""Data pipeline, optimizers, schedules, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data import CharLMData, ClassificationData, TokenStream, TokenStreamConfig
from repro.optim import adamw, apply_updates, momentum, sgd
from repro.optim.schedules import constant, cosine, exponential, wsd


class TestData:
    def test_label_shard_non_iid(self):
        d = ClassificationData(n_workers=8, n_classes=10, classes_per_worker=3,
                               samples_per_worker=64, seed=0)
        assert d.heterogeneity() > 0.3
        for w in range(8):
            b = d.batch(w, 0, 16)
            labels = set(np.asarray(b["y"]).tolist())
            assert len(labels) <= 3

    def test_iid_partition_low_heterogeneity(self):
        d = ClassificationData(n_workers=8, partition="iid",
                               samples_per_worker=64)
        assert d.heterogeneity() < 0.25

    def test_dirichlet_partition(self):
        d = ClassificationData(n_workers=4, partition="dirichlet",
                               dirichlet_alpha=0.1, samples_per_worker=64)
        assert d.heterogeneity() > 0.3

    def test_batches_deterministic(self):
        d = ClassificationData(n_workers=2, samples_per_worker=32, seed=1)
        b1 = d.batch(0, 5, 8)
        b2 = d.batch(0, 5, 8)
        np.testing.assert_array_equal(np.asarray(b1["x"]), np.asarray(b2["x"]))

    def test_charlm_stream(self):
        d = CharLMData(n_workers=3, vocab=40, seq_len=16)
        b = d.batch(1, 0, 4)
        assert b["tokens"].shape == (4, 16)
        assert int(b["tokens"].max()) < 40

    def test_token_stream_sharding_and_resume(self):
        cfg = TokenStreamConfig(vocab_size=100, seq_len=8, global_batch=8,
                                n_workers=4)
        s = TokenStream(cfg)
        b0 = s.worker_batch(0)
        state = s.state_dict()
        b1 = s.worker_batch(0)
        s2 = TokenStream(cfg)
        s2.load_state_dict(state)
        b1r = s2.worker_batch(0)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b1r["tokens"]))
        assert not np.array_equal(np.asarray(b0["tokens"]),
                                  np.asarray(b1["tokens"]))

    def test_token_stream_worker_distributions_differ(self):
        cfg = TokenStreamConfig(vocab_size=1000, seq_len=256, global_batch=4,
                                n_workers=2, worker_shift=0.5)
        s = TokenStream(cfg)
        t0 = np.asarray(s.worker_batch(0)["tokens"]).ravel()
        t1 = np.asarray(s.worker_batch(1)["tokens"]).ravel()
        assert abs(np.median(t0) - np.median(t1)) > 50


class TestOptim:
    def _quad(self):
        target = jnp.asarray([1.0, -2.0, 3.0])
        loss = lambda p: jnp.sum((p["w"] - target) ** 2)
        return loss, {"w": jnp.zeros(3)}

    @pytest.mark.parametrize("opt", [sgd(), momentum(0.9), adamw()])
    def test_optimizers_reduce_quadratic(self, opt):
        loss, params = self._quad()
        state = opt.init(params)
        for _ in range(200):
            g = jax.grad(loss)(params)
            upd, state = opt.update(g, state, params, jnp.float32(0.05))
            params = apply_updates(params, upd)
        assert float(loss(params)) < 1e-2

    def test_adamw_weight_decay(self):
        opt = adamw(weight_decay=0.5)
        params = {"w": jnp.ones(2)}
        state = opt.init(params)
        g = {"w": jnp.zeros(2)}
        upd, _ = opt.update(g, state, params, jnp.float32(0.1))
        assert float(upd["w"][0]) < 0  # decay pulls toward zero

    def test_schedules(self):
        assert float(constant(0.1)(100)) == pytest.approx(0.1)
        e = exponential(0.1, 0.95)
        assert float(e(0)) == pytest.approx(0.1)
        assert float(e(10)) == pytest.approx(0.1 * 0.95 ** 10)
        c = cosine(1.0, 100, warmup=10)
        assert float(c(5)) == pytest.approx(0.5)
        assert float(c(100)) == pytest.approx(0.0, abs=1e-6)
        w = wsd(1.0, 1000)
        assert float(w(5)) < 1.0          # warming up
        assert float(w(500)) == pytest.approx(1.0)   # stable
        assert float(w(999)) < 0.2        # decayed
        assert float(w(1000)) == pytest.approx(0.1, rel=1e-2)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "b": {"c": jnp.ones(4, jnp.bfloat16)}}
        ck.save(3, tree, extra={"note": "x"})
        restored, extra = ck.restore(tree)
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        assert restored["b"]["c"].dtype == jnp.bfloat16
        assert extra["note"] == "x"

    def test_latest_and_gc(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        tree = {"w": jnp.zeros(2)}
        for s in (1, 2, 3, 4):
            ck.save(s, tree)
        assert ck.all_steps() == [3, 4]
        assert ck.latest_step() == 4

    def test_worker_slice_restore(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        stacked = {"w": jnp.arange(12, dtype=jnp.float32).reshape(4, 3)}
        ck.save(1, stacked)
        single = {"w": jnp.zeros(3)}
        out = ck.restore_worker_slice(single, worker=2)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(stacked["w"][2]))

    def test_shape_mismatch_raises(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, {"w": jnp.zeros(3)})
        with pytest.raises(ValueError):
            ck.restore({"w": jnp.zeros(4)})
