"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.consensus import metropolis_matrix
from repro.kernels.gossip_mix import gossip_mix, gossip_mix_ref
from repro.kernels.linear_scan import linear_scan, linear_scan_ref
from repro.kernels.swa_attention import swa_attention, swa_attention_ref


def _tol(dt):
    return dict(atol=2e-2, rtol=2e-2) if dt == jnp.bfloat16 else dict(atol=2e-5, rtol=1e-4)


class TestGossipMix:
    @pytest.mark.parametrize("n,d", [(4, 128), (16, 1024), (13, 257), (32, 2048),
                                     (7, 64), (128, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, n, d, dtype):
        key = jax.random.PRNGKey(n * d)
        W = jax.random.normal(key, (n, d)).astype(dtype)
        P = jnp.asarray(metropolis_matrix(
            n, [(i, (i + 1) % n) for i in range(n - 1)]), dtype)
        out = gossip_mix(W, P, block_d=256)
        ref = gossip_mix_ref(W, P)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), **_tol(dtype))

    def test_multidim_leaves(self):
        n = 8
        W = jax.random.normal(jax.random.PRNGKey(0), (n, 3, 5, 7))
        P = jnp.eye(n) * 0.5 + 0.5 / n
        P = P / P.sum(0, keepdims=True)
        out = gossip_mix(W, P)
        ref = gossip_mix_ref(W.reshape(n, -1), P).reshape(W.shape)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)

    def test_identity_matrix(self):
        W = jax.random.normal(jax.random.PRNGKey(1), (6, 100))
        out = gossip_mix(W, jnp.eye(6))
        np.testing.assert_allclose(np.asarray(out), np.asarray(W), atol=1e-6)


class TestLinearScan:
    @pytest.mark.parametrize("B,T,D", [(1, 32, 64), (2, 128, 96), (1, 100, 33),
                                       (3, 17, 8), (2, 256, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, B, T, D, dtype):
        k1, k2 = jax.random.split(jax.random.PRNGKey(B * T + D))
        a = jax.nn.sigmoid(jax.random.normal(k1, (B, T, D))).astype(dtype)
        x = jax.random.normal(k2, (B, T, D)).astype(dtype)
        out = linear_scan(a, x, block_t=32, block_d=64)
        ref = linear_scan_ref(a, x)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), **_tol(dtype))

    def test_zero_decay_copies_input(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 16, 8))
        out = linear_scan(jnp.zeros_like(x), x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=1e-6)

    def test_unit_decay_cumsum(self):
        x = jnp.ones((1, 10, 4))
        out = linear_scan(jnp.ones_like(x), x)
        np.testing.assert_allclose(np.asarray(out)[0, :, 0],
                                   np.arange(1, 11, dtype=np.float32), atol=1e-5)


class TestSWAAttention:
    @pytest.mark.parametrize("B,T,H,KV,dh,w", [
        (1, 128, 4, 2, 32, 40), (2, 256, 4, 4, 64, 100),
        (1, 192, 8, 1, 16, 64), (1, 64, 2, 2, 128, 16),
    ])
    def test_matches_oracle(self, B, T, H, KV, dh, w):
        ks = jax.random.split(jax.random.PRNGKey(T + w), 3)
        q = jax.random.normal(ks[0], (B, T, H, dh))
        k = jax.random.normal(ks[1], (B, T, KV, dh))
        v = jax.random.normal(ks[2], (B, T, KV, dh))
        out = swa_attention(q, k, v, window=w, block_q=64, block_k=64)
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, T, dh)
        kf = k.transpose(0, 2, 1, 3).reshape(B * KV, T, dh)
        vf = v.transpose(0, 2, 1, 3).reshape(B * KV, T, dh)
        ref = swa_attention_ref(qf, kf, vf, window=w, n_groups=H // KV)
        ref = ref.reshape(B, H, T, dh).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    def test_bf16(self):
        B, T, H, KV, dh, w = 1, 128, 2, 2, 32, 32
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, T, H, dh)).astype(jnp.bfloat16)
        k = jax.random.normal(ks[1], (B, T, KV, dh)).astype(jnp.bfloat16)
        v = jax.random.normal(ks[2], (B, T, KV, dh)).astype(jnp.bfloat16)
        out = swa_attention(q, k, v, window=w, block_q=64, block_k=64)
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, T, dh)
        kf = k.transpose(0, 2, 1, 3).reshape(B * KV, T, dh)
        vf = v.transpose(0, 2, 1, 3).reshape(B * KV, T, dh)
        ref = swa_attention_ref(qf, kf, vf, window=w, n_groups=1)
        ref = ref.reshape(B, H, T, dh).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=3e-2)

    def test_window_one_attends_self_only(self):
        B, T, H, dh = 1, 64, 1, 16
        q = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, dh))
        v = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, dh))
        out = swa_attention(q, jnp.ones_like(q), v, window=1,
                            block_q=32, block_k=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(v), atol=1e-5)

    def test_nondivisible_T_padded(self):
        B, T, H, dh, w = 1, 70, 2, 16, 20
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (B, T, H, dh))
        k = jax.random.normal(ks[1], (B, T, H, dh))
        v = jax.random.normal(ks[2], (B, T, H, dh))
        out = swa_attention(q, k, v, window=w, block_q=32, block_k=32)
        assert out.shape == (B, T, H, dh)
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, T, dh)
        kf = k.transpose(0, 2, 1, 3).reshape(B * H, T, dh)
        vf = v.transpose(0, 2, 1, 3).reshape(B * H, T, dh)
        ref = swa_attention_ref(qf, kf, vf, window=w, n_groups=1)
        ref = ref.reshape(B, H, T, dh).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
