"""Distributed-layer tests: sharding policy, mesh views, gossip equivalence,
and a scaled-down dry-run — all in subprocesses so the main test process keeps
its single CPU device (XLA fixes the device count at first use)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


class TestShardingPolicy:
    def test_param_specs_cover_all_leaves(self):
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config
        from repro.launch.sharding import param_pspecs
        from repro.models.transformer import init_model

        class FakeMesh:
            shape = {"fsdp": 4, "model": 16, "data": 16}

        for name in ("qwen3-8b", "grok-1-314b", "rwkv6-1.6b",
                     "recurrentgemma-2b"):
            cfg = get_config(name)
            shapes = jax.eval_shape(lambda k: init_model(k, cfg),
                                    jax.random.PRNGKey(0))
            specs = param_pspecs(shapes, FakeMesh(), fsdp="fsdp",
                                 model="model")
            flat_shapes = jax.tree.leaves(shapes)
            flat_specs = jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, P))
            assert len(flat_shapes) == len(flat_specs)
            for sh, sp in zip(flat_shapes, flat_specs):
                assert len(sp) <= len(sh.shape)
                # every named axis divides its dim
                for dim, axis in zip(sh.shape, tuple(sp)):
                    if axis is None:
                        continue
                    axes = axis if isinstance(axis, tuple) else (axis,)
                    size = int(np.prod([FakeMesh.shape[a] for a in axes]))
                    assert dim % size == 0, (name, sh.shape, tuple(sp))

    def test_expert_parallel_when_divisible(self):
        import jax
        from repro.configs import get_config
        from repro.launch.sharding import param_pspecs
        from repro.models.transformer import init_model

        class FakeMesh:
            shape = {"fsdp": 4, "model": 16}

        cfg = get_config("arctic-480b")  # 128 experts % 16 == 0
        shapes = jax.eval_shape(lambda k: init_model(k, cfg),
                                jax.random.PRNGKey(0))
        specs = param_pspecs(shapes, FakeMesh(), fsdp="fsdp", model="model")
        spec = specs["layers"]["ffn"]["w_gate"]
        assert tuple(spec)[1] == "model"  # E axis expert-parallel


class TestMeshViews:
    def test_hierarchical_view_shapes(self):
        out = run_py("""
            import jax
            from repro.launch.mesh import hierarchical_view
            from repro.utils.compat import auto_axis_types, make_mesh
            base = make_mesh((4, 2), ("data", "model"),
                             axis_types=auto_axis_types(2))
            v, axes = hierarchical_view(base, 2, 2)
            print(v.axis_names, v.shape["worker"], v.shape["fsdp"])
            v1, axes1 = hierarchical_view(base, 4, 1)
            print(v1.axis_names, axes1.fsdp)
        """, devices=8)
        assert "('worker', 'fsdp', 'model') 2 2" in out
        assert "('worker', 'model') None" in out

    def test_production_mesh_axes(self):
        out = run_py("""
            import jax
            from repro.launch.mesh import make_production_mesh
            # 512 host devices: both meshes must build
            m1 = make_production_mesh()
            m2 = make_production_mesh(multi_pod=True)
            print(m1.axis_names, m1.devices.size)
            print(m2.axis_names, m2.devices.size)
        """, devices=512)
        assert "('data', 'model') 256" in out
        assert "('pod', 'data', 'model') 512" in out


class TestGossipEquivalence:
    def test_shardmap_ring_matches_dense_mixing(self):
        """ppermute ring gossip == dense Pᵀ·W with ring Metropolis weights."""
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
            from repro.launch.mesh import TrainAxes
            from repro.launch.steps import _tree_gossip, default_gossip_weights
            from repro.core.consensus import metropolis_matrix
            from repro.utils.compat import auto_axis_types, make_mesh, shard_map

            n = 4
            mesh = make_mesh((n,), ("worker",), axis_types=auto_axis_types(1))
            axes = TrainAxes(pod=None, worker="worker", fsdp=None, model="model")
            W = {"w": jnp.arange(n * 6, dtype=jnp.float32).reshape(n, 6)}
            spec = {"w": P("worker", None)}
            gw = default_gossip_weights(n, False)
            f = shard_map(lambda W: _tree_gossip(W, axes, n, gw),
                          mesh=mesh, in_specs=(spec,), out_specs=spec)
            out = f(W)
            Pm = metropolis_matrix(n, [(i, (i + 1) % n) for i in range(n)])
            ref = Pm.T @ np.asarray(W["w"])
            err = float(np.abs(np.asarray(out["w"]) - ref).max())
            print("ERR", err)
        """, devices=4)
        assert float(out.strip().split()[-1]) < 1e-5

    def test_multipod_gossip_doubly_stochastic(self):
        """Pod-edge mixing preserves the mean (doubly stochastic check)."""
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch.mesh import TrainAxes
            from repro.launch.steps import _tree_gossip, default_gossip_weights
            from repro.utils.compat import auto_axis_types, make_mesh, shard_map
            mesh = make_mesh((2, 2), ("pod", "worker"),
                             axis_types=auto_axis_types(2))
            axes = TrainAxes(pod="pod", worker="worker", fsdp=None, model="model")
            W = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 5))}
            spec = {"w": P(("pod", "worker"), None)}
            gw = default_gossip_weights(2, True)
            f = shard_map(lambda W: _tree_gossip(W, axes, 2, gw),
                          mesh=mesh, in_specs=(spec,), out_specs=spec)
            out = f(W)
            print("MEAN_ERR",
                  float(np.abs(np.asarray(out["w"]).mean(0)
                               - np.asarray(W["w"]).mean(0)).max()))
        """, devices=4)
        assert float(out.strip().split()[-1]) < 1e-5


class TestDryRunSmall:
    """Scaled-down dry-run through the exact dryrun code path."""

    def test_train_and_decode_lower_on_small_mesh(self):
        out = run_py("""
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.configs import get_config
            from repro.launch import sharding as S, shapes as SH, steps as ST
            from repro.launch.mesh import hierarchical_view
            from repro.models.transformer import init_model
            from repro.utils.compat import auto_axis_types, make_mesh

            base = make_mesh((4, 2), ("data", "model"),
                             axis_types=auto_axis_types(2))
            view, axes = hierarchical_view(base, 2, 2)
            cfg = get_config("qwen3-8b").reduced()
            nw = 2
            params_sds = jax.eval_shape(ST.stacked_init(cfg, nw),
                                        jax.random.PRNGKey(0))
            pspecs = S.param_pspecs(params_sds, view, fsdp=axes.fsdp,
                                    model=axes.model,
                                    worker_axes=axes.worker_axes)
            shape = SH.InputShape("t", "train", 64, 8)
            batch_sds, bspecs = SH.train_input_specs(cfg, shape, nw, axes)
            step = ST.build_train_step(cfg, nw, axes, view, pspecs,
                                       logit_chunk=16)
            ns = lambda s: jax.tree.map(lambda x: NamedSharding(view, x), s,
                                        is_leaf=lambda x: isinstance(x, P))
            gw = ST.gossip_weights_spec()
            j = jax.jit(step, in_shardings=(
                ns(pspecs), ns(bspecs), NamedSharding(view, P()),
                jax.tree.map(lambda _: NamedSharding(view, P()), gw)))
            with view:
                c = j.lower(params_sds, batch_sds,
                            jax.ShapeDtypeStruct((), jnp.float32), gw).compile()
            assert c.memory_analysis() is not None
            print("TRAIN_OK")

            mesh = base
            cfg2 = SH.shape_config(get_config("rwkv6-1.6b").reduced(),
                                   SH.SHAPES["long_500k"])
            shape2 = SH.InputShape("d", "decode", 256, 4)
            p_sds = jax.eval_shape(lambda k: init_model(k, cfg2),
                                   jax.random.PRNGKey(0))
            psp = S.param_pspecs(p_sds, mesh, fsdp="data", model="model")
            inp, specs = SH.decode_input_specs(cfg2, shape2, mesh)
            sstep = ST.build_serve_step(cfg2)
            nsm = lambda s: jax.tree.map(lambda x: NamedSharding(mesh, x), s,
                                         is_leaf=lambda x: isinstance(x, P))
            j2 = jax.jit(sstep, in_shardings=(
                nsm(psp), nsm(specs["token"]), nsm(specs["state"]),
                NamedSharding(mesh, P())))
            with mesh:
                c2 = j2.lower(p_sds, inp["token"], inp["state"],
                              inp["pos"]).compile()
            print("DECODE_OK")
        """, devices=8)
        assert "TRAIN_OK" in out and "DECODE_OK" in out


class TestHloAnalysis:
    def test_trip_count_corrected_flops(self):
        """Custom HLO cost model multiplies while bodies by trip count."""
        out = run_py("""
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch.hlo_analysis import analyze_hlo_text
            from repro.utils.compat import auto_axis_types, make_mesh
            mesh = make_mesh((2, 2), ("data", "model"),
                             axis_types=auto_axis_types(2))
            def f(w, x):
                def body(c, wi):
                    return jnp.tanh(c @ wi), ()
                return jax.lax.scan(body, x, w)[0].sum()
            w = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
            x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
            j = jax.jit(f, in_shardings=(
                NamedSharding(mesh, P(None, None, "model")),
                NamedSharding(mesh, P("data", None))))
            with mesh:
                c = j.lower(w, x).compile()
            cost = analyze_hlo_text(c.as_text())
            print("FLOPS", cost.flops)
            print("AG", cost.collectives.bytes_by_kind["all-gather"])
        """, devices=4)
        lines = dict(l.split() for l in out.strip().splitlines())
        assert float(lines["FLOPS"]) == pytest.approx(5 * 2 * 4 * 32 * 64, rel=0.05)
        assert float(lines["AG"]) == pytest.approx(5 * 4 * 32 * 4, rel=0.05)

    def test_parser_on_synthetic_hlo(self):
        from repro.launch.hlo_analysis import analyze_hlo_text
        hlo = """
HloModule test, num_partitions=2

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%i2, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]{1,0}) tuple(%z, %a)
  %w = (s32[], f32[8,8]{1,0}) while(%t0), condition=%cond, body=%body
  ROOT %r = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""
        cost = analyze_hlo_text(hlo)
        assert cost.flops == pytest.approx(7 * 2 * 8 * 8 * 8)
        assert cost.collectives.bytes_by_kind["all-reduce"] == pytest.approx(
            7 * 8 * 8 * 4)
        assert cost.collectives.count_by_kind["all-reduce"] == 7
