"""Sparse-native event generation: lazy dense views, vectorized packing
round-trips, the event-horizon batcher, and scheduler edge-case fixes
(AD-PSGD's isolated-worker lock bug).

The generation layer's contract after the sparse-native refactor:

- schedulers never build an (n, n) matrix per event — events carry the
  active-worker lanes and the A×A submatrix, and the dense views stay
  unmaterialized unless a consumer asks;
- packing events and unpacking them back is *exact* (array-equal, not
  allclose) in both the sparse and dense batch forms;
- the optional ``horizon=K`` batcher is deterministic and yields the same
  trainer trajectories across all three execution modes, while being a
  different RNG-stream realization than the default per-event draws.
"""
import itertools
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology
from repro.core.baselines import make_scheduler
from repro.core.consensus import (is_doubly_stochastic, metropolis_matrix,
                                  metropolis_submatrix)
from repro.core.runner import DecentralizedTrainer
from repro.core.scheduler import EventBatch, SparseEventBatch
from repro.core.straggler import StragglerModel
from repro.core.topology import Graph
from repro.data.synthetic import ClassificationData

N = 8
DATA = ClassificationData(n_workers=N, d=16, n_classes=4,
                          samples_per_worker=64, seed=0)


def loss_fn(params, batch):
    logits = batch["x"] @ params["w"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], axis=1))


def init_fn(key):
    return {"w": jax.random.normal(key, (16, 4)) * 0.1}


def _sched(alg, seed=0, n=N, **kw):
    g = topology.erdos_renyi(n, 0.4, seed=3)
    sm = StragglerModel(n=n, straggler_prob=0.2, slowdown=6.0, seed=seed)
    return make_scheduler(alg, g, sm, **kw)


def _trainer(sched, mode, seed=0, **kw):
    return DecentralizedTrainer(
        sched, loss_fn, init_fn,
        lambda w, s: DATA.batch(w, s, batch_size=8),
        DATA.eval_batch(64), eta0=0.2, eta_decay=0.99, seed=seed,
        mode=mode, **kw)


def _disconnected_graph():
    """A 4-worker connected component plus one fully isolated worker."""
    adj = np.zeros((5, 5), dtype=bool)
    for a, b in ((0, 1), (1, 2), (0, 2), (2, 3)):
        adj[a, b] = adj[b, a] = True
    return Graph(5, adj)


class TestSparseNativeEvents:
    @pytest.mark.parametrize("alg", ["dsgd_aau", "ad_psgd", "prague", "agp"])
    def test_generation_never_materializes_dense(self, alg):
        """The hot loop is sparse-native: streaming and packing events leaves
        every lazy dense view (P, grad_workers, restart_workers) unbuilt."""
        sched = _sched(alg)
        evs = list(itertools.islice(sched.events(), 24))
        SparseEventBatch.from_events(evs, active_bound=sched.active_bound(),
                                     edge_bound=sched.edge_bound())
        for ev in evs:
            assert ev._P is None and ev._gw is None and ev._rw is None
            assert len(ev.workers) <= sched.active_bound()

    @pytest.mark.parametrize("alg", ["dsgd_aau", "ad_psgd", "prague", "agp"])
    def test_lanes_consistent_with_dense_views(self, alg):
        sched = _sched(alg)
        for ev in itertools.islice(sched.events(), 24):
            np.testing.assert_array_equal(
                np.nonzero(ev.grad_workers)[0], ev.workers[ev.grad_lanes])
            np.testing.assert_array_equal(
                np.nonzero(ev.restart_workers)[0],
                ev.workers[ev.restart_lanes])
            # P is identity off the active set, the submatrix on it
            P = ev.P
            np.testing.assert_array_equal(
                P[np.ix_(ev.workers, ev.workers)], ev.P_sub)
            off = np.setdiff1d(np.arange(ev.n), ev.workers)
            np.testing.assert_array_equal(P[np.ix_(off, off)],
                                          np.eye(len(off)))

    def test_metropolis_submatrix_bit_equals_dense_build(self):
        rng = np.random.default_rng(0)
        for _ in range(50):
            n = int(rng.integers(4, 200))
            m = int(rng.integers(2, min(10, n)))
            widx = np.sort(rng.choice(n, size=m, replace=False))
            sub_adj = np.zeros((m, m), dtype=bool)
            for i in range(m):
                for j in range(i + 1, m):
                    if rng.random() < 0.5:
                        sub_adj[i, j] = sub_adj[j, i] = True
            edges = [(int(widx[i]), int(widx[j]))
                     for i, j in zip(*np.nonzero(np.triu(sub_adj, 1)))]
            dense = metropolis_matrix(n, edges)[np.ix_(widx, widx)]
            sub = metropolis_submatrix(n, widx, sub_adj)
            np.testing.assert_array_equal(sub, dense)  # exact, not allclose


class TestPackingRoundTripsExact:
    """pack → to_events → pack must reproduce every packed array exactly."""

    @pytest.mark.parametrize("alg", ["dsgd_aau", "ad_psgd", "prague", "agp"])
    def test_sparse_pack_unpack_pack(self, alg):
        sched = _sched(alg)
        evs = list(itertools.islice(sched.events(), 16))
        b1 = SparseEventBatch.from_events(
            evs, active_bound=sched.active_bound(),
            edge_bound=sched.edge_bound())
        b2 = SparseEventBatch.from_events(
            b1.to_events(N), active_bound=sched.active_bound(),
            edge_bound=sched.edge_bound())
        for field in ("times", "workers", "n_workers", "P_sub",
                      "grad_workers", "restart_workers", "param_copies_sent",
                      "edges", "n_edges"):
            np.testing.assert_array_equal(getattr(b1, field),
                                          getattr(b2, field), err_msg=field)
        assert b1.k0 == b2.k0

    @pytest.mark.parametrize("alg", ["dsgd_aau", "ad_psgd", "prague", "agp"])
    def test_dense_pack_unpack_pack(self, alg):
        sched = _sched(alg)
        evs = list(itertools.islice(sched.events(), 16))
        b1 = EventBatch.from_events(evs, edge_bound=sched.edge_bound())
        b2 = EventBatch.from_events(b1.to_events(),
                                    edge_bound=sched.edge_bound())
        for field in ("times", "P", "grad_workers", "restart_workers",
                      "param_copies_sent", "edges", "n_edges"):
            np.testing.assert_array_equal(getattr(b1, field),
                                          getattr(b2, field), err_msg=field)

    def test_dense_stack_matches_lazy_per_event_dense(self):
        """The vectorized identity+scatter P stack equals stacking each
        event's lazily-materialized dense matrix."""
        sched = _sched("dsgd_aau")
        evs = list(itertools.islice(sched.events(), 12))
        batch = EventBatch.from_events(evs, edge_bound=sched.edge_bound())
        ref = np.stack([ev.P for ev in evs]).astype(np.float32)
        np.testing.assert_array_equal(batch.P, ref)


class TestADPSGDIsolatedWorkers:
    """Regression: a worker with no graph neighbors must not acquire the
    atomic-averaging lock, pay ``avg_time``, or send copies (it has nobody
    to average with)."""

    def _events(self, avg_time=0.25, nev=40):
        g = _disconnected_graph()
        # deterministic completion times: every local computation takes
        # exactly base_time, so lock-free behavior is directly readable
        sm = StragglerModel(n=5, straggler_prob=0.0, slowdown=1.0,
                            jitter=0.0, seed=0)
        sched = make_scheduler("ad_psgd", g, sm, avg_time=avg_time)
        return list(itertools.islice(sched.events(), nev))

    def test_isolated_worker_skips_lock_and_sends_nothing(self):
        evs = self._events()
        iso = [ev for ev in evs if 4 in ev.workers]
        assert iso, "isolated worker must still fire events"
        for ev in iso:
            assert ev.workers.tolist() == [4]
            assert ev.param_copies_sent == 0
            assert len(ev.edges) == 0
            np.testing.assert_array_equal(ev.P_sub, np.ones((1, 1)))
            # completion times are exact multiples of base_time: no avg_time
            # (0.25·base) was ever added, so no lock was acquired
            assert float(ev.time) == pytest.approx(round(float(ev.time)))

    def test_connected_component_still_serializes(self):
        evs = self._events()
        conn = [ev for ev in evs if 4 not in ev.workers]
        for ev in conn:
            assert ev.param_copies_sent == 2
            assert len(ev.edges) == 1
        # lock serialization: connected events are avg_time apart and never
        # earlier than the previous one
        ts = [float(ev.time) for ev in conn]
        assert all(t2 - t1 >= 0.25 - 1e-12 for t1, t2 in zip(ts, ts[1:]))

    def test_stream_stays_time_sorted_across_components(self):
        """Lock-shifted connected events and raw-time isolated events must
        still come out globally time-sorted (the reorder buffer), otherwise
        ``max_time``-bounded consumers — which stop at the first event past
        the bound — would silently drop in-range isolated-worker events."""
        evs = self._events(avg_time=0.5, nev=60)
        assert [ev.k for ev in evs] == list(range(60))
        ts = [float(ev.time) for ev in evs]
        assert all(b >= a for a, b in zip(ts, ts[1:]))
        assert any(4 in ev.workers for ev in evs)

    def test_trainer_modes_agree_on_disconnected_graph(self):
        g = _disconnected_graph()
        data = ClassificationData(n_workers=5, d=16, n_classes=4,
                                  samples_per_worker=64, seed=0)

        def mk(mode):
            sm = StragglerModel(n=5, straggler_prob=0.2, slowdown=6.0, seed=0)
            return DecentralizedTrainer(
                make_scheduler("ad_psgd", g, sm), loss_fn, init_fn,
                lambda w, s: data.batch(w, s, batch_size=8),
                data.eval_batch(64), eta0=0.2, seed=0, mode=mode,
                block_size=5, batch_pool=32)

        ref = mk("per_event")
        res_ref = ref.run(max_events=20, eval_every=10)
        sparse = mk("sparse_scan")
        res_sparse = sparse.run(max_events=20, eval_every=10)
        for la, lb in zip(jax.tree.leaves(ref.W), jax.tree.leaves(sparse.W)):
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       atol=1e-6)
        assert res_sparse.final_loss == pytest.approx(res_ref.final_loss,
                                                      abs=1e-5)


class TestEventHorizonBatcher:
    @pytest.mark.parametrize("alg", ["ad_psgd", "agp"])
    def test_deterministic(self, alg):
        e1 = list(itertools.islice(_sched(alg, horizon=16).events(), 50))
        e2 = list(itertools.islice(_sched(alg, horizon=16).events(), 50))
        for a, b in zip(e1, e2):
            assert a.time == b.time
            np.testing.assert_array_equal(a.workers, b.workers)
            np.testing.assert_array_equal(a.P_sub, b.P_sub)

    @pytest.mark.parametrize("alg", ["ad_psgd", "agp"])
    def test_stream_invariants(self, alg):
        sched = _sched(alg, horizon=8)
        evs = list(itertools.islice(sched.events(), 60))
        assert [ev.k for ev in evs] == list(range(60))
        for ev in evs:
            assert np.allclose(ev.P.sum(axis=1), 1.0)
            if alg == "ad_psgd":
                assert is_doubly_stochastic(ev.P)
            for i, j in ev.active_edges:
                assert sched.graph.adj[i, j]
        if alg == "ad_psgd":  # the averaging lock keeps times ordered
            ts = [ev.time for ev in evs]
            assert all(b >= a for a, b in zip(ts, ts[1:]))

    def test_horizon_is_a_different_realization(self):
        """Documented trade-off: vectorized draws reorder the RNG stream,
        so horizon events differ from the exact per-event stream."""
        exact = [ev.time for ev in
                 itertools.islice(_sched("ad_psgd").events(), 50)]
        horizon = [ev.time for ev in
                   itertools.islice(_sched("ad_psgd", horizon=16).events(), 50)]
        assert exact != horizon

    def test_trainer_modes_agree_on_horizon_stream(self):
        def mk(mode):
            return _trainer(_sched("ad_psgd", horizon=8), mode,
                            block_size=7, batch_pool=48)
        ref = mk("per_event")
        res_ref = ref.run(max_events=30, eval_every=10)
        sparse = mk("sparse_scan")
        res_sparse = sparse.run(max_events=30, eval_every=10)
        for la, lb in zip(jax.tree.leaves(ref.W), jax.tree.leaves(sparse.W)):
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       atol=1e-6)
        for p_r, p_s in zip(res_ref.history, res_sparse.history):
            assert p_s.k == p_r.k and p_s.time == pytest.approx(p_r.time)
            assert p_s.loss == pytest.approx(p_r.loss, abs=1e-5)

    def test_bad_horizon_rejected(self):
        with pytest.raises(ValueError, match="horizon"):
            _sched("ad_psgd", horizon=0)


class TestMaxTimePoolSizing:
    def test_pool_derived_from_max_time(self):
        """A max_time-bounded scan run sizes its batch pool from a restart
        estimate instead of the old 64-draw fallback, so long runs don't
        silently revisit samples."""
        tr = _trainer(_sched("ad_psgd"), "scan")
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # the wrap warning must not fire
            tr.run(max_time=60.0, eval_every=50)
        # 2 × 60 / min base time (=1.0) = 120 draws per worker
        assert tr._pool_len == 120
        assert int(jnp.max(tr._ptr)) <= tr._pool_len

    def test_explicit_batch_pool_still_wins(self):
        tr = _trainer(_sched("ad_psgd"), "scan", batch_pool=24)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            tr.run(max_time=30.0, eval_every=50)
        assert tr._pool_len == 24
