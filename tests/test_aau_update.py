"""JAX update engine (core/aau.py): eq. (5) semantics, staleness, push-sum."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aau
from repro.core.consensus import metropolis_matrix
from repro.utils.tree import tree_stack


def _stacked_params(n, d, seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))}


class TestGossipMixDense:
    def test_matches_matrix_product(self):
        n, d = 8, 33
        W = _stacked_params(n, d)
        P = jnp.asarray(metropolis_matrix(n, [(0, 1), (2, 3), (4, 5)]),
                        jnp.float32)
        out = aau.gossip_mix_dense(W, P)
        expect = np.asarray(W["w"]).T @ np.asarray(P)
        np.testing.assert_allclose(np.asarray(out["w"]), expect.T, rtol=1e-5)

    def test_kernel_path_matches(self):
        n, d = 16, 640
        W = _stacked_params(n, d)
        P = jnp.asarray(metropolis_matrix(
            n, [(i, (i + 1) % n) for i in range(n)]), jnp.float32)
        o1 = aau.gossip_mix_dense(W, P, use_kernel=False)
        o2 = aau.gossip_mix_dense(W, P, use_kernel=True)
        np.testing.assert_allclose(np.asarray(o1["w"]), np.asarray(o2["w"]),
                                   atol=1e-5)

    def test_identity_preserves(self):
        W = _stacked_params(5, 7)
        out = aau.gossip_mix_dense(W, jnp.eye(5))
        np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(W["w"]))

    def test_average_consensus_fixed_point(self):
        """Repeated mixing over a connected ring converges to the average."""
        n, d = 8, 4
        W = _stacked_params(n, d)
        target = np.asarray(W["w"]).mean(0)
        P = jnp.asarray(metropolis_matrix(
            n, [(i, (i + 1) % n) for i in range(n)]), jnp.float32)
        for _ in range(200):
            W = aau.gossip_mix_dense(W, P)
        np.testing.assert_allclose(np.asarray(W["w"]),
                                   np.tile(target, (n, 1)), atol=1e-4)


class TestMaskedStep:
    def test_masked_workers_keep_params(self):
        n, d = 6, 5
        W = _stacked_params(n, d)
        S = W
        y = jnp.ones((n,))
        grads = {"w": jnp.ones((n, d))}
        P = jnp.eye(n)
        gm = jnp.asarray([True, False, False, False, False, False])
        W2, S2, y2 = aau.masked_gossip_step(W, S, y, grads, P, gm, gm,
                                            jnp.float32(0.1))
        np.testing.assert_allclose(np.asarray(W2["w"][1:]),
                                   np.asarray(W["w"][1:]))
        np.testing.assert_allclose(np.asarray(W2["w"][0]),
                                   np.asarray(W["w"][0]) - 0.1)

    def test_snapshot_refresh_only_on_restart(self):
        n, d = 4, 3
        W = _stacked_params(n, d, seed=1)
        S = _stacked_params(n, d, seed=2)
        grads = {"w": jnp.zeros((n, d))}
        gm = jnp.asarray([True, True, False, False])
        rm = jnp.asarray([True, False, False, False])
        W2, S2, _ = aau.masked_gossip_step(W, S, jnp.ones((n,)), grads,
                                           jnp.eye(n), gm, rm, jnp.float32(0.1))
        np.testing.assert_allclose(np.asarray(S2["w"][0]), np.asarray(W2["w"][0]))
        np.testing.assert_allclose(np.asarray(S2["w"][1:]), np.asarray(S["w"][1:]))

    def test_pushsum_debias(self):
        """Row-stochastic AGP push matrices preserve Σ w_j and Σ y_j; the
        mass-weighted average is invariant."""
        n, d = 4, 3
        W = _stacked_params(n, d)
        y = jnp.ones((n,))
        P = np.eye(n)
        P[0, 0] = 0.5
        P[0, 1] = 0.5                      # worker 0 pushes half to 1
        P = jnp.asarray(P, jnp.float32)
        grads = {"w": jnp.zeros((n, d))}
        gm = jnp.zeros((n,), bool)
        before = np.asarray(aau.debiased_average(W, y)["w"])
        W2, _, y2 = aau.masked_gossip_step(W, W, y, grads, P, gm, gm,
                                           jnp.float32(0.0))
        after = np.asarray(aau.debiased_average(W2, y2)["w"])
        assert y2[0] == pytest.approx(0.5)
        np.testing.assert_allclose(np.asarray(W2["w"]).sum(0),
                                   np.asarray(W["w"]).sum(0), rtol=1e-6)
        # mass-weighted mean preserved
        np.testing.assert_allclose(
            (np.asarray(W2["w"]) / np.asarray(y2)[:, None] *
             np.asarray(y2)[:, None]).mean(0),
            np.asarray(W["w"]).mean(0), rtol=1e-6)


class TestShardedGossip:
    def test_ring_gossip_single_device_identity(self):
        # n=1 path (degenerate) — no permutes
        x = jnp.arange(6.0)
        out = aau.ring_gossip(x, "data", 1, jnp.float32(1.0),
                              jnp.float32(0), jnp.float32(0))
        np.testing.assert_allclose(np.asarray(out), np.asarray(x))

    def test_ring_gossip_shard_map_matches_dense(self):
        """shard_map ppermute ring == dense P·W with ring Metropolis weights."""
        n_dev = jax.device_count()
        if n_dev < 2:
            pytest.skip("needs >1 device")  # covered by test_dryrun subprocess

    def test_tree_ring_gossip_preserves_dtype(self):
        x = {"a": jnp.ones((4, 3), jnp.bfloat16)}
        out = aau.tree_ring_gossip(x, "data", 1, jnp.float32(1),
                                   jnp.float32(0), jnp.float32(0))
        assert out["a"].dtype == jnp.bfloat16
