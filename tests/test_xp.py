"""Experiment harness: spec validation, sweep aggregation, artifact schema,
NaN-honest speedup reporting, and the trainer dtype policy.
"""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.xp import (ExperimentSpec, artifact_payload, build_trainer,
                      csv_rows, load_artifact, run_spec, smoke_spec,
                      speedup_rows, write_artifact)

TINY = ExperimentSpec(
    name="tiny",
    algorithms=("dsgd_aau", "ad_psgd"),
    reference="dsgd_sync",
    scenarios=("paper_default", "churn"),
    scales=(6,),
    seeds=(0, 1),
    mode="sparse_scan",
    max_events=16,
    eval_every=8,
    target_loss=2.5,  # reached almost immediately: speedups stay finite
)


@pytest.fixture(scope="module")
def tiny_sweep():
    return run_spec(TINY)


class TestSpec:
    def test_rejects_unknown_algorithm(self):
        with pytest.raises(KeyError):
            ExperimentSpec(algorithms=("nope",))

    def test_rejects_unbounded(self):
        with pytest.raises(ValueError):
            ExperimentSpec(max_events=None, max_time=None)

    def test_round_trips_to_dict(self):
        d = TINY.to_dict()
        assert d["name"] == "tiny"
        json.dumps(d)  # JSON-serializable
        assert ExperimentSpec(**{**d, "algorithms": tuple(d["algorithms"]),
                                 "reference": d["reference"],
                                 "scenarios": tuple(d["scenarios"]),
                                 "scales": tuple(d["scales"]),
                                 "seeds": tuple(d["seeds"])}).name == "tiny"

    def test_smoke_preset_covers_all_scenarios(self):
        from repro.scenarios import scenario_names
        assert smoke_spec().scenarios == scenario_names()


class TestSweep:
    def test_record_grid_complete(self, tiny_sweep):
        # 2 scenarios × 1 scale × 2 seeds × (ref + 2 algs)
        assert len(tiny_sweep.records) == 2 * 1 * 2 * 3
        for r in tiny_sweep.records:
            assert r.result.total_events == 16
            assert np.isfinite(r.result.final_loss)

    def test_speedup_rows_aggregate_seeds(self, tiny_sweep):
        rows = speedup_rows(tiny_sweep)
        assert {(r["scenario"], r["algorithm"]) for r in rows} == {
            ("paper_default", "dsgd_aau"), ("paper_default", "ad_psgd"),
            ("churn", "dsgd_aau"), ("churn", "ad_psgd")}
        for r in rows:
            assert r["n_seeds"] == 2
            assert r["unreached"] == 0
            assert math.isfinite(r["speedup_mean"])
            assert r["speedup_std"] >= 0

    def test_artifact_schema_and_round_trip(self, tiny_sweep, tmp_path):
        payload = artifact_payload(tiny_sweep)
        assert set(payload) == {"meta", "scenarios", "speedup_vs_n",
                                "convergence", "dtype_policy"}
        assert payload["meta"]["spec"]["name"] == "tiny"
        assert set(payload["scenarios"]) == {"paper_default", "churn"}
        conv = payload["convergence"]
        assert all(c["points"] for c in conv)
        p = str(tmp_path / "artifact.json")
        write_artifact(p, payload)
        back = load_artifact(p)
        assert back["meta"]["spec"]["scales"] == [6]
        rows = csv_rows(back)
        assert rows and all(len(r.split(",")) == 3 for r in rows)

    def test_reference_unreached_keeps_algorithm_time(self):
        """When only the sync reference misses the target, the row must say
        so (unreached_ref) and keep the algorithm's measured t_target."""
        from repro.core.runner import RunResult
        from repro.xp.sweep import RunRecord, SweepResult

        def rec(alg, t_target):
            res = RunResult(algorithm=alg, history=[], final_loss=1.0,
                            final_metric=0.0, total_events=10,
                            total_time=5.0, total_comm_copies=0,
                            param_count=1)
            return RunRecord(scenario="paper_default", algorithm=alg, n=6,
                             seed=0, dtype="float32", wall_s=0.1,
                             t_target=t_target, result=res)

        spec = TINY.replace(algorithms=("ad_psgd",), seeds=(0,))
        sweep = SweepResult(
            spec=spec, records=[rec("dsgd_sync", None), rec("ad_psgd", 2.5)],
            dtype_rows=[], scenario_meta={"paper_default": {}})
        (row,) = speedup_rows(sweep)
        assert math.isnan(row["speedup_mean"])
        assert row["unreached"] == 0 and row["unreached_ref"] == 1
        assert row["t_target_mean"] == pytest.approx(2.5)
        line = [l for l in csv_rows({"speedup_vs_n": [row]})
                if "/speedup/" in l][0]
        assert "t_target=2.5" in line and "t_sync=unreached" in line
        assert "unreached_ref=1/1" in line

    def test_unreached_target_reports_nan_not_zero(self):
        spec = TINY.replace(scenarios=("paper_default",), seeds=(0,),
                            target_loss=1e-9)  # unreachable in 16 events
        sweep = run_spec(spec)
        rows = speedup_rows(sweep)
        assert rows
        for r in rows:
            assert math.isnan(r["speedup_mean"])
            assert r["unreached"] == r["n_seeds"]
        for line in csv_rows(artifact_payload(sweep)):
            if "/speedup/" in line:
                assert "speedup_vs_sync=nan" in line
                assert "t_target=unreached" in line
                assert "=0.0" not in line.split(",", 2)[2]


class TestDtypePolicy:
    def test_bf16_worker_state(self):
        tr = build_trainer(TINY, "ad_psgd", 6, seed=0, dtype="bfloat16")
        for leaf in jax.tree.leaves(tr.W):
            assert leaf.dtype == jnp.bfloat16
        res = tr.run(max_events=8, eval_every=8)
        assert np.isfinite(res.final_loss)
        for leaf in jax.tree.leaves(tr._pools):
            if jnp.issubdtype(leaf.dtype, jnp.floating):
                assert leaf.dtype == jnp.bfloat16
        assert tr.y.dtype == jnp.float32  # push-sum weights stay fp32

    def test_fp32_default_unchanged(self):
        tr = build_trainer(TINY, "ad_psgd", 6, seed=0)
        assert all(l.dtype == jnp.float32 for l in jax.tree.leaves(tr.W))

    @pytest.mark.parametrize("mode", ["scan", "per_event"])
    def test_bf16_survives_dense_paths(self, mode):
        """The dense scan must carry bf16 without promotion (a lax.scan
        carry keeps its dtype), and the per-event step must not silently
        promote the state back to fp32 after the first event."""
        spec = TINY.replace(mode=mode)
        tr = build_trainer(spec, "dsgd_sync", 6, seed=0, dtype="bfloat16")
        res = tr.run(max_events=6, eval_every=6)
        assert np.isfinite(res.final_loss)
        for leaf in jax.tree.leaves(tr.W):
            assert leaf.dtype == jnp.bfloat16

    def test_bad_dtype_rejected(self):
        with pytest.raises((ValueError, TypeError)):
            build_trainer(TINY, "ad_psgd", 6, seed=0, dtype="int32")

    def test_spec_threads_dtype(self):
        spec = TINY.replace(dtype="bfloat16")
        tr = build_trainer(spec, "dsgd_aau", 6, seed=0)
        assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(tr.W))
