"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED variant
(≤2 layers / pattern, d_model ≤ 512, ≤4 experts), run one forward and one
train step on CPU, assert output shapes and no NaNs; run one decode step; and
check forward↔decode consistency (exactly for non-MoE, drop-free-capacity
MoE for the rest).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import (decode_step, forward, init_decode_state, init_model,
                          lm_loss, param_count)
from repro.models.multimodal import make_stub_prefix
from repro.models.transformer import prefill
from repro.optim import apply_updates, sgd


def _setup(name, **cfg_over):
    cfg = get_config(name).reduced()
    if cfg_over:
        cfg = dataclasses.replace(cfg, **cfg_over)
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, T = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend:
        batch["prefix"] = make_stub_prefix(jax.random.PRNGKey(2), cfg, B)
    return cfg, params, batch


@pytest.mark.parametrize("name", ASSIGNED)
class TestArchSmoke:
    def test_reduced_config_bounds(self, name):
        cfg = get_config(name).reduced()
        assert cfg.n_layers <= 3
        assert cfg.d_model <= 512
        assert cfg.n_experts <= 4

    def test_forward_shapes_no_nans(self, name):
        cfg, params, batch = _setup(name)
        logits, aux = forward(params, cfg, batch["tokens"],
                              prefix_embeds=batch.get("prefix"))
        B, T = batch["tokens"].shape
        assert logits.shape == (B, T, cfg.vocab_size)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        assert np.isfinite(float(aux))

    def test_one_train_step_decreases_loss(self, name):
        cfg, params, batch = _setup(name)
        opt = sgd()
        loss_fn = lambda p: lm_loss(p, cfg, batch)
        l0, g = jax.value_and_grad(loss_fn)(params)
        upd, _ = opt.update(g, opt.init(params), params, jnp.float32(0.5))
        params2 = apply_updates(params, upd)
        l1 = loss_fn(params2)
        assert np.isfinite(float(l0)) and np.isfinite(float(l1))
        assert float(l1) < float(l0)

    def test_decode_step_shapes(self, name):
        cfg, params, batch = _setup(name)
        B = batch["tokens"].shape[0]
        st = init_decode_state(cfg, B, 32)
        logits, st2 = decode_step(params, cfg, batch["tokens"][:, 0], st,
                                  jnp.int32(0))
        assert logits.shape == (B, cfg.vocab_size)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        # state structure preserved
        jax.tree.map(lambda a, b: (_ for _ in ()).throw(AssertionError())
                     if a.shape != b.shape else None, st, st2)

    def test_prefill_matches_forward_last_token(self, name):
        over = {"moe_capacity_factor": 64.0} if "moe" in get_config(name).family else {}
        cfg, params, batch = _setup(name, **over)
        logits, _ = forward(params, cfg, batch["tokens"],
                            prefix_embeds=batch.get("prefix"))
        last, states = prefill(params, cfg, batch["tokens"], cache_len=64,
                               prefix_embeds=batch.get("prefix"))
        np.testing.assert_allclose(np.asarray(last),
                                   np.asarray(logits[:, -1]), atol=1e-4)

    def test_decode_chain_matches_forward(self, name):
        over = {"moe_capacity_factor": 64.0} if "moe" in get_config(name).family else {}
        cfg, params, batch = _setup(name, **over)
        toks = batch["tokens"][:1, :8]
        pf = batch.get("prefix")
        pf = pf[:1] if pf is not None else None
        logits_full, _ = forward(params, cfg, toks, prefix_embeds=pf)
        st = init_decode_state(cfg, 1, 32)
        off = cfg.n_prefix_tokens if cfg.frontend else 0
        if cfg.frontend:
            # prefix is consumed via prefill; decode continues after it
            _, st = prefill(params, cfg, toks[:, :1], cache_len=32,
                            prefix_embeds=pf)
            lg, st = decode_step(params, cfg, toks[0, 1][None], st,
                                 jnp.int32(off + 1))
            assert np.all(np.isfinite(np.asarray(lg, np.float32)))
            return
        outs = []
        for t in range(8):
            lg, st = decode_step(params, cfg, toks[:, t], st, jnp.int32(t))
            outs.append(lg)
        dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                                   atol=2e-4)

    def test_param_count_positive(self, name):
        cfg = get_config(name)
        n = param_count(cfg.reduced())
        assert n > 1e5


class TestFullConfigMetadata:
    """The FULL configs are exercised only via the dry-run; here we verify
    their analytic metadata matches the assignment table."""

    @pytest.mark.parametrize("name,layers,d_model,vocab", [
        ("deepseek-67b", 95, 8192, 102400),
        ("rwkv6-1.6b", 24, 2048, 65536),
        ("minicpm-2b", 40, 2304, 122753),
        ("musicgen-large", 48, 2048, 2048),
        ("grok-1-314b", 64, 6144, 131072),
        ("mistral-nemo-12b", 40, 5120, 131072),
        ("arctic-480b", 35, 7168, 32000),
        ("llava-next-mistral-7b", 32, 4096, 32000),
        ("recurrentgemma-2b", 26, 2560, 256000),
        ("qwen3-8b", 36, 4096, 151936),
    ])
    def test_assignment_table(self, name, layers, d_model, vocab):
        cfg = get_config(name)
        assert cfg.n_layers == layers
        assert cfg.d_model == d_model
        assert cfg.vocab_size == vocab

    @pytest.mark.parametrize("name,lo,hi", [
        ("deepseek-67b", 60e9, 75e9),
        ("grok-1-314b", 290e9, 340e9),
        ("arctic-480b", 440e9, 520e9),
        ("mistral-nemo-12b", 11e9, 14e9),
        ("qwen3-8b", 7e9, 10e9),
        ("rwkv6-1.6b", 1.2e9, 2.2e9),
        ("recurrentgemma-2b", 2.0e9, 3.6e9),
        ("minicpm-2b", 2.0e9, 3.3e9),
    ])
    def test_param_counts_match_names(self, name, lo, hi):
        n = param_count(get_config(name))
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B params"

    def test_moe_active_counts(self):
        from repro.models import active_param_count
        g = get_config("grok-1-314b")
        assert active_param_count(g) < 0.5 * param_count(g)
