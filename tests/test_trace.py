"""Virtual-time tracing (repro/obs/trace + critical_path): cross-mode
trace equality, Chrome-trace schema validity, the wait-blame oracle,
zero trajectory drift, and consistency with the telemetry counters.

The contract under test:

- the finalized :class:`Trace` is **bit-identical** across ``per_event``,
  ``scan`` and ``sparse_scan`` (incl. bucketed dispatch) of the same
  scheduler stream — all four host modes record the pre-merge, pre-pad
  identity stream the driving loop already holds;
- ``fused`` is a different-but-deterministic RNG realization: its trace
  is internally consistent and identical across reruns, not
  event-matched to the host modes';
- tracing is a pure observer: trajectories are bit-identical with it on
  or off;
- ``Σ blame + residual_wait == Σ wait`` exactly, and the blame pass's
  busy/wait vectors reproduce telemetry's ``busy_t``/``idle_t`` (f64 vs
  f32 tolerance) — the blame table is a lossless decomposition of the
  utilization numbers;
- the critical path tiles ``[0, t_end]``: ``compute_t + wait_t == t_end``
  and consecutive segments abut exactly;
- :func:`chrome_trace` emits a valid Chrome Trace Event Format document
  (JSON-serializable, complete spans, paired flow arrows).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology
from repro.core.baselines import make_scheduler
from repro.core.runner import DecentralizedTrainer
from repro.core.straggler import StragglerModel
from repro.data.synthetic import ClassificationData
from repro.obs.critical_path import (attribute_wait, critical_path,
                                     straggler_tax)
from repro.obs.trace import Trace, chrome_trace, load_run_log, wall_track
from repro.obs.trace import main as trace_main

N = 16
DATA = ClassificationData(n_workers=N, d=16, n_classes=4,
                          samples_per_worker=64, seed=0)


def loss_fn(params, batch):
    logits = batch["x"] @ params["w"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], axis=1))


def init_fn(key):
    return {"w": jax.random.normal(key, (16, 4)) * 0.1}


def _sched(alg, seed=0, slowdown=6.0, **kw):
    g = topology.erdos_renyi(N, 0.4, seed=3)
    sm = StragglerModel(n=N, straggler_prob=0.2, slowdown=slowdown,
                        seed=seed)
    return make_scheduler(alg, g, sm, **kw)


def _trainer(alg, mode, seed=0, sched_kw=None, **kw):
    kw.setdefault("trace", True)
    return DecentralizedTrainer(
        _sched(alg, seed, **(sched_kw or {})), loss_fn, init_fn,
        lambda w, s: DATA.batch(w, s, batch_size=8),
        DATA.eval_batch(64), eta0=0.2, eta_decay=0.99, seed=seed,
        mode=mode, **kw)


_TRACE_FIELDS = ("times", "copies", "lane_ev", "lane_worker", "lane_fin",
                 "lane_grad", "lane_restart", "edge_ev", "edge_src",
                 "edge_dst")


def _assert_trace_equal(a: Trace, b: Trace, ctx=""):
    assert a.n == b.n, ctx
    for f in _TRACE_FIELDS:
        va, vb = getattr(a, f), getattr(b, f)
        if va.dtype == np.float64:  # compare clocks bitwise, not approx
            va, vb = va.view(np.uint64), vb.view(np.uint64)
        np.testing.assert_array_equal(va, vb,
                                      err_msg=f"{ctx}: Trace.{f} differs")


class TestCrossModeTraceEqual:
    """per_event / scan / sparse_scan record bit-identical traces."""

    EVENTS = 60

    @pytest.mark.parametrize("alg,sched_kw", [
        ("dsgd_aau", {"buckets": (4, 8, 16)}),   # forces bucketed dispatch
        ("ad_psgd", {}),
    ])
    def test_modes_bit_identical(self, alg, sched_kw):
        traces, summaries = {}, {}
        for mode in ("per_event", "scan", "sparse_scan"):
            tr = _trainer(alg, mode, sched_kw=sched_kw)
            res = tr.run(max_events=self.EVENTS, eval_every=20)
            traces[mode] = tr.last_trace
            summaries[mode] = res.trace
        _assert_trace_equal(traces["per_event"], traces["scan"],
                            f"{alg} per_event vs scan")
        _assert_trace_equal(traces["per_event"], traces["sparse_scan"],
                            f"{alg} per_event vs sparse_scan")
        # the blame summaries are pure functions of the trace, minus the
        # mode tag itself
        for mode in ("scan", "sparse_scan"):
            s, ref = dict(summaries[mode]), dict(summaries["per_event"])
            s.pop("mode"), ref.pop("mode")
            assert s == ref, f"{alg}: summary drift in {mode}"

    def test_sync_scan_matches_per_event(self):
        traces = {}
        for mode in ("per_event", "scan"):
            tr = _trainer("dsgd_sync", mode)
            tr.run(max_events=48, eval_every=16)
            traces[mode] = tr.last_trace
        _assert_trace_equal(traces["per_event"], traces["scan"],
                            "dsgd_sync per_event vs scan")

    def test_trace_is_well_formed(self):
        tr = _trainer("dsgd_aau", "sparse_scan")
        res = tr.run(max_events=self.EVENTS, eval_every=20)
        t = tr.last_trace
        assert t.n_events == res.total_events
        assert (np.diff(t.lane_ev) >= 0).all()        # stream order
        assert (np.diff(t.edge_ev) >= 0).all()
        assert (np.diff(t.times) >= 0).all()          # commit clocks sorted
        assert (t.lane_fin <= t.times[t.lane_ev] + 1e-6).all()
        assert int(t.copies.sum()) == res.total_comm_copies
        assert t.algorithm == "dsgd_aau" and t.mode == "sparse_scan"


class TestFusedTrace:
    """mode="fused": one drain, deterministic, internally consistent."""

    def test_deterministic_across_reruns(self):
        traces = []
        for _ in range(2):
            tr = _trainer("ad_psgd", "fused")
            tr.run(max_events=48, eval_every=16)
            traces.append(tr.last_trace)
        _assert_trace_equal(traces[0], traces[1], "fused rerun")

    def test_internally_consistent(self):
        tr = _trainer("ad_psgd", "fused")
        res = tr.run(max_events=48, eval_every=16)
        t = tr.last_trace
        assert t.mode == "fused" and t.n_events == res.total_events
        assert int(t.copies.sum()) == res.total_comm_copies
        # every event has exactly one grad/restart lane (the finisher)
        assert int(t.lane_grad.sum()) == t.n_events
        np.testing.assert_array_equal(t.lane_grad, t.lane_restart)
        assert (t.lane_fin <= t.times[t.lane_ev] + 1e-6).all()
        # summary survives alongside telemetry (shared widened outputs)
        assert res.trace is not None
        assert res.trace["algorithm"] == "ad_psgd"


class TestBlameOracle:
    """Hand-built 3-worker schedule with known attribution."""

    @staticmethod
    def _trace():
        # ev0 @ t=4.0: all three restart, fins (2, 4, 3)  → gate w1
        # ev1 @ t=7.5: w0, w1 restart,    fins (6, 7)     → gate w1,
        #              commit 0.5 after the gate fin → residual 2·0.5
        # ev2 @ t=9.0: w2 restarts alone, fin 9           → gate w2
        return Trace(
            n=3,
            times=np.array([4.0, 7.5, 9.0]),
            copies=np.array([4, 2, 0], dtype=np.int64),
            lane_ev=np.array([0, 0, 0, 1, 1, 2], dtype=np.int64),
            lane_worker=np.array([0, 1, 2, 0, 1, 2], dtype=np.int32),
            lane_fin=np.array([2.0, 4.0, 3.0, 6.0, 7.0, 9.0]),
            lane_grad=np.ones(6, dtype=bool),
            lane_restart=np.ones(6, dtype=bool),
            edge_ev=np.array([0, 0, 1], dtype=np.int64),
            edge_src=np.array([0, 1, 0], dtype=np.int32),
            edge_dst=np.array([1, 2, 1], dtype=np.int32),
            algorithm="oracle")

    def test_attribution_matches_hand_computation(self):
        attr = attribute_wait(self._trace())
        np.testing.assert_allclose(attr["blame"], [0.0, 4.0, 0.0])
        np.testing.assert_allclose(attr["busy"], [4.0, 7.0, 8.0])
        np.testing.assert_allclose(attr["wait"], [3.5, 0.5, 1.0])
        assert attr["residual_wait"] == pytest.approx(1.0)
        np.testing.assert_array_equal(attr["gate_worker"], [1, 1, 2])
        np.testing.assert_allclose(attr["gate_fin"], [4.0, 7.0, 9.0])
        # gate DAG edges: ev0's gate had no prior restart; ev1's gate (w1)
        # last restarted at ev0; ev2's gate (w2) likewise
        np.testing.assert_array_equal(attr["gate_prev_ev"], [-1, 0, 0])
        np.testing.assert_allclose(attr["gate_prev_t"], [0.0, 4.0, 4.0])

    def test_critical_path_walks_gates(self):
        cp = critical_path(self._trace())
        # backward from ev2 (gate w2, started at ev0's commit) to ev0
        assert [s["event"] for s in cp["segments"]] == [0, 2]
        assert [s["worker"] for s in cp["segments"]] == [1, 2]
        assert cp["compute_t"] == pytest.approx(9.0)
        assert cp["wait_t"] == pytest.approx(0.0)
        assert cp["t_end"] == pytest.approx(9.0)

    def test_summary(self):
        s = straggler_tax(self._trace())
        assert s["blame_total"] == pytest.approx(4.0)
        assert s["residual_wait"] == pytest.approx(1.0)
        # blame_total + residual ≡ total wait, tax = wait / (busy + wait)
        assert s["wait_t"] == pytest.approx(5.0)
        # summary fields round to 6 decimals (JSON friendliness)
        assert s["straggler_tax"] == pytest.approx(5.0 / 24.0, abs=1e-6)
        assert s["blame_top"][0] == {"worker": 1, "blame_t": 4.0,
                                     "share": 1.0}


class TestAttributionInvariants:
    """Blame ≡ wait decomposition; agreement with telemetry counters."""

    @pytest.mark.parametrize("alg,sched_kw", [
        ("dsgd_aau", {"buckets": (4, 8, 16)}),
        ("ad_psgd", {}),
        ("dsgd_sync", {}),
    ])
    def test_blame_plus_residual_is_total_wait(self, alg, sched_kw):
        tr = _trainer(alg, "scan" if alg == "dsgd_sync" else "sparse_scan",
                      sched_kw=sched_kw)
        tr.run(max_events=60, eval_every=20)
        attr = attribute_wait(tr.last_trace)
        total_wait = float(attr["wait"].sum())
        assert float(attr["blame"].sum()) + float(attr["residual_wait"]) \
            == pytest.approx(total_wait, rel=1e-9, abs=1e-9)
        if alg == "ad_psgd":
            # single-finisher gates: all wait is protocol (lock) residual
            assert float(attr["blame"].sum()) == 0.0

    def test_matches_telemetry_counters(self):
        tr = _trainer("dsgd_aau", "sparse_scan", telemetry=True)
        tr.run(max_events=60, eval_every=20)
        attr = attribute_wait(tr.last_trace)
        M = jax.device_get(tr._metrics)
        np.testing.assert_allclose(attr["busy"], np.asarray(M.busy_t),
                                   rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(attr["wait"], np.asarray(M.idle_t),
                                   rtol=1e-5, atol=1e-4)

    def test_critical_path_tiles_the_run(self):
        tr = _trainer("dsgd_aau", "sparse_scan")
        tr.run(max_events=60, eval_every=20)
        trace = tr.last_trace
        cp = critical_path(trace)
        assert cp["compute_t"] + cp["wait_t"] == pytest.approx(
            cp["t_end"], rel=1e-9)
        segs = cp["segments"]
        assert segs[0]["t_start"] == 0.0
        assert segs[-1]["t_commit"] == pytest.approx(float(trace.times[-1]))
        for a, b in zip(segs, segs[1:]):  # consecutive segments abut
            assert b["t_start"] == pytest.approx(a["t_commit"])


class TestZeroDrift:
    """Tracing is a pure observer: bit-identical state with it on/off."""

    @pytest.mark.parametrize("alg,mode", [
        ("dsgd_aau", "scan"),
        ("dsgd_aau", "sparse_scan"),
        ("dsgd_aau", "per_event"),
        ("ad_psgd", "fused"),
    ])
    def test_state_and_history_identical(self, alg, mode):
        results = {}
        for on in (False, True):
            tr = _trainer(alg, mode, trace=on)
            res = tr.run(max_events=48, eval_every=16)
            results[on] = (res, np.asarray(tr.y))
        r0, y0 = results[False]
        r1, y1 = results[True]
        np.testing.assert_array_equal(
            y0.view(np.uint32), y1.view(np.uint32),
            err_msg=f"{alg}/{mode}: consensus state drifts with trace")
        assert [(h.k, h.time, h.loss) for h in r0.history] \
            == [(h.k, h.time, h.loss) for h in r1.history]
        assert r0.total_comm_copies == r1.total_comm_copies
        assert r1.trace is not None and r0.trace is None


_SPAN_KEYS = {"name", "ph", "pid", "tid", "ts", "dur"}


def _validate_chrome(doc):
    """Chrome Trace Event Format (JSON Array/Object format) checks."""
    json.loads(json.dumps(doc))  # serializable, round-trips
    assert isinstance(doc["traceEvents"], list)
    flows = {}
    for e in doc["traceEvents"]:
        assert isinstance(e["name"], str) and e["name"]
        assert e["ph"] in ("X", "M", "s", "f", "i")
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        if e["ph"] == "X":
            assert _SPAN_KEYS <= set(e)
            assert e["ts"] >= 0 and e["dur"] >= 0
        elif e["ph"] in ("s", "f"):
            flows.setdefault(e["id"], []).append(e["ph"])
        elif e["ph"] == "i":
            assert e["s"] in ("t", "p", "g")
    for fid, phs in flows.items():
        assert sorted(phs) == ["f", "s"], f"unpaired flow id {fid}"


class TestChromeTraceExport:
    def test_virtual_track_schema(self):
        tr = _trainer("dsgd_aau", "sparse_scan")
        tr.run(max_events=60, eval_every=20)
        doc = chrome_trace(trace=tr.last_trace)
        _validate_chrome(doc)
        evs = doc["traceEvents"]
        assert any(e["ph"] == "X" and e["name"] == "compute" for e in evs)
        assert any(e["ph"] == "X" and e["name"] == "wait" for e in evs)
        assert any(e["ph"] == "s" for e in evs)  # gossip flow arrows
        assert doc["otherData"]["algorithm"] == "dsgd_aau"
        # thread metadata names every worker
        names = {e["tid"] for e in evs
                 if e["ph"] == "M" and e["name"] == "thread_name"}
        assert names == set(range(N))

    def test_wall_track_from_run_log(self, tmp_path):
        log = tmp_path / "run.jsonl"
        tr = _trainer("dsgd_aau", "sparse_scan", run_log=str(log))
        tr.run(max_events=48, eval_every=16)
        records = load_run_log(str(log))
        assert all("ts" in r for r in records)
        doc = chrome_trace(trace=tr.last_trace, run_log=records)
        _validate_chrome(doc)
        walls = [e for e in doc["traceEvents"] if e["pid"] == 1]
        assert any(e["ph"] == "X" and e["name"].startswith("dispatch:")
                   for e in walls)
        assert any(e["ph"] == "i" for e in walls)  # lifecycle instants

    def test_cli_round_trip(self, tmp_path, capsys):
        log = tmp_path / "run.jsonl"
        tr = _trainer("ad_psgd", "sparse_scan", run_log=str(log))
        tr.run(max_events=48, eval_every=16)
        out = tmp_path / "out.trace.json"
        assert trace_main([str(log), "-o", str(out)]) == 0
        assert "wrote" in capsys.readouterr().out
        doc = json.loads(out.read_text())
        _validate_chrome(doc)
        assert any(e["ph"] == "X" for e in doc["traceEvents"])

    def test_malformed_log_lines_skipped(self, tmp_path):
        log = tmp_path / "bad.jsonl"
        log.write_text('{"event": "a", "ts": 0.5}\nnot json\n\n[1, 2]\n')
        records = load_run_log(str(log))
        assert records == [{"event": "a", "ts": 0.5}]
        _validate_chrome(chrome_trace(run_log=records))

    def test_wall_track_span_durations_bracket(self):
        recs = [{"event": "block_dispatch", "ts": 0.0, "mode": "scan"},
                {"event": "block_dispatch", "ts": 0.25, "mode": "scan"},
                {"event": "run_end", "ts": 0.3}]
        spans = [e for e in wall_track(recs) if e["ph"] == "X"]
        assert [s["dur"] for s in spans] == [pytest.approx(0.25e6),
                                             pytest.approx(0.05e6)]
