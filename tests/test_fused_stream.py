"""Device-resident event streaming: packed-native generation, event-blocked
merged dispatch, and the fused on-device generator (``mode="fused"``).

Three equivalence tiers, matching the three tentpole stages:

- the array-native packed generators (``packed_stream(native=True)``) must
  be **bit-identical** to the object-path adapter, chunk by chunk, for every
  scheduler — same RNG consumption, same float casts, same k0 bookkeeping;
- ``merge_event_groups`` + the runner's event-blocked dispatch must be
  **bit-exact** re-executions of the one-event-per-step sparse scan (the
  trajectory equivalence lives in tests/test_sparse_event_stream.py; here
  the merged-vs-unmerged runner paths are pinned against each other);
- the fused generator is a *different-but-deterministic* realization
  (horizon-order RNG), so it is pinned **distributionally**: exact event /
  restart / comm accounting, event-rate agreement with the exact stream,
  and per-(seed, block) determinism.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology
from repro.core.baselines import make_scheduler
from repro.core.runner import DecentralizedTrainer, choose_mode
from repro.core.scheduler import (BucketedSparseEventBatch, PackedEventStream,
                                  SparseEventBatch, merge_event_groups)
from repro.core.straggler import StragglerModel, TimeSampler
from repro.data.synthetic import ClassificationData
from repro.scenarios import get_scenario

N = 16
ALL_ALGS = ["dsgd_aau", "ad_psgd", "prague", "agp", "dsgd_sync"]
DATA = ClassificationData(n_workers=N, d=16, n_classes=4,
                          samples_per_worker=64, seed=0)


def loss_fn(params, batch):
    logits = batch["x"] @ params["w"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], axis=1))


def init_fn(key):
    return {"w": jax.random.normal(key, (16, 4)) * 0.1}


def _sched(alg, seed=0, straggler=None, **kw):
    g = topology.erdos_renyi(N, 0.4, seed=3)
    sm = straggler or StragglerModel(n=N, straggler_prob=0.2, slowdown=6.0,
                                     seed=seed)
    return make_scheduler(alg, g, sm, **kw)


def _trainer(alg, mode, seed=0, sched_kw=None, **kw):
    return DecentralizedTrainer(
        _sched(alg, seed, **(sched_kw or {})), loss_fn, init_fn,
        lambda w, s: DATA.batch(w, s, batch_size=8),
        DATA.eval_batch(64), eta0=0.2, eta_decay=0.99, seed=seed,
        mode=mode, **kw)


def _assert_sparse_equal(a: SparseEventBatch, b: SparseEventBatch):
    assert a.k0 == b.k0 and a.E == b.E and a.A == b.A
    np.testing.assert_array_equal(a.workers, b.workers)
    np.testing.assert_array_equal(a.n_workers, b.n_workers)
    np.testing.assert_array_equal(a.P_sub, b.P_sub)          # bit-exact
    np.testing.assert_array_equal(a.grad_workers, b.grad_workers)
    np.testing.assert_array_equal(a.restart_workers, b.restart_workers)
    np.testing.assert_array_equal(a.edges, b.edges)
    np.testing.assert_array_equal(a.n_edges, b.n_edges)
    np.testing.assert_array_equal(a.times, b.times)          # bit-exact
    np.testing.assert_array_equal(a.param_copies_sent, b.param_copies_sent)


def _assert_chunks_equal(a, b):
    assert type(a) is type(b)
    if isinstance(a, BucketedSparseEventBatch):
        assert a.k0 == b.k0 and a.buckets == b.buckets
        np.testing.assert_array_equal(a.event_bucket, b.event_bucket)
        np.testing.assert_array_equal(a.positions, b.positions)
        for sa, sb in zip(a.batches, b.batches):
            assert (sa is None) == (sb is None)
            if sa is not None:
                _assert_sparse_equal(sa, sb)
    else:
        _assert_sparse_equal(a, b)


class TestNativePackedGeneration:
    @pytest.mark.parametrize("alg", ALL_ALGS)
    def test_native_chunks_bit_identical_to_object_path(self, alg):
        native = _sched(alg).packed_stream(native=True)
        obj = _sched(alg).packed_stream(native=False)
        assert type(obj) is PackedEventStream
        for k in (7, 1, 12, 5):  # uneven chunk sizes exercise k0 bookkeeping
            _assert_chunks_equal(native.next_chunk(k), obj.next_chunk(k))

    @pytest.mark.parametrize("alg", ALL_ALGS)
    def test_native_stream_engaged(self, alg):
        # every built-in scheduler has an array-native generator
        assert _sched(alg)._native_packed_stream() is not None

    def test_horizon_scheduler_keeps_object_adapter(self):
        # the native pair stream replays the *exact* per-event RNG order;
        # horizon batching draws in a different order by construction
        sched = _sched("ad_psgd", sched_kw=None) if False else _sched(
            "ad_psgd", horizon=64)
        assert sched._native_packed_stream() is None
        assert type(sched.packed_stream(native=True)) is PackedEventStream


class TestMergeEventGroups:
    def _batch(self, alg="ad_psgd", events=24):
        sched = _sched(alg)
        evs = list(itertools.islice(sched.events(), events))
        return SparseEventBatch.from_events(
            evs, active_bound=sched.active_bound(),
            edge_bound=sched.edge_bound())

    def test_groups_are_conflict_free_and_order_preserving(self):
        batch = self._batch()
        merged, lane_off = merge_event_groups(batch, 4)
        assert merged.A == 4 * batch.A
        assert lane_off.shape == (merged.E, merged.A)
        assert merged.n_workers.sum() == batch.n_workers.sum()
        prev_last = -1
        for g in range(merged.E):
            valid = merged.workers[g] >= 0
            w = merged.workers[g][valid]
            # pairwise-disjoint worker sets within one scan step
            assert len(set(w.tolist())) == len(w)
            # offsets map each lane back to its source event, in stream order
            offs = lane_off[g][valid]
            assert (np.diff(offs) >= 0).all()
            assert offs[0] > prev_last  # groups partition the stream
            prev_last = int(offs[-1])
            for lane, off in zip(np.where(valid)[0], offs):
                assert merged.workers[g, lane] in batch.workers[off]
        assert prev_last == batch.E - 1

    def test_merged_payload_matches_sources(self):
        batch = self._batch()
        merged, lane_off = merge_event_groups(batch, 4)
        # group time is the last member's; copies are summed over members
        assert merged.param_copies_sent.sum() == batch.param_copies_sent.sum()
        e = 0
        for g in range(merged.E):
            members = np.unique(lane_off[g][merged.workers[g] >= 0])
            assert merged.times[g] == batch.times[int(members[-1])]
            e = int(members[-1]) + 1
        assert e == batch.E

    def test_k1_is_identity_with_arange_offsets(self):
        batch = self._batch()
        merged, lane_off = merge_event_groups(batch, 1)
        _assert_sparse_equal(merged, batch)
        np.testing.assert_array_equal(
            lane_off, np.broadcast_to(np.arange(batch.E)[:, None],
                                      (batch.E, batch.A)))

    @pytest.mark.parametrize("alg", ["ad_psgd", "dsgd_aau"])
    def test_merged_dispatch_bit_exact_vs_one_event_per_step(self, alg):
        one = _trainer(alg, "sparse_scan", block_size=8, batch_pool=48,
                       events_per_step=1)
        merged = _trainer(alg, "sparse_scan", block_size=8, batch_pool=48,
                          events_per_step=8)
        r1 = one.run(max_events=40, eval_every=10)
        r2 = merged.run(max_events=40, eval_every=10)
        np.testing.assert_array_equal(np.asarray(one.W["w"]),
                                      np.asarray(merged.W["w"]))
        np.testing.assert_array_equal(np.asarray(one.y), np.asarray(merged.y))
        assert r1.total_comm_copies == r2.total_comm_copies
        assert [p.loss for p in r1.history] == [p.loss for p in r2.history]


class TestChooseMode:
    def test_crossover_table(self):
        assert choose_mode(16, (2,)) == "scan"
        assert choose_mode(256, (2,)) == "sparse_scan"
        assert choose_mode(64, (16, 32, 64)) == "scan"
        assert choose_mode(256, (16, 64, 256)) == "sparse_scan"
        assert choose_mode(1024, (2,), global_events=True) == "scan"

    def test_auto_resolves_at_construction(self):
        tr = _trainer("ad_psgd", "auto", block_size=8, batch_pool=48)
        assert tr.mode == "scan"  # N=16 sits below every crossover
        tr.run(max_events=16, eval_every=8)  # and the resolved mode runs

    def test_auto_picks_sparse_at_scale(self):
        g = topology.erdos_renyi(128, 0.1, seed=1)
        sm = StragglerModel(n=128, straggler_prob=0.1, slowdown=10.0, seed=0)
        sched = make_scheduler("ad_psgd", g, sm)
        data = ClassificationData(n_workers=128, d=16, n_classes=4,
                                  samples_per_worker=4, seed=0)
        tr = DecentralizedTrainer(
            sched, loss_fn, init_fn,
            lambda w, s: data.batch(w, s, batch_size=4),
            data.eval_batch(32), mode="auto")
        assert tr.mode == "sparse_scan"


class TestFusedGating:
    def test_iid_horizon_flags(self):
        assert TimeSampler.iid_horizon is True
        for name in ("paper_default", "heavy_tail", "bimodal", "churn"):
            assert get_scenario(name, n=N).make_sampler().iid_horizon, name
        # diurnal factors depend on per-worker draw history: not exchangeable
        assert not get_scenario("diurnal", n=N).make_sampler().iid_horizon

    def test_fused_supported_follows_sampler(self):
        assert _sched("ad_psgd").fused_supported()
        assert _sched("agp").fused_supported()
        sched = _sched("ad_psgd",
                       straggler=get_scenario("diurnal", n=N, seed=0))
        assert not sched.fused_supported()

    def test_fused_rejects_clique_schedulers(self):
        with pytest.raises(ValueError, match="fused"):
            _trainer("dsgd_aau", "fused")

    def test_fused_rejects_history_dependent_sampler(self):
        with pytest.raises(ValueError, match="iid"):
            _trainer("ad_psgd", "fused",
                     sched_kw=dict(straggler=get_scenario("diurnal", n=N)))


class TestFusedStream:
    EVENTS = 96

    def _run(self, alg="ad_psgd", seed=0, warmup=False, **kw):
        tr = _trainer(alg, "fused", seed=seed, block_size=16, batch_pool=96,
                      **kw)
        if warmup:
            tr.warmup()
        res = tr.run(max_events=self.EVENTS, eval_every=24)
        return tr, res

    @pytest.mark.parametrize("alg", ["ad_psgd", "agp"])
    def test_deterministic_per_seed(self, alg):
        t1, r1 = self._run(alg)
        t2, r2 = self._run(alg)
        np.testing.assert_array_equal(np.asarray(t1.W["w"]),
                                      np.asarray(t2.W["w"]))
        np.testing.assert_array_equal(np.asarray(t1.y), np.asarray(t2.y))
        assert r1.total_time == r2.total_time
        assert r1.total_comm_copies == r2.total_comm_copies
        assert [p.loss for p in r1.history] == [p.loss for p in r2.history]

    def test_warmup_does_not_shift_the_stream(self):
        t1, r1 = self._run(warmup=False)
        t2, r2 = self._run(warmup=True)
        np.testing.assert_array_equal(np.asarray(t1.W["w"]),
                                      np.asarray(t2.W["w"]))
        assert r1.total_time == r2.total_time

    def test_exact_event_accounting(self):
        # erdos_renyi(16, 0.4, seed=3) is connected: every event is a pair
        # exchange, so comm and restart totals are exact, not statistical.
        sched = _sched("ad_psgd")
        assert all(len(nb) for nb in sched.graph.neighbor_lists)
        copies_pair = int(sched.fused_spec()["copies_pair"])
        tr, res = self._run()
        assert res.total_events == self.EVENTS
        assert res.total_comm_copies == self.EVENTS * copies_pair
        # one finisher restart per event
        assert int(np.asarray(tr._ptr).sum()) == self.EVENTS
        # pair events: finisher + neighbor active
        assert res.history[-1].n_active_mean == pytest.approx(2.0)

    def test_distributional_match_with_exact_stream(self):
        # The fused stream is a different realization of the same process:
        # virtual-clock rate and per-worker activation spread must agree
        # with the exact heap stream within sampling noise.
        tr, res = self._run()
        exact = _trainer("ad_psgd", "sparse_scan", block_size=16,
                         batch_pool=96)
        res_exact = exact.run(max_events=self.EVENTS, eval_every=24)
        assert res.total_time == pytest.approx(res_exact.total_time, rel=0.5)
        assert res.total_comm_copies == res_exact.total_comm_copies
        ptr = np.asarray(tr._ptr)
        # every worker keeps finishing work (96 events over 16 workers)
        assert (ptr > 0).all()
        assert ptr.max() <= 4 * self.EVENTS // N

    def test_fused_loss_decreases(self):
        _, res = self._run()
        assert res.final_loss < res.history[0].loss
