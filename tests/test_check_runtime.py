"""Runtime sanitizers: transfer guard, leak check, compile-count contract.

The N=64 regression pins **zero implicit device→host transfers per
compiled block** for the sparse_scan / bucketed / fused paths: the whole
driving loop runs under :func:`repro.check.runtime.sanitized`, whose
host-conversion guard raises on any ``float()``/``np.asarray()``/
``.item()`` applied to a jax value outside an explicit
``jax.device_get``.  The compile counter pins PR 6's one-compile-per-rung
contract: after warmup, a steady-state run adds zero jit-cache entries and
the sparse block holds exactly one program per bucket rung.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.check.runtime import (CompileCounter, host_conversion_guard,
                                 jit_cache_size, sanitize_enabled, sanitized)
from repro.core import topology
from repro.core.baselines import make_scheduler
from repro.core.runner import DecentralizedTrainer
from repro.core.straggler import StragglerModel
from repro.data.synthetic import ClassificationData

N = 64
DATA = ClassificationData(n_workers=N, d=16, n_classes=4,
                          samples_per_worker=32, seed=0)


def loss_fn(params, batch):
    logits = batch["x"] @ params["w"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], axis=1))


def init_fn(key):
    return {"w": jax.random.normal(key, (16, 4)) * 0.1}


def _trainer(alg, mode, sched_kw=None, **kw):
    g = topology.erdos_renyi(N, 0.15, seed=3)
    sm = StragglerModel(n=N, straggler_prob=0.2, slowdown=6.0, seed=0)
    return DecentralizedTrainer(
        make_scheduler(alg, g, sm, **(sched_kw or {})), loss_fn, init_fn,
        lambda w, s: DATA.batch(w, s, batch_size=8),
        DATA.eval_batch(64), eta0=0.2, seed=0, mode=mode, **kw)


class TestHostConversionGuard:
    def test_implicit_conversions_raise(self):
        x = jnp.ones(())
        # np.asarray via a lambda: the guard patches the numpy module
        # attribute, so the lookup must happen under the guard
        for convert in (float, int, bool, lambda v: np.asarray(v),
                        lambda v: v.item(), lambda v: v.tolist()):
            with sanitized(check_leaks=False):
                with pytest.raises(RuntimeError, match="implicit device"):
                    convert(x)

    def test_explicit_device_get_is_legal(self):
        with sanitized(check_leaks=False):
            v = jax.device_get(jnp.arange(4))
            assert isinstance(v, np.ndarray)
            # host data downstream of the fetch converts freely
            assert float(np.max(v)) == 3.0

    def test_guard_restores_on_exit(self):
        with sanitized(check_leaks=False):
            pass
        assert float(jnp.ones(())) == 1.0

    def test_audit_mode_records_instead_of_raising(self):
        with host_conversion_guard(raise_on_violation=False) as violations:
            float(jnp.ones(()))
            np.asarray(jnp.zeros((2, 3)))
            assert ("__float__", ()) in violations
            assert ("asarray", (2, 3)) in violations

    def test_env_flag(self):
        assert not sanitize_enabled("")
        assert not sanitize_enabled("0")
        assert sanitize_enabled("1")

    def test_leak_check_catches_tracer_escape(self):
        leaked = []

        @jax.jit
        def leaky(x):
            leaked.append(x)
            return x + 1

        with pytest.raises(Exception, match="[Ll]eak"):
            with sanitized(transfer_guard=None):
                leaky(jnp.ones(()))


class TestZeroImplicitTransfersN64:
    """The regression the ISSUE pins: sparse_scan / bucketed / fused at
    N=64 complete a full run with zero implicit device→host transfers.

    The runs wrap in the transfer guard alone (``check_leaks=False``):
    tracing the N=64 scan under ``jax.checking_leaks`` costs minutes, and
    leak coverage on a real run lives in the N=16 full-stack test below.
    """

    @pytest.mark.parametrize("alg,mode", [
        ("ad_psgd", "sparse_scan"),            # single-rung sparse
        ("dsgd_aau", "sparse_scan"),           # bucketed (16, 64)
        ("ad_psgd", "fused"),                  # generate-and-consume
    ], ids=["sparse_scan", "bucketed", "fused"])
    def test_run_has_zero_implicit_transfers(self, alg, mode):
        tr = _trainer(alg, mode, block_size=16)
        with sanitized(check_leaks=False):
            result = tr.run(max_events=96, eval_every=32)
        assert np.isfinite(result.final_loss)
        assert result.total_events == 96

    def test_guarded_run_matches_unguarded(self):
        r0 = _trainer("ad_psgd", "sparse_scan", block_size=16).run(
            max_events=64, eval_every=32)
        with sanitized(check_leaks=False):
            r1 = _trainer("ad_psgd", "sparse_scan", block_size=16).run(
                max_events=64, eval_every=32)
        assert r0.final_loss == r1.final_loss  # sanitizers observe, never alter


class TestTrainerSanitizeFlag:
    def test_env_flag_reaches_trainer(self, monkeypatch):
        monkeypatch.setenv("REPRO_SANITIZE", "1")
        assert _trainer("ad_psgd", "sparse_scan", block_size=16).sanitize
        monkeypatch.setenv("REPRO_SANITIZE", "0")
        assert not _trainer("ad_psgd", "sparse_scan", block_size=16).sanitize

    def test_full_stack_sanitized_run_small(self):
        """leak check + transfer guard around a real (N=16) run, via the
        trainer's own ``sanitize=True`` path"""
        n = 16
        data = ClassificationData(n_workers=n, d=16, n_classes=4,
                                  samples_per_worker=32, seed=0)
        tr = DecentralizedTrainer(
            make_scheduler("ad_psgd", topology.erdos_renyi(n, 0.4, seed=3),
                           StragglerModel(n=n, straggler_prob=0.2,
                                          slowdown=6.0, seed=0)),
            loss_fn, init_fn, lambda w, s: data.batch(w, s, batch_size=8),
            data.eval_batch(64), eta0=0.2, seed=0, mode="sparse_scan",
            block_size=16, sanitize=True)
        result = tr.run(max_events=64, eval_every=32)
        assert np.isfinite(result.final_loss)


class TestCompileCountPerRung:
    def test_one_compile_per_rung_bucketed(self):
        # batch_pool pinned: the auto-sized pool would grow mid-run for
        # max_events=96 and re-trace each rung (see warmup's docstring)
        tr = _trainer("dsgd_aau", "sparse_scan", block_size=16,
                      batch_pool=128)
        buckets = tr.scheduler.active_buckets()
        assert len(buckets) > 1, "N=64 AAU ladder should be multi-rung"
        tr.warmup()
        counter = CompileCounter()
        counter.track("sparse", tr._sparse)
        counter.assert_equals("sparse", len(buckets))
        tr.run(max_events=96, eval_every=32)
        # steady state: the run dispatches into the warmed per-rung
        # programs and compiles nothing new
        counter.assert_steady_state("sparse")
        counter.assert_equals("sparse", len(buckets))

    def test_counter_raises_on_contract_violation(self):
        tr = _trainer("ad_psgd", "sparse_scan", block_size=16)
        tr.warmup()
        counter = CompileCounter()
        counter.track("sparse", tr._sparse)
        with pytest.raises(AssertionError, match="compile-count"):
            counter.assert_equals("sparse", 99)

    def test_cache_size_readable(self):
        tr = _trainer("ad_psgd", "sparse_scan", block_size=16)
        tr.warmup()
        assert jit_cache_size(tr._sparse) == 1
        assert jit_cache_size(object()) is None
