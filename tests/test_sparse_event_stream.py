"""Sparse active-set event engine: SparseEventBatch packing, the
gather-compute-scatter scan (``mode="sparse_scan"``), and the
``sparse_gossip`` Pallas kernel.

The sparse path must be an *exact* re-execution of the dense compiled scan
(which is itself equivalence-tested against the per-event interpreter in
tests/test_event_stream.py): same scheduler seed ⇒ same ``(W, S, y)``
trajectory and the same recorded history, while touching only the workers
each event names.
"""
import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology
from repro.core.baselines import make_scheduler
from repro.core.consensus import metropolis_matrix
from repro.core.runner import DecentralizedTrainer
from repro.core.scheduler import EventBatch, SparseEventBatch
from repro.core.straggler import StragglerModel
from repro.data.synthetic import ClassificationData
from repro.kernels.sparse_gossip import (sparse_gossip_apply,
                                         sparse_gossip_apply_ref,
                                         sparse_gossip_ref,
                                         sparse_gossip_rows)

N = 16
DATA = ClassificationData(n_workers=N, d=16, n_classes=4,
                          samples_per_worker=64, seed=0)


def loss_fn(params, batch):
    logits = batch["x"] @ params["w"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], axis=1))


def init_fn(key):
    return {"w": jax.random.normal(key, (16, 4)) * 0.1}


def _sched(alg, seed=0, **kw):
    g = topology.erdos_renyi(N, 0.4, seed=3)
    sm = StragglerModel(n=N, straggler_prob=0.2, slowdown=6.0, seed=seed)
    return make_scheduler(alg, g, sm, **kw)


def _trainer(alg, mode, seed=0, **kw):
    return DecentralizedTrainer(
        _sched(alg, seed), loss_fn, init_fn,
        lambda w, s: DATA.batch(w, s, batch_size=8),
        DATA.eval_batch(64), eta0=0.2, eta_decay=0.99, seed=seed,
        mode=mode, **kw)


class TestSparseEventBatchPacking:
    @pytest.mark.parametrize("alg", ["dsgd_aau", "ad_psgd", "prague", "agp"])
    def test_round_trip_reconstructs_dense_events(self, alg):
        sched = _sched(alg)
        evs = list(itertools.islice(sched.events(), 12))
        batch = SparseEventBatch.from_events(
            evs, active_bound=sched.active_bound(),
            edge_bound=sched.edge_bound())
        assert batch.E == 12 and batch.A == sched.active_bound()
        for orig, back in zip(evs, batch.to_events(N)):
            assert back.k == orig.k
            assert back.time == pytest.approx(orig.time)
            np.testing.assert_array_equal(back.grad_workers, orig.grad_workers)
            np.testing.assert_array_equal(back.restart_workers,
                                          orig.restart_workers)
            np.testing.assert_allclose(back.P, orig.P)
            assert back.active_edges == orig.active_edges
            assert back.param_copies_sent == orig.param_copies_sent

    def test_single_edge_schedulers_carry_two_lanes(self):
        """AD-PSGD's sparse form is (E, 2) indices + (E, 2, 2) submatrices —
        the dense (E, n, n) stack is gone entirely."""
        sched = _sched("ad_psgd")
        batches = list(itertools.islice(sched.sparse_event_batches(5), 2))
        assert [b.E for b in batches] == [5, 5]
        assert batches[1].k0 == 5
        assert batches[0].workers.shape == (5, 2)
        assert batches[0].P_sub.shape == (5, 2, 2)
        assert batches[0].edges.shape == (5, 1, 2)

    def test_sorted_active_sets_and_zero_padding(self):
        sched = _sched("dsgd_aau")
        batch = next(sched.sparse_event_batches(8))
        for e in range(batch.E):
            m = int(batch.n_workers[e])
            lanes = batch.workers[e]
            assert (lanes[:m] >= 0).all() and (lanes[m:] == -1).all()
            assert list(lanes[:m]) == sorted(set(lanes[:m].tolist()))
            # padded lanes carry no mass in either direction and no masks
            assert np.all(batch.P_sub[e, m:, :] == 0.0)
            assert np.all(batch.P_sub[e, :, m:] == 0.0)
            assert not batch.grad_workers[e, m:].any()
            assert not batch.restart_workers[e, m:].any()

    def test_overflowing_active_bound_raises(self):
        sched = _sched("dsgd_aau")
        evs = list(itertools.islice(sched.events(), 10))
        widest = max(int(ev.grad_workers.sum()) for ev in evs)
        with pytest.raises(ValueError, match="active_bound"):
            SparseEventBatch.from_events(evs, active_bound=widest - 1)

    def test_pad_to_is_noop_events(self):
        sched = _sched("ad_psgd")
        evs = list(itertools.islice(sched.events(), 3))
        batch = SparseEventBatch.from_events(evs, active_bound=2).pad_to(8)
        assert batch.E == 8
        assert (batch.workers[3:] == -1).all()
        assert (batch.n_workers[3:] == 0).all()
        assert np.all(batch.P_sub[3:] == 0.0)
        assert not batch.grad_workers[3:].any()
        assert (batch.n_edges[3:] == 0).all()
        assert batch.param_copies_sent[3:].sum() == 0

    def test_padded_noop_block_leaves_state_bit_exact(self):
        tr = _trainer("ad_psgd", "sparse_scan")
        tr._ensure_sparse()
        W0 = jax.tree.map(lambda x: np.asarray(x).copy(), tr.W)
        ev = list(itertools.islice(_sched("ad_psgd").events(), 1))
        batch = SparseEventBatch.from_events(ev, active_bound=2, edge_bound=1)
        off = np.zeros_like(batch.grad_workers)
        noop = dataclasses.replace(
            batch, workers=np.full_like(batch.workers, -1),
            n_workers=np.zeros_like(batch.n_workers),
            P_sub=np.zeros_like(batch.P_sub),
            grad_workers=off, restart_workers=off)
        tr._dispatch_sparse_block(noop.pad_to(tr.block_size), rounds=0)
        for a, b in zip(jax.tree.leaves(W0), jax.tree.leaves(tr.W)):
            np.testing.assert_array_equal(a, np.asarray(b))
        np.testing.assert_array_equal(np.asarray(tr._ptr), np.zeros(N))


class TestSparseScanEquivalence:
    """Same scheduler seed ⇒ sparse_scan ≡ scan ≡ per_event (fp32):
    parameters, snapshots, push-sum weights, and recorded history."""

    @pytest.mark.parametrize("alg", ["dsgd_aau", "ad_psgd", "agp"])
    def test_matches_dense_scan_and_per_event(self, alg):
        per_event = _trainer(alg, "per_event")
        res_pe = per_event.run(max_events=40, eval_every=10)
        dense = _trainer(alg, "scan", block_size=7, batch_pool=48)
        res_dense = dense.run(max_events=40, eval_every=10)
        # block_size deliberately not dividing eval_every: exercises the
        # eval-boundary snapping + no-op padding on the sparse path too
        sparse = _trainer(alg, "sparse_scan", block_size=7, batch_pool=48)
        res_sparse = sparse.run(max_events=40, eval_every=10)

        for other, res_other, tol in ((dense, res_dense, 0.0),
                                      (per_event, res_pe, 1e-6)):
            for name, a, b in (("W", other.W, sparse.W),
                               ("S", other.S, sparse.S)):
                for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                    np.testing.assert_allclose(
                        np.asarray(la), np.asarray(lb), atol=tol,
                        err_msg=f"{name} vs {other.mode}")
            np.testing.assert_allclose(np.asarray(other.y),
                                       np.asarray(sparse.y), atol=tol)
            assert len(res_other.history) == len(res_sparse.history)
            for p_o, p_s in zip(res_other.history, res_sparse.history):
                assert p_s.k == p_o.k
                assert p_s.time == pytest.approx(p_o.time)
                assert p_s.loss == pytest.approx(p_o.loss, abs=1e-5)
                assert p_s.metric == pytest.approx(p_o.metric, abs=1e-5)
                assert p_s.comm_param_copies == p_o.comm_param_copies
                assert p_s.n_active_mean == pytest.approx(p_o.n_active_mean)
            assert res_sparse.total_events == res_other.total_events
            assert res_sparse.total_time == pytest.approx(
                res_other.total_time)

    def test_agp_pushsum_debias_survives_sparse_scan(self):
        sparse = _trainer("agp", "sparse_scan", block_size=8, batch_pool=48)
        sparse.run(max_events=30, eval_every=30)
        y = np.asarray(sparse.y)
        assert not np.allclose(y, 1.0)        # row-stochastic pushes moved mass
        assert y.sum() == pytest.approx(N, rel=1e-4)  # total mass conserved

    def test_kernel_path_matches_plain_sparse_scan(self):
        ref = _trainer("ad_psgd", "sparse_scan", block_size=4, batch_pool=24)
        res_ref = ref.run(max_events=12, eval_every=12)
        fused = _trainer("ad_psgd", "sparse_scan", block_size=4,
                         batch_pool=24, use_kernel=True)
        res_fused = fused.run(max_events=12, eval_every=12)
        for la, lb in zip(jax.tree.leaves(ref.W), jax.tree.leaves(fused.W)):
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       atol=2e-5)
        assert res_fused.final_loss == pytest.approx(res_ref.final_loss,
                                                     abs=1e-4)

    def test_sync_scheduler_falls_back_to_dense_scan(self):
        """Global-barrier streams gain nothing from gathering: sparse_scan
        silently degrades to the dense scan and still runs correctly."""
        dense = _trainer("dsgd_sync", "scan", block_size=4, batch_pool=24)
        res_dense = dense.run(max_events=12, eval_every=6)
        sparse = _trainer("dsgd_sync", "sparse_scan", block_size=4,
                          batch_pool=24)
        assert sparse.mode == "scan"  # automatic fallback
        res_sparse = sparse.run(max_events=12, eval_every=6)
        assert res_sparse.final_loss == pytest.approx(res_dense.final_loss)

    def test_max_time_bound(self):
        ref = _trainer("ad_psgd", "scan", block_size=4).run(
            max_time=20.0, eval_every=10)
        sparse = _trainer("ad_psgd", "sparse_scan", block_size=4).run(
            max_time=20.0, eval_every=10)
        assert sparse.total_events == ref.total_events
        assert sparse.final_loss == pytest.approx(ref.final_loss, abs=1e-6)

    def test_warmup_leaves_state_unchanged(self):
        tr = _trainer("dsgd_aau", "sparse_scan")
        W0 = jax.tree.map(lambda x: np.asarray(x).copy(), tr.W)
        tr.warmup()
        for a, b in zip(jax.tree.leaves(W0), jax.tree.leaves(tr.W)):
            np.testing.assert_array_equal(a, np.asarray(b))


class TestSparseGossipKernel:
    def _problem(self, n, d, A, seed=0, pad=0):
        key = jax.random.PRNGKey(seed)
        W = jax.random.normal(key, (n, d), jnp.float32)
        G = jax.random.normal(jax.random.fold_in(key, 1), (A, d), jnp.float32)
        rng = np.random.default_rng(seed)
        w = np.full(A, -1, np.int32)
        m = A - pad
        w[:m] = np.sort(rng.choice(n, size=m, replace=False))
        P = np.zeros((A, A), np.float32)
        P[:m, :m] = metropolis_matrix(
            m, [(i, (i + 1) % m) for i in range(max(m - 1, 1))]) if m > 1 \
            else 1.0
        mask = np.zeros(A, np.float32)
        mask[:m] = 0.1 * rng.random(m)
        return W, G, jnp.asarray(P), jnp.asarray(mask), jnp.asarray(w)

    @pytest.mark.parametrize("n,d,A,pad", [
        (16, 256, 2, 0),     # AD-PSGD/AGP shape
        (16, 256, 2, 1),     # isolated-worker event: one padded lane
        (64, 640, 8, 3),     # AAU-style subset with padding, D % 512 != 0
        (256, 512, 16, 5),   # paper-scale row count
    ])
    def test_rows_match_ref(self, n, d, A, pad):
        W, G, P, mask, w = self._problem(n, d, A, seed=n + A, pad=pad)
        Q = mask[:, None] * P
        out = sparse_gossip_rows(W, G, P, mask, w, block_d=256)
        ref = sparse_gossip_ref(W, G, P, Q, w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)
        # padded lanes produce exactly zero rows (the scatter drops them)
        if pad:
            assert np.all(np.asarray(out)[A - pad:] == 0.0)

    def test_apply_untouched_rows_bit_exact(self):
        """Scatter semantics: rows outside the active set are *identical*
        buffers-worth of data, and -1 lanes write nowhere."""
        W, G, P, mask, w = self._problem(32, 256, 4, seed=7, pad=2)
        out = np.asarray(sparse_gossip_apply(W, G, P, mask, w, block_d=256))
        ref = np.asarray(sparse_gossip_apply_ref(W, G, P, mask, w))
        np.testing.assert_allclose(out, ref, atol=2e-5)
        active = set(np.asarray(w)[np.asarray(w) >= 0].tolist())
        for i in range(32):
            if i not in active:
                np.testing.assert_array_equal(out[i], np.asarray(W)[i])

    def test_apply_matches_dense_masked_gossip(self):
        """The sparse kernel on the active set equals the dense fused kernel
        run with the full N×N matrix that is identity off the set."""
        from repro.kernels.gossip_mix import masked_gossip_ref
        n, d, A = 24, 384, 6
        W, Ga, P_sub, mask, w = self._problem(n, d, A, seed=3, pad=0)
        widx = np.asarray(w)
        P = np.eye(n, dtype=np.float32)
        P[np.ix_(widx, widx)] = np.asarray(P_sub)
        G = np.zeros((n, d), np.float32)
        G[widx] = np.asarray(Ga)
        scaled = np.zeros(n, np.float32)
        scaled[widx] = np.asarray(mask)
        dense = masked_gossip_ref(jnp.asarray(W), jnp.asarray(G),
                                  jnp.asarray(P), jnp.asarray(scaled))
        sparse = sparse_gossip_apply(W, Ga, P_sub, mask, w, block_d=384)
        np.testing.assert_allclose(np.asarray(sparse), np.asarray(dense),
                                   atol=2e-5)

    def test_all_padded_lanes_is_identity(self):
        W, G, P, mask, w = self._problem(16, 256, 4, seed=5, pad=0)
        w_all_pad = jnp.full_like(w, -1)
        out = sparse_gossip_apply(W, G, jnp.zeros_like(P),
                                  jnp.zeros_like(mask), w_all_pad,
                                  block_d=256)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(W))
