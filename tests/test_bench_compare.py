"""Bench artifact schema discipline + the --compare trend gate.

Covers the typed writer (``common.write_bench_json``: number-or-null
schema, legacy ``"unsupported"`` normalization, rejection of NaN and
non-JSON scalars), the tolerant metric reader (``common.as_metric``) and
the ``benchmarks.run --compare`` soft gate (warn >= 10%, fail >= 30% on
pinned throughput metrics, regression direction aware).
"""
import json

import numpy as np
import pytest

from benchmarks.common import as_metric, write_bench_json
from benchmarks.run import compare


class TestWriteBenchJson:
    def test_normalizes_legacy_unsupported(self, tmp_path):
        p = tmp_path / "b.json"
        write_bench_json(str(p), {
            "results": [{"n": 16, "alg": "x", "gen_horizon_eps":
                         "unsupported", "gen_eps": 10.0}]})
        row = json.loads(p.read_text())["results"][0]
        assert row["gen_horizon_eps"] is None
        assert row["gen_eps"] == 10.0

    def test_accepts_np_float64_rejects_np_float32(self, tmp_path):
        p = tmp_path / "b.json"
        write_bench_json(str(p), {"v": np.float64(1.5)})  # float subclass
        assert json.loads(p.read_text())["v"] == 1.5
        with pytest.raises(TypeError, match="float\\(\\)/int\\(\\)"):
            write_bench_json(str(p), {"v": np.float32(1.5)})
        with pytest.raises(TypeError):
            write_bench_json(str(p), {"v": np.int32(3)})

    def test_rejects_non_finite(self, tmp_path):
        p = tmp_path / "b.json"
        with pytest.raises(ValueError, match="non-finite"):
            write_bench_json(str(p), {"v": float("nan")})
        with pytest.raises(ValueError):
            write_bench_json(str(p), {"rows": [{"v": float("inf")}]})

    def test_nested_containers(self, tmp_path):
        p = tmp_path / "b.json"
        write_bench_json(str(p), {
            "results": [{"buckets": (4, 8), "occ": [{"A": 4, "fill": 0.5}],
                         "note": "unsupported"}]})
        row = json.loads(p.read_text())["results"][0]
        assert row["buckets"] == [4, 8]
        assert row["note"] is None  # normalized wherever it appears


class TestAsMetric:
    @pytest.mark.parametrize("v,expect", [
        (3, 3.0), (2.5, 2.5), ("2.5", 2.5),
        (None, None), ("unsupported", None), ("nan", None), ("inf", None),
        (True, None), ([1, 2], None), ({"a": 1}, None),
    ])
    def test_values(self, v, expect):
        assert as_metric(v) == expect


def _write(tmp_path, name, rows):
    p = tmp_path / name
    p.write_text(json.dumps({"bench": "event_stream", "results": rows}))
    return str(p)


_BASE = {"n": 16, "alg": "ad_psgd", "events": 1024,
         "gen_eps": 1000.0, "sparse_eps": 500.0,
         "telemetry_overhead": 1.05, "gen_horizon_eps": None}


class TestCompareGate:
    def test_identical_passes(self, tmp_path):
        p = _write(tmp_path, "a.json", [_BASE])
        assert compare(p, p) == 0

    def test_small_regression_warns_but_passes(self, tmp_path, capsys):
        old = _write(tmp_path, "old.json", [_BASE])
        new = _write(tmp_path, "new.json",
                     [{**_BASE, "sparse_eps": 500.0 * 0.85}])  # -15%
        assert compare(old, new) == 0
        assert "WARN" in capsys.readouterr().out

    def test_large_pinned_regression_fails(self, tmp_path, capsys):
        old = _write(tmp_path, "old.json", [_BASE])
        new = _write(tmp_path, "new.json",
                     [{**_BASE, "sparse_eps": 500.0 * 0.6}])  # -40%
        assert compare(old, new) == 1
        assert "FAIL" in capsys.readouterr().out

    def test_large_unpinned_regression_only_warns(self, tmp_path, capsys):
        old = _write(tmp_path, "old.json", [_BASE])
        # overhead ratios are not pinned: 1.05 -> 1.60 warns, never fails
        new = _write(tmp_path, "new.json",
                     [{**_BASE, "telemetry_overhead": 1.60}])
        assert compare(old, new) == 0
        assert "WARN" in capsys.readouterr().out

    def test_overhead_direction_is_lower_better(self, tmp_path, capsys):
        old = _write(tmp_path, "old.json", [_BASE])
        new = _write(tmp_path, "new.json",
                     [{**_BASE, "telemetry_overhead": 0.95}])
        assert compare(old, new) == 0
        assert "WARN" not in capsys.readouterr().out  # improvement

    def test_improvement_never_flags(self, tmp_path, capsys):
        old = _write(tmp_path, "old.json", [_BASE])
        new = _write(tmp_path, "new.json",
                     [{**_BASE, "sparse_eps": 5000.0}])
        assert compare(old, new) == 0
        out = capsys.readouterr().out
        assert "WARN" not in out and "FAIL" not in out

    def test_tolerates_legacy_and_missing(self, tmp_path):
        # legacy string sentinel on one side, null on the other, a metric
        # missing entirely, and a row present in only one file
        old = _write(tmp_path, "old.json", [
            {**_BASE, "gen_horizon_eps": "unsupported"},
            {"n": 64, "alg": "prague", "gen_eps": 1.0},
        ])
        new = _write(tmp_path, "new.json", [
            {k: v for k, v in _BASE.items() if k != "telemetry_overhead"},
            {"n": 128, "alg": "prague", "gen_eps": 1.0},
        ])
        assert compare(old, new) == 0

    def test_recorded_artifact_self_compare(self):
        assert compare("BENCH_event_stream.json",
                       "BENCH_event_stream.json") == 0
