"""Model-zoo correctness: primitives, chunked recurrences, attention paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import rwkv as RW
from repro.configs.base import ModelConfig


def _dense_cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab_size=97,
                param_dtype="float32", compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


class TestPrimitives:
    def test_rmsnorm_unit_scale(self):
        p = L.init_rmsnorm(8, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(0), (3, 8)) * 10
        y = L.rmsnorm(p, x)
        rms = np.sqrt(np.mean(np.asarray(y) ** 2, axis=-1))
        np.testing.assert_allclose(rms, 1.0, atol=1e-3)

    def test_rope_preserves_norm_and_relativity(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 2, 16))
        pos = jnp.arange(6)
        y = L.apply_rope(x, pos, 10000.0)
        np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                                   np.linalg.norm(np.asarray(x), axis=-1),
                                   rtol=1e-5)
        # relative property: <rope(q,m), rope(k,n)> depends only on m-n
        q = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))
        k = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 1, 16))
        def dot_at(m, n):
            qm = L.apply_rope(q, jnp.asarray([m]), 10000.0)
            kn = L.apply_rope(k, jnp.asarray([n]), 10000.0)
            return float(jnp.sum(qm * kn))
        assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), rel=1e-4)

    @pytest.mark.parametrize("T,window", [(96, None), (96, 17), (256, 50)])
    def test_blockwise_attention_matches_plain(self, T, window):
        B, H, KV, dh = 2, 4, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, T, H, dh))
        k = jax.random.normal(ks[1], (B, T, KV, dh))
        v = jax.random.normal(ks[2], (B, T, KV, dh))
        pos = jnp.arange(T)
        ref = L._plain_attention(q, k, v, pos, pos, window)
        out = L.blockwise_attention(q, k, v, window=window,
                                    block_q=32, block_k=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    def test_blockwise_attention_nondivisible_T(self):
        B, T, H, KV, dh = 1, 70, 2, 2, 8
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        q = jax.random.normal(ks[0], (B, T, H, dh))
        k = jax.random.normal(ks[1], (B, T, KV, dh))
        v = jax.random.normal(ks[2], (B, T, KV, dh))
        pos = jnp.arange(T)
        ref = L._plain_attention(q, k, v, pos, pos, None)
        out = L.blockwise_attention(q, k, v, block_q=32, block_k=32)
        assert out.shape == (B, T, H, dh)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    def test_blockwise_attention_grad_finite(self):
        B, T, H, KV, dh = 1, 64, 2, 1, 8
        ks = jax.random.split(jax.random.PRNGKey(5), 3)
        q = jax.random.normal(ks[0], (B, T, H, dh))
        k = jax.random.normal(ks[1], (B, T, KV, dh))
        v = jax.random.normal(ks[2], (B, T, KV, dh))
        g = jax.grad(lambda q: jnp.sum(L.blockwise_attention(
            q, k, v, block_q=16, block_k=16)))(q)
        assert np.all(np.isfinite(np.asarray(g)))

    def test_rolling_cache_decode(self):
        """Decode with a rolling window cache == windowed attention."""
        cfg = _dense_cfg(attn_window=8)
        p = L.init_attention(jax.random.PRNGKey(0), cfg)
        B, T = 1, 20
        x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model)) * 0.3
        full, _ = L.apply_attention(p, cfg, x, jnp.arange(T), window=8)
        cache = L.KVCache.empty(B, 8, cfg.n_kv_heads, cfg.d_head, jnp.float32)
        outs = []
        for t in range(T):
            o, cache = L.apply_attention(p, cfg, x[:, t:t + 1],
                                         jnp.asarray([t]), cache=cache,
                                         window=8)
            outs.append(o)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=1e-4)


class TestRWKV:
    def test_chunked_matches_stepwise(self):
        B, H, T, K = 2, 2, 48, 8
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        r, k = (jax.random.normal(ks[i], (B, H, T, K)) for i in range(2))
        v = jax.random.normal(ks[2], (B, H, T, K))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, H, T, K))) * 0.5 + 0.4
        u = jax.random.normal(ks[4], (H, K)) * 0.1
        S0 = jnp.zeros((B, H, K, K))
        S = S0
        ys = []
        for t in range(T):
            y, S = RW.rwkv_step(r[:, :, t], k[:, :, t], v[:, :, t],
                                w[:, :, t], u, S)
            ys.append(y)
        ref = jnp.stack(ys, axis=2)
        out, S_T = RW.chunked_rwkv(r, k, v, w, u, S0, chunk=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
        np.testing.assert_allclose(np.asarray(S_T), np.asarray(S), atol=1e-4)

    def test_state_carry_across_segments(self):
        """Prefix then continuation == full sequence (streaming invariance)."""
        B, H, T, K = 1, 2, 32, 4
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        r, k = (jax.random.normal(ks[i], (B, H, T, K)) for i in range(2))
        v = jax.random.normal(ks[2], (B, H, T, K))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, H, T, K))) * 0.4 + 0.5
        u = jnp.zeros((H, K))
        full, Sf = RW.chunked_rwkv(r, k, v, w, u, jnp.zeros((B, H, K, K)), chunk=8)
        h1, S1 = RW.chunked_rwkv(r[:, :, :16], k[:, :, :16], v[:, :, :16],
                                 w[:, :, :16], u, jnp.zeros((B, H, K, K)), chunk=8)
        h2, S2 = RW.chunked_rwkv(r[:, :, 16:], k[:, :, 16:], v[:, :, 16:],
                                 w[:, :, 16:], u, S1, chunk=8)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 2)),
                                   np.asarray(full), atol=1e-4)
        np.testing.assert_allclose(np.asarray(S2), np.asarray(Sf), atol=1e-4)


class TestRGLRU:
    def test_scan_matches_loop(self):
        B, T, W = 2, 24, 8
        a = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(0), (B, T, W)))
        x = jax.random.normal(jax.random.PRNGKey(1), (B, T, W))
        out = RG.rglru_scan(a, x)
        h = jnp.zeros((B, W))
        ref = []
        for t in range(T):
            h = a[:, t] * h + x[:, t]
            ref.append(h)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(jnp.stack(ref, 1)), atol=1e-5)

    def test_block_decode_matches_prefill(self):
        cfg = ModelConfig(name="g", family="hybrid", n_layers=3, d_model=32,
                          n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=50,
                          block_pattern=("rec", "rec", "attn"), rnn_width=32,
                          attn_window=16, param_dtype="float32",
                          compute_dtype="float32")
        p = RG.init_rglru_block(jax.random.PRNGKey(0), cfg)
        B, T = 1, 12
        x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model)) * 0.3
        full, _ = RG.apply_rglru_block(p, cfg, x)
        st = RG.RGLRUState.zeros(B, cfg, jnp.float32)
        outs = []
        for t in range(T):
            o, st = RG.apply_rglru_block(p, cfg, x[:, t:t + 1], st)
            outs.append(o)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                                   np.asarray(full), atol=1e-4)


class TestMoE:
    def _cfg(self, E=4, k=2, cf=8.0):
        return ModelConfig(name="m", family="moe", n_layers=1, d_model=16,
                           n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=50,
                           n_experts=E, top_k=k, moe_capacity_factor=cf,
                           param_dtype="float32", compute_dtype="float32")

    def test_output_shape_and_aux(self):
        cfg = self._cfg()
        p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
        out, aux = MOE.apply_moe(p, cfg, x)
        assert out.shape == x.shape
        assert float(aux) >= 1.0 - 1e-6  # E·Σ f·p ≥ 1 (uniform lower bound)

    def test_generous_capacity_equals_dense_gather(self):
        """With no drops, MoE output == explicit per-token expert mixture."""
        cfg = self._cfg(cf=100.0)
        p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 16))
        out, _ = MOE.apply_moe(p, cfg, x)
        # reference: route every token through all experts, weight by gates
        xt = x.reshape(-1, 16)
        logits = xt @ p["router"]
        gates, idx, _ = MOE._top_k_gating(logits, cfg.top_k)
        def expert(e, t):
            g = jax.nn.silu(t @ p["w_gate"][e])
            u = t @ p["w_up"][e]
            return (g * u) @ p["w_down"][e]
        ref = np.zeros_like(np.asarray(xt))
        for n in range(xt.shape[0]):
            for j in range(cfg.top_k):
                e = int(idx[n, j])
                ref[n] += float(gates[n, j]) * np.asarray(expert(e, xt[n]))
        np.testing.assert_allclose(np.asarray(out).reshape(-1, 16), ref,
                                   atol=1e-4)

    def test_capacity_drops_tokens(self):
        cfg = self._cfg(E=2, k=1, cf=0.01)   # capacity floor = 4
        p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 16))
        out, _ = MOE.apply_moe(p, cfg, x)
        # dropped tokens produce zero MoE output
        norms = np.linalg.norm(np.asarray(out)[0], axis=-1)
        assert (norms < 1e-6).sum() >= 64 - 2 * 4

    def test_grad_flows_to_router(self):
        cfg = self._cfg()
        p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 16))
        g = jax.grad(lambda p: MOE.apply_moe(p, cfg, x)[0].sum())(p)
        assert float(jnp.abs(g["router"]).sum()) > 0
