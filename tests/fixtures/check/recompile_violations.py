"""Seeded violations for the jit-in-loop / static-arg churn rules."""
import functools

import jax


def _fold(x, spec):
    return x


fold = jax.jit(_fold, static_argnums=(1,))


@functools.partial(jax.jit, static_argnums=(1,))
def fold_decorated(x, spec):
    return x


def jit_per_iteration(fns, x):
    outs = []
    for fn in fns:
        jf = jax.jit(fn)  # expect: jit-in-loop
        outs.append(jf(x))
    return outs


def jit_hoisted(fns, x):
    # built once, reused across calls — the sanctioned shape
    jitted = [jax.jit(fn) for fn in fns]
    return [jf(x) for jf in jitted]


def unhashable_static(x):
    return fold(x, [1, 2])  # expect: unhashable-static


def hashable_static(x):
    return fold(x, (1, 2))


def loop_varying_static(x, specs):
    acc = x
    for spec in specs:
        acc = fold_decorated(acc, spec)  # expect: loop-varying-static
    return acc


def suppressed(fns, x):
    for fn in fns:
        jf = jax.jit(fn)  # repro: disable=jit-in-loop
        x = jf(x)
    return x
