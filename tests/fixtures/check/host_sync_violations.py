"""Seeded violations for the host-sync rule (block-dispatch scopes only)."""
import jax
import jax.numpy as jnp
import numpy as np


def _dispatch_block(batch, ptr):
    v = jnp.max(batch)
    a = float(v)  # expect: host-sync
    b = int(jnp.sum(batch))  # expect: host-sync
    c = np.asarray(jnp.ones(3))  # expect: host-sync
    d = v.item()  # expect: host-sync
    e = bool(jnp.any(batch > 0))  # expect: host-sync
    ok = int(np.max(jax.device_get(ptr)))
    quiet = float(v)  # repro: disable=host-sync
    return a, b, c, d, e, ok, quiet


def _run_sparse_stream(chunks):
    total = 0
    for chunk in chunks:
        # host-side numpy accounting is not a device sync
        total += int(chunk.stream_copies().sum())
    return total


def not_a_dispatch_scope(batch):
    # same pattern outside the configured scopes: deliberate drain-time
    # syncs are allowed
    return float(jnp.max(batch))
