"""Seeded violations for the use-after-donate / missing-alias-break rules.

Never imported or executed — linted by tests/test_check.py against the
``# expect: <rule>`` markers.  Excluded from the repo-wide run by the
engine's default ``tests/fixtures/`` path exclude.
"""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, donate_argnums=(0, 1))
def step(W, S, y):
    return W + 1.0, S - 1.0, y


def read_after_donate(W, S, y):
    W2, S2, y2 = step(W, S, y)
    return W2 + W  # expect: use-after-donate


def read_on_error_path(W, S, y):
    W2, S2, y2 = step(W, S, y)
    if y2 < 0:
        raise ValueError(f"bad push-sum weight, W was {W}")  # expect: use-after-donate
    return W2


def self_clearing_rebind(W, S, y):
    W, S, y = step(W, S, y)
    return W + S


def suppressed_read(W, S, y):
    W2, S2, y2 = step(W, S, y)
    return W2 + W  # repro: disable=use-after-donate


def builds_without_alias_break(loss_fn):
    block = build_sparse_event_scan(loss_fn)  # expect: missing-alias-break
    return block


def builds_with_alias_break(loss_fn, S):
    block = build_sparse_event_scan(loss_fn)
    S = jax.tree.map(jnp.array, S)
    return block, S
