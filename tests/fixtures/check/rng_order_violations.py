"""Seeded violations for the rng-order / global-rng rules."""
import numpy as np


class UndeclaredScheduler:  # expect: rng-order
    def __init__(self, seed):
        self._rng = np.random.default_rng(seed)

    def events(self):
        return self._rng.random(4)


class DeclaredScheduler:
    rng_methods = ("_events_exact",)

    def __init__(self, seed):
        self._rng = np.random.default_rng(seed)
        # construction-time draws are pinned by the constructor seed
        self.base = self._rng.random(8)

    def _events_exact(self):
        return self._rng.random(4)

    def debug_sample(self):
        return self._rng.random()  # expect: rng-order

    def suppressed_sample(self):
        return self._rng.random()  # repro: disable=rng-order


def legacy_global_noise(k):
    return np.random.rand(k)  # expect: global-rng


def sanctioned_constructor(seed):
    return np.random.default_rng(seed)
