"""Seeded violations for the pallas-alias / kernel-gate rules."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.sparse_gossip import sparse_scatter_rows


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def alias_index_out_of_range(X):
    N, D = X.shape
    return pl.pallas_call(  # expect: pallas-alias
        _kernel,
        out_shape=jax.ShapeDtypeStruct((N, D), X.dtype),
        input_output_aliases={5: 0},
    )(X)


def alias_output_out_of_range(X):
    N, D = X.shape
    return pl.pallas_call(  # expect: pallas-alias
        _kernel,
        out_shape=jax.ShapeDtypeStruct((N, D), X.dtype),
        input_output_aliases={0: 3},
    )(X)


def alias_into_scalar_prefetch(workers, X):
    N, D = X.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(num_scalar_prefetch=1, grid=(1,))
    return pl.pallas_call(  # expect: pallas-alias
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, D), X.dtype),
        input_output_aliases={0: 0},
    )(workers, X)


def alias_dtype_mismatch(X):
    N, D = X.shape
    return pl.pallas_call(  # expect: pallas-alias
        _kernel,
        out_shape=jax.ShapeDtypeStruct((N, D), jnp.float32),
        input_output_aliases={0: 0},
    )(X)


def alias_shape_mismatch(X):
    return pl.pallas_call(  # expect: pallas-alias
        _kernel,
        out_shape=jax.ShapeDtypeStruct((8, 128), X.dtype),
        input_output_aliases={0: 0},
    )(X)


def alias_consistent(X):
    N, D = X.shape
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((N, D), X.dtype),
        input_output_aliases={0: 0},
    )(X)


def ungated_scatter(X, rows, w):
    return sparse_scatter_rows(X, rows, w)  # expect: kernel-gate


def gated_scatter(X, rows, w, use_kernel):
    if not use_kernel:
        out = X.at[w].set(rows)
    else:
        out = sparse_scatter_rows(X, rows, w)
    return out


def suppressed_scatter(X, rows, w):
    return sparse_scatter_rows(X, rows, w)  # repro: disable=kernel-gate
