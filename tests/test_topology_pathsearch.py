"""Graph constructors + Pathsearch (Algorithm 3) invariants."""
import numpy as np
import pytest

from conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()

from repro.core import topology
from repro.core.pathsearch import PathSearchState


class TestTopology:
    @pytest.mark.parametrize("maker,args", [
        (topology.ring, (8,)),
        (topology.fully_connected, (6,)),
        (topology.torus, (3, 4)),
        (topology.erdos_renyi, (16, 0.2)),
        (topology.multipod, (8, 2)),
    ])
    def test_connected_symmetric(self, maker, args):
        g = maker(*args)
        assert g.is_connected()
        assert np.array_equal(g.adj, g.adj.T)
        assert not np.any(np.diag(g.adj))

    def test_ring_degree(self):
        g = topology.ring(10)
        assert all(g.degree(i) == 2 for i in range(10))

    def test_torus_degree(self):
        g = topology.torus(4, 4)
        assert all(g.degree(i) == 4 for i in range(16))

    @given(n=st.integers(2, 40), p=st.floats(0.0, 1.0), seed=st.integers(0, 100))
    @settings(max_examples=40, deadline=None)
    def test_erdos_renyi_always_connected(self, n, p, seed):
        assert topology.erdos_renyi(n, p, seed=seed).is_connected()

    def test_multipod_cross_edges_sparse(self):
        g = topology.multipod(16, 2, inter_pod_edges=2)
        cross = sum(1 for i, j in g.edges if (i < 16) != (j < 16))
        assert 1 <= cross <= 4  # sparse DCI bridges only


class TestPathsearch:
    @given(n=st.integers(2, 20), seed=st.integers(0, 300))
    @settings(max_examples=50, deadline=None)
    def test_epoch_completes_within_n_minus_1_commits(self, n, seed):
        """The paper's bound B ≤ N−1: an epoch needs at most N−1 committed
        edges (spanning-tree growth), regardless of finish order."""
        g = topology.erdos_renyi(n, 0.4, seed=seed)
        ps = PathSearchState(g)
        rng = np.random.default_rng(seed)
        commits = 0
        guard = 0
        while not ps.epoch_complete():
            guard += 1
            # draws are random subsets; progress per draw is probabilistic —
            # only a genuine deadlock would exhaust this bound
            assert guard < 500 * n, "pathsearch failed to make progress"
            finished = set(rng.choice(n, size=rng.integers(2, n + 1),
                                      replace=False).tolist())
            novel = ps.novel_edges(finished)
            if novel:
                # commit() dedups candidates that became redundant as earlier
                # candidates merged their components
                ps.commit(novel)
        assert len(ps.committed) <= n - 1
        assert ps.vertices == set(range(n))

    def test_commit_only_between_components(self):
        g = topology.fully_connected(4)
        ps = PathSearchState(g)
        ps.commit([(0, 1)])
        # (0,1) already same component -> not novel
        assert (0, 1) not in ps.novel_edges({0, 1})
        assert ps.num_components == 3
        ps.commit([(2, 3)])
        assert ps.num_components == 2
        # merging edge between the two components IS novel (impl. note in
        # pathsearch.py: deviation from the paper's literal condition)
        novel = ps.novel_edges({0, 2})
        assert (0, 2) in novel
        ps.commit(novel)
        assert ps.epoch_complete()

    def test_reset_epoch(self):
        g = topology.ring(3)
        ps = PathSearchState(g)
        ps.commit([(0, 1), (1, 2)])
        assert ps.epoch_complete()
        ps.reset_epoch()
        assert ps.committed == set() and ps.vertices == set()
        assert ps.epochs_completed == 1
        assert not ps.epoch_complete()

    def test_respects_graph_edges(self):
        g = topology.ring(4)  # edges only (0,1),(1,2),(2,3),(3,0)
        ps = PathSearchState(g)
        novel = ps.novel_edges({0, 2})
        assert novel == []  # 0-2 not a graph edge
