"""End-to-end behaviour tests of the full system.

Covers: decentralized training of an *assigned-architecture* reduced model
through the paper's algorithm, the serving stack, and the checkpoint/resume
loop — i.e. the paths a user of the framework actually runs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.core import topology
from repro.core.baselines import make_scheduler
from repro.core.runner import DecentralizedTrainer
from repro.core.straggler import StragglerModel
from repro.data import CharLMData
from repro.models import init_model, lm_loss


def _trainer(alg="dsgd_aau", n=8, seed=0):
    cfg = get_config("paper-char-lm").reduced()
    data = CharLMData(n_workers=n, vocab=cfg.vocab_size, seq_len=32, seed=0)
    g = topology.erdos_renyi(n, 0.4, seed=1)
    sm = StragglerModel(n=n, straggler_prob=0.2, slowdown=6.0, seed=seed)
    sched = make_scheduler(alg, g, sm)
    return DecentralizedTrainer(
        sched,
        lambda p, b: lm_loss(p, cfg, b),
        lambda k: init_model(k, cfg),
        lambda w, s: data.batch(w, s, batch_size=8),
        data.eval_batch(16),
        eta0=0.5, eta_decay=0.99, seed=seed,
    )


class TestDecentralizedLMTraining:
    """Train the paper's char-LM stand-in decentralized with DSGD-AAU."""

    def test_lm_loss_decreases(self):
        res = _trainer().run(max_events=60, eval_every=30)
        first = res.history[0].loss
        assert res.final_loss < first
        assert np.isfinite(res.final_loss)

    def test_all_algorithms_run_the_same_model(self):
        for alg in ("dsgd_aau", "dsgd_sync", "ad_psgd", "prague", "agp"):
            res = _trainer(alg).run(max_events=12, eval_every=12)
            assert np.isfinite(res.final_loss), alg


class TestServing:
    def test_batched_server_end_to_end(self):
        from repro.launch.serve import BatchedServer, Request
        cfg = get_config("qwen3-8b").reduced()
        params = init_model(jax.random.PRNGKey(0), cfg)
        server = BatchedServer(cfg, params, batch_slots=2, cache_len=64)
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=5).astype(np.int32), max_new=4)
            for i in range(3)]
        server.run(reqs)
        assert all(r.done and len(r.out) == 4 for r in reqs)
        assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.out)

    def test_greedy_decode_deterministic(self):
        from repro.launch.serve import BatchedServer, Request
        cfg = get_config("rwkv6-1.6b").reduced()
        params = init_model(jax.random.PRNGKey(0), cfg)
        server = BatchedServer(cfg, params, batch_slots=1, cache_len=32)
        p = np.asarray([1, 2, 3], np.int32)
        r1 = Request(rid=0, prompt=p, max_new=6)
        r2 = Request(rid=1, prompt=p, max_new=6)
        server.run([r1])
        server.run([r2])
        assert r1.out == r2.out


class TestCheckpointResume:
    def test_trainer_state_roundtrip(self, tmp_path):
        tr = _trainer()
        tr.run(max_events=10, eval_every=10)
        ck = Checkpointer(str(tmp_path))
        ck.save(10, jax.device_get(tr.W))
        restored, _ = ck.restore(tr.W)
        for a, b in zip(jax.tree.leaves(tr.W), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestCLIDrivers:
    def test_train_cli_demo(self, capsys):
        from repro.launch.train import main
        rc = main(["--arch", "minicpm-2b", "--demo", "--steps", "2",
                   "--seq", "32", "--global-batch", "2", "--workers", "1"])
        assert rc == 0
        assert "step" in capsys.readouterr().out

    def test_serve_cli_demo(self, capsys):
        from repro.launch.serve import main
        rc = main(["--arch", "minicpm-2b", "--demo", "--requests", "2",
                   "--slots", "2", "--max-new", "3"])
        assert rc == 0
        assert "served 2 requests" in capsys.readouterr().out
