"""Device-resident telemetry (repro/obs): cross-mode bit-exactness,
the DSGD-AAU staleness-bound monitor, zero trajectory drift, comm-byte
accounting, and the structured run logger.

The contract under test (see repro/obs/metrics.py):

- the drained ``MetricsCarry`` is **bit-identical** across ``per_event``,
  ``scan`` and ``sparse_scan`` (incl. bucketed dispatch) of the same
  scheduler stream — every accumulator uses order-exact operations only;
- the ``fused`` mode is a different-but-deterministic realization: its
  counters are internally consistent and deterministic, not event-matched;
- telemetry is a pure observer: trajectories are bit-identical with it on
  or off;
- ``stale_max`` obeys the 2N−4 event-staleness bound induced by
  Pathsearch's per-epoch commit bound B ≤ N−1 (the issue's "≤ N−1" is the
  per-epoch *edge* bound, which does not bound event staleness directly —
  the histogram empirically reaches beyond N−1 and up to exactly 2N−4).
"""
import io
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology
from repro.core.baselines import make_scheduler
from repro.core.runner import DecentralizedTrainer
from repro.core.straggler import StragglerModel
from repro.data.synthetic import ClassificationData
from repro.obs import RunLogger, init_metrics
from repro.obs.metrics import (STALE_HIST_BINS, block_metrics_update,
                               fused_metrics_fold, sparse_metrics_update)

N = 16
DATA = ClassificationData(n_workers=N, d=16, n_classes=4,
                          samples_per_worker=64, seed=0)


def loss_fn(params, batch):
    logits = batch["x"] @ params["w"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], axis=1))


def init_fn(key):
    return {"w": jax.random.normal(key, (16, 4)) * 0.1}


def _sched(alg, seed=0, slowdown=6.0, **kw):
    g = topology.erdos_renyi(N, 0.4, seed=3)
    sm = StragglerModel(n=N, straggler_prob=0.2, slowdown=slowdown,
                        seed=seed)
    return make_scheduler(alg, g, sm, **kw)


def _trainer(alg, mode, seed=0, sched_kw=None, **kw):
    kw.setdefault("telemetry", True)
    return DecentralizedTrainer(
        _sched(alg, seed, **(sched_kw or {})), loss_fn, init_fn,
        lambda w, s: DATA.batch(w, s, batch_size=8),
        DATA.eval_batch(64), eta0=0.2, eta_decay=0.99, seed=seed,
        mode=mode, **kw)


def _bits(M):
    """MetricsCarry → dict of integer views (f32 compared bitwise)."""
    host = jax.device_get(M)
    out = {}
    for f in host._fields:
        a = np.asarray(getattr(host, f))
        out[f] = a.view(np.uint32) if a.dtype == np.float32 else a
    return out


def _assert_carry_equal(Ma, Mb, ctx=""):
    a, b = _bits(Ma), _bits(Mb)
    for f in a:
        np.testing.assert_array_equal(
            a[f], b[f], err_msg=f"{ctx}: MetricsCarry.{f} differs")


class TestCrossModeBitExact:
    """per_event / scan / sparse_scan drain bit-identical counters."""

    EVENTS = 60

    @pytest.mark.parametrize("alg,sched_kw", [
        ("dsgd_aau", {"buckets": (4, 8, 16)}),   # forces bucketed dispatch
        ("ad_psgd", {}),
    ])
    def test_modes_bit_identical(self, alg, sched_kw):
        carries, summaries = {}, {}
        for mode in ("per_event", "scan", "sparse_scan"):
            tr = _trainer(alg, mode, sched_kw=sched_kw)
            res = tr.run(max_events=self.EVENTS, eval_every=20)
            carries[mode] = tr._metrics
            summaries[mode] = res.telemetry
        _assert_carry_equal(carries["per_event"], carries["scan"],
                            f"{alg} per_event vs scan")
        _assert_carry_equal(carries["per_event"], carries["sparse_scan"],
                            f"{alg} per_event vs sparse_scan")
        # the drained summaries (minus the sparse-only occupancy report)
        # must agree too — they are pure functions of the carry
        for mode in ("scan", "sparse_scan"):
            s = dict(summaries[mode])
            ref = dict(summaries["per_event"])
            s.pop("bucket_occupancy", None)
            ref.pop("bucket_occupancy", None)
            assert s == ref, f"{alg}: summary drift in {mode}"

    def test_sync_scan_matches_per_event(self):
        carries = {}
        for mode in ("per_event", "scan"):
            tr = _trainer("dsgd_sync", mode)
            tr.run(max_events=48, eval_every=16)
            carries[mode] = tr._metrics
        _assert_carry_equal(carries["per_event"], carries["scan"],
                            "dsgd_sync per_event vs scan")

    def test_counters_are_consistent(self):
        tr = _trainer("dsgd_aau", "sparse_scan")
        res = tr.run(max_events=self.EVENTS, eval_every=20)
        t = res.telemetry
        assert sum(t["stale_hist"]) == sum(t["grad_steps"])
        assert t["comm_copies"] == res.total_comm_copies
        assert len(t["grad_steps"]) == N
        assert len(t["stale_hist"]) == STALE_HIST_BINS
        assert all(0.0 <= u <= 1.0 for u in t["utilization"])
        # occupancy covers every event exactly once
        occ = t["bucket_occupancy"]
        assert sum(o["events"] for o in occ) == self.EVENTS


class TestTrajectoryUnchanged:
    """Telemetry is a pure observer: bit-identical state with it on/off."""

    @pytest.mark.parametrize("alg,mode", [
        ("dsgd_aau", "scan"),
        ("dsgd_aau", "sparse_scan"),
        ("dsgd_aau", "per_event"),
        ("ad_psgd", "fused"),
    ])
    def test_state_and_history_identical(self, alg, mode):
        results = {}
        for tel in (False, True):
            tr = _trainer(alg, mode, telemetry=tel)
            res = tr.run(max_events=48, eval_every=16)
            results[tel] = (res, np.asarray(tr.y))
        r0, y0 = results[False]
        r1, y1 = results[True]
        np.testing.assert_array_equal(
            y0.view(np.uint32), y1.view(np.uint32),
            err_msg=f"{alg}/{mode}: consensus state drifts with telemetry")
        assert [(h.k, h.time, h.loss) for h in r0.history] \
            == [(h.k, h.time, h.loss) for h in r1.history]
        assert r0.total_comm_copies == r1.total_comm_copies
        assert r1.telemetry is not None and r0.telemetry is None


class TestStalenessBound:
    """DSGD-AAU's runtime monitor: stale_max ≤ 2N−4, and the bound is the
    *event*-staleness consequence of the per-epoch commit bound B ≤ N−1."""

    @pytest.mark.parametrize("seed,slowdown", [
        (0, 6.0), (1, 6.0), (2, 25.0), (3, 100.0),
    ])
    def test_bound_holds(self, seed, slowdown):
        tr = _trainer("dsgd_aau", "sparse_scan", seed=seed,
                      sched_kw={"slowdown": slowdown})
        res = tr.run(max_events=200, eval_every=100)
        b = res.telemetry["staleness_bound"]
        assert b["bound"] == 2 * N - 4
        assert b["edges_per_epoch_bound"] == N - 1
        assert b["observed_max"] == res.telemetry["stale_max"]
        assert b["ok"], (
            f"stale_max {b['observed_max']} exceeds 2N-4={b['bound']} "
            f"(seed={seed}, slowdown={slowdown})")

    def test_bound_is_reachable_beyond_n_minus_1(self):
        """Heavy straggling drives staleness past N−1 (so N−1 is NOT an
        event-staleness bound) while still respecting 2N−4."""
        worst = 0
        for seed in range(6):
            tr = _trainer("dsgd_aau", "sparse_scan", seed=seed,
                          sched_kw={"slowdown": 200.0})
            res = tr.run(max_events=300, eval_every=300)
            worst = max(worst, res.telemetry["stale_max"])
            assert res.telemetry["staleness_bound"]["ok"]
        assert worst > N - 1, (
            f"expected some stream to exceed N-1={N - 1} event staleness; "
            f"worst observed {worst}")

    def test_matches_host_replay(self):
        """The device staleness histogram equals a host replay of the
        event stream's restart bookkeeping."""
        import itertools
        sched = _sched("dsgd_aau")
        evs = list(itertools.islice(sched.events(), 120))
        last = np.full(N, -1, dtype=np.int64)
        hist = np.zeros(STALE_HIST_BINS, dtype=np.int64)
        smax, ssum = 0, 0
        for k, ev in enumerate(evs):
            for w in np.flatnonzero(ev.grad_workers):
                s = int(k - last[w] - 1)
                smax = max(smax, s)
                ssum += s
                hist[min(int(np.log2(s + 1)), STALE_HIST_BINS - 1)] += 1
            for w in np.flatnonzero(ev.restart_workers):
                last[w] = k
        tr = _trainer("dsgd_aau", "sparse_scan")
        res = tr.run(max_events=120, eval_every=120)
        t = res.telemetry
        assert t["stale_max"] == smax
        assert t["stale_hist"] == hist.tolist()
        assert sum(t["stale_hist"]) * t["stale_mean"] == pytest.approx(ssum)

    def test_non_aau_has_no_bound(self):
        tr = _trainer("ad_psgd", "sparse_scan")
        res = tr.run(max_events=40, eval_every=40)
        assert "staleness_bound" not in res.telemetry


class TestFusedTelemetry:
    """Fused mode: deterministic, internally consistent, block-fold
    equals the sequential per-event fold on identical payloads."""

    def test_deterministic_and_consistent(self):
        summ = []
        for _ in range(2):
            tr = _trainer("ad_psgd", "fused")
            res = tr.run(max_events=96, eval_every=48)
            t = res.telemetry
            assert sum(t["grad_steps"]) == res.total_events
            assert t["comm_copies"] == res.total_comm_copies
            assert sum(t["stale_hist"]) == sum(t["grad_steps"])
            summ.append(t)
        assert summ[0] == summ[1], "fused telemetry not deterministic"

    def test_block_fold_matches_sequential_fold(self):
        """block_metrics_update ≡ event-by-event sparse_metrics_update on
        the same payload stream (integers exact, f32 to float tolerance),
        including the carry handoff between consecutive blocks."""
        rng = np.random.default_rng(7)
        n, A, E, k0 = 9, 2, 120, 13
        workers = np.full((E, A), -1, np.int32)
        gm = np.zeros((E, A), bool)
        cpl = np.zeros((E, A), bool)
        for e in range(E):
            if rng.random() < 0.8:
                i, j = rng.choice(n, 2, replace=False)
                workers[e] = [min(i, j), max(i, j)]
                gm[e, rng.integers(2)] = True
                cpl[e] = True
            else:
                workers[e, 0] = rng.integers(n)
                gm[e, 0] = True
        ts = np.cumsum(rng.random(E).astype(np.float32) * 0.1,
                       dtype=np.float32)
        fin = (ts[:, None]
               - rng.random((E, A)).astype(np.float32) * 0.05)
        ks = (k0 + np.arange(E)).astype(np.int32)
        copies = rng.integers(0, 3, E).astype(np.int32)

        M_seq = init_metrics(n)
        for e in range(E):
            P = (np.full((A, A), 0.5, np.float32) if cpl[e].all()
                 else np.eye(A, dtype=np.float32))
            M_seq = sparse_metrics_update(
                M_seq, jnp.asarray(workers[e]), jnp.asarray(P),
                jnp.asarray(gm[e]), jnp.asarray(gm[e]),
                jnp.full((A,), ts[e]), jnp.asarray(fin[e]),
                jnp.full((A,), ks[e], jnp.int32), jnp.int32(copies[e]))

        h = E // 2
        M_blk = init_metrics(n)
        for sl in (slice(None, h), slice(h, None)):
            M_blk = block_metrics_update(
                M_blk, jnp.asarray(workers[sl]), jnp.asarray(gm[sl]),
                jnp.asarray(gm[sl]), jnp.asarray(cpl[sl]),
                jnp.asarray(ts[sl]), jnp.asarray(fin[sl]),
                jnp.asarray(ks[sl]), jnp.asarray(copies[sl]))

        a, b = jax.device_get(M_seq), jax.device_get(M_blk)
        for f in a._fields:
            av, bv = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
            if av.dtype == np.float32 and f in ("busy_t", "idle_t"):
                np.testing.assert_allclose(av, bv, rtol=1e-6, atol=1e-6,
                                           err_msg=f)
            else:
                np.testing.assert_array_equal(av, bv, err_msg=f)

    def test_fused_fold_matches_generic_block_fold(self):
        """fused_metrics_fold (the O(E) drain-time specialization) ≡
        block_metrics_update on the rebuilt 2-lane fused payloads."""
        rng = np.random.default_rng(11)
        n, E, k0, copies_pair = 7, 200, 0, 2
        i_seq = rng.integers(0, n, E).astype(np.int32)
        p_seq = np.where(rng.random(E) < 0.85,
                         (i_seq + rng.integers(1, n, E)) % n,
                         -1).astype(np.int32)
        t_ev = np.cumsum(rng.random(E).astype(np.float32) * 0.1,
                         dtype=np.float32)
        t_raw = t_ev - rng.random(E).astype(np.float32) * 0.02
        ks = (k0 + np.arange(E)).astype(np.int32)

        # the rebuild the per-block path used: sorted pair, finisher lane
        has = p_seq >= 0
        workers = np.stack([np.where(has, np.minimum(i_seq, p_seq), i_seq),
                            np.where(has, np.maximum(i_seq, p_seq), -1)],
                           axis=1).astype(np.int32)
        lanes = workers == i_seq[:, None]
        coupled = has[:, None] & (workers >= 0)
        fin = np.where(lanes, t_raw[:, None], t_ev[:, None])
        copies = np.where(has, copies_pair, 0).astype(np.int32)
        M_blk = block_metrics_update(
            init_metrics(n), jnp.asarray(workers), jnp.asarray(lanes),
            jnp.asarray(lanes), jnp.asarray(coupled), jnp.asarray(t_ev),
            jnp.asarray(fin), jnp.asarray(ks), jnp.asarray(copies))

        # the specialized fold, split across two drains' worth of carry
        h = E // 3
        M_fus = init_metrics(n)
        for sl in (slice(None, h), slice(h, None)):
            M_fus = fused_metrics_fold(
                M_fus, jnp.asarray(i_seq[sl]), jnp.asarray(p_seq[sl]),
                jnp.asarray(t_raw[sl]), jnp.asarray(t_ev[sl]),
                copies_pair, jnp.int32(ks[sl][0]))

        a, b = jax.device_get(M_blk), jax.device_get(M_fus)
        for f in a._fields:
            av, bv = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
            if av.dtype == np.float32 and f in ("busy_t", "idle_t"):
                np.testing.assert_allclose(av, bv, rtol=1e-6, atol=1e-6,
                                           err_msg=f)
            else:
                np.testing.assert_array_equal(av, bv, err_msg=f)


class TestCommBytes:
    """RunResult.comm_bytes prices copies via the trainer's dtype policy."""

    def test_bf16_reports_bf16_bytes(self):
        tr = _trainer("dsgd_aau", "sparse_scan", telemetry=False,
                      dtype=jnp.bfloat16)
        res = tr.run(max_events=24, eval_every=24)
        assert res.bytes_per_scalar == 2
        assert res.comm_bytes() == \
            res.total_comm_copies * res.param_count * 2
        # explicit override still wins (the old fp32 pricing, on request)
        assert res.comm_bytes(4) == 2 * res.comm_bytes()

    def test_fp32_default(self):
        tr = _trainer("dsgd_aau", "scan", telemetry=False)
        res = tr.run(max_events=24, eval_every=24)
        assert res.bytes_per_scalar == 4
        assert res.comm_bytes() == \
            res.total_comm_copies * res.param_count * 4


class TestRunLogger:
    def test_jsonl_schema_and_warn_once(self):
        buf = io.StringIO()
        log = RunLogger(buf)
        log.log("run_start", n=4)
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            log.warn_once("pool_wrap", "pool wrapped")
            log.warn_once("pool_wrap", "pool wrapped")   # deduped
            log.warn_once("rng_order", "notice", warn=False)  # log-only
        lines = [json.loads(s) for s in buf.getvalue().splitlines()]
        assert [l["event"] for l in lines] \
            == ["run_start", "pool_wrap", "rng_order"]
        assert len(rec) == 1 and "pool wrapped" in str(rec[0].message)

    def test_disabled_logger_is_noop(self):
        log = RunLogger(None)
        assert not log.enabled
        log.log("anything", x=1)   # must not raise

    def test_trainer_emits_run_events(self):
        buf = io.StringIO()
        tr = _trainer("dsgd_aau", "sparse_scan", run_log=buf)
        tr.run(max_events=40, eval_every=20)
        events = [json.loads(s)["event"] for s in buf.getvalue().splitlines()]
        assert events[0] == "run_start"
        assert events[-1] == "run_end"
        assert "block_dispatch" in events
        assert "compile" in events

    def test_pool_wrap_routes_through_logger(self):
        """The batch-pool wrap notice lands in the JSONL log AND still
        warns on stderr (the pre-logger contract)."""
        buf = io.StringIO()
        tr = _trainer("dsgd_aau", "sparse_scan", telemetry=False,
                      run_log=buf, batch_pool=2)
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            tr.run(max_events=120, eval_every=60)
        wraps = [json.loads(s) for s in buf.getvalue().splitlines()
                 if json.loads(s)["event"] == "pool_wrap"]
        assert len(wraps) == 1, "pool_wrap must be logged exactly once"
        assert any("batch pool" in str(w.message) for w in rec)
