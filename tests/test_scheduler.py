"""Event-stream invariants for DSGD-AAU and the baseline schedulers."""
import itertools

import numpy as np
import pytest

from conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()

from repro.core import topology
from repro.core.baselines import make_scheduler
from repro.core.consensus import is_doubly_stochastic
from repro.core.straggler import StragglerModel


def take(sched, k):
    return list(itertools.islice(sched.events(), k))


def _mk(alg, n=12, seed=0, **kw):
    g = topology.erdos_renyi(n, 0.35, seed=seed)
    sm = StragglerModel(n=n, straggler_prob=0.2, slowdown=6.0, seed=seed)
    return make_scheduler(alg, g, sm, **kw), g


ALL_ALGS = ["dsgd_aau", "dsgd_sync", "ad_psgd", "prague", "agp"]


class TestEventStreams:
    @pytest.mark.parametrize("alg", ALL_ALGS)
    def test_monotone_time_and_counter(self, alg):
        sched, _ = _mk(alg)
        evs = take(sched, 50)
        ks = [e.k for e in evs]
        assert ks == list(range(50))
        ts = [e.time for e in evs]
        assert all(t2 >= t1 for t1, t2 in zip(ts, ts[1:]))

    @pytest.mark.parametrize("alg", ALL_ALGS)
    def test_mass_conserving(self, alg):
        """In the W·P orientation, Σ_j out_j = Σ_i W_i ⇔ rows sum to 1."""
        sched, _ = _mk(alg)
        for ev in take(sched, 40):
            assert np.allclose(ev.P.sum(axis=1), 1.0), alg
            assert np.all(ev.P >= -1e-12)

    @pytest.mark.parametrize("alg", ["dsgd_aau", "dsgd_sync", "ad_psgd", "prague"])
    def test_doubly_stochastic_for_undirected_algs(self, alg):
        sched, _ = _mk(alg)
        for ev in take(sched, 40):
            assert is_doubly_stochastic(ev.P), alg

    @pytest.mark.parametrize("alg", ALL_ALGS)
    def test_active_edges_subset_of_graph(self, alg):
        sched, g = _mk(alg)
        for ev in take(sched, 40):
            if alg == "prague":
                continue  # Prague groups are logical, not topology-bound
            for i, j in ev.active_edges:
                assert g.adj[i, j], (alg, i, j)

    @pytest.mark.parametrize("alg", ALL_ALGS)
    def test_inactive_workers_untouched(self, alg):
        """Alg.1 line 7: rows/cols of inactive AND non-neighbor workers are e_i."""
        sched, _ = _mk(alg)
        for ev in take(sched, 30):
            touched = set(np.nonzero(ev.grad_workers)[0].tolist())
            for i, j in ev.active_edges:
                touched |= {i, j}
            for w in range(sched.n):
                if w not in touched:
                    assert ev.P[w, w] == pytest.approx(1.0)
                    assert ev.P[w].sum() == pytest.approx(1.0)


class TestAAUSemantics:
    def test_sync_waits_for_slowest(self):
        """Synchronous iterations take ≥ the straggler slowdown sometimes."""
        sched, _ = _mk("dsgd_sync", n=16)
        evs = take(sched, 30)
        dts = np.diff([0.0] + [e.time for e in evs])
        assert dts.max() > 4.0  # barrier hits a 6× straggler

    def test_aau_faster_than_sync_in_virtual_time(self):
        a, _ = _mk("dsgd_aau", n=16)
        s, _ = _mk("dsgd_sync", n=16)
        ta = take(a, 60)[-1].time
        ts = take(s, 60)[-1].time
        assert ta < ts

    def test_aau_active_sets_adaptive(self):
        """a(k) — the active-set size — varies over iterations (the paper's
        'adaptive' property), unlike sync (always N) and AD-PSGD (always 1)."""
        sched, _ = _mk("dsgd_aau", n=16)
        sizes = {e.n_active for e in take(sched, 60)}
        assert len(sizes) > 2

    def test_aau_grad_equals_restart(self):
        sched, _ = _mk("dsgd_aau")
        for ev in take(sched, 30):
            assert np.array_equal(ev.grad_workers, ev.restart_workers)

    def test_adpsgd_staleness_exists(self):
        """AD-PSGD averages into a neighbor that is NOT restarted — the
        staleness mechanism the paper criticizes (Fig. 1b)."""
        sched, _ = _mk("ad_psgd")
        found = False
        for ev in take(sched, 50):
            touched = {i for e in ev.active_edges for i in e}
            restarted = set(np.nonzero(ev.restart_workers)[0].tolist())
            if touched - restarted:
                found = True
                break
        assert found

    def test_prague_groups_have_expected_size(self):
        sched, _ = _mk("prague", group_size=4)
        sizes = [e.n_active for e in take(sched, 40)]
        assert max(sizes) <= 4 and min(sizes) >= 1

    @given(seed=st.integers(0, 50), n=st.sampled_from([2, 3, 5, 8, 16]))
    @settings(max_examples=20, deadline=None)
    def test_aau_events_always_fire(self, seed, n):
        """No deadlock: the stream always produces events (progress guarantee
        from the component-merge pathsearch condition)."""
        g = topology.erdos_renyi(n, 0.5, seed=seed)
        sm = StragglerModel(n=n, straggler_prob=0.3, slowdown=10.0, seed=seed)
        sched = make_scheduler("dsgd_aau", g, sm)
        evs = take(sched, 20)
        assert len(evs) == 20

    def test_determinism(self):
        e1 = take(_mk("dsgd_aau", seed=7)[0], 20)
        e2 = take(_mk("dsgd_aau", seed=7)[0], 20)
        for a, b in zip(e1, e2):
            assert a.time == b.time and np.array_equal(a.P, b.P)
