"""Consensus-matrix properties (paper Assumption 1 and Lemmas 1–2)."""
import numpy as np
import pytest

from conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()

from repro.core import consensus, topology


def _random_edges(n, rng, p=0.4):
    edges = []
    for i in range(n):
        for j in range(i + 1, n):
            if rng.random() < p:
                edges.append((i, j))
    return edges


class TestMetropolis:
    def test_empty_edges_is_identity(self):
        P = consensus.metropolis_matrix(5, [])
        assert np.allclose(P, np.eye(5))

    def test_single_edge(self):
        P = consensus.metropolis_matrix(3, [(0, 1)])
        assert P[0, 1] == pytest.approx(0.5)
        assert P[0, 0] == pytest.approx(0.5)
        assert P[2, 2] == pytest.approx(1.0)
        assert consensus.is_doubly_stochastic(P)

    def test_rejects_self_edge(self):
        with pytest.raises(ValueError):
            consensus.metropolis_matrix(3, [(1, 1)])

    @given(n=st.integers(2, 24), seed=st.integers(0, 1000))
    @settings(max_examples=60, deadline=None)
    def test_doubly_stochastic_for_any_active_set(self, n, seed):
        """Assumption 1: Metropolis weights are doubly stochastic for every
        symmetric active-edge set."""
        rng = np.random.default_rng(seed)
        P = consensus.metropolis_matrix(n, _random_edges(n, rng))
        assert consensus.is_doubly_stochastic(P)

    @given(n=st.integers(2, 12), seed=st.integers(0, 200))
    @settings(max_examples=30, deadline=None)
    def test_nonnegative_diagonal(self, n, seed):
        """Waiting-count weights keep P_ii = 1 − Σ P_ij ≥ 0."""
        rng = np.random.default_rng(seed)
        P = consensus.metropolis_matrix(n, _random_edges(n, rng, p=0.9))
        assert np.all(np.diag(P) >= -1e-12)


class TestProducts:
    def test_product_contracts_to_uniform(self):
        """Lemma 1/2: products of connected-graph Metropolis matrices
        converge geometrically to (1/N)·11ᵀ."""
        n = 8
        g = topology.ring(n)
        P = consensus.metropolis_matrix(n, g.edges)
        gaps = []
        Phi = np.eye(n)
        for k in range(60):
            Phi = Phi @ P
            gaps.append(consensus.contraction_to_uniform(Phi))
        assert gaps[-1] < 1e-3
        # geometric decay: later gaps shrink by a stable ratio
        assert gaps[50] < gaps[25] < gaps[10]

    def test_time_varying_product_doubly_stochastic(self):
        rng = np.random.default_rng(1)
        n = 10
        mats = [consensus.metropolis_matrix(n, _random_edges(n, rng))
                for _ in range(20)]
        Phi = consensus.consensus_product(mats)
        assert consensus.is_doubly_stochastic(Phi, tol=1e-8)

    def test_spectral_gap_positive_for_connected(self):
        g = topology.erdos_renyi(12, 0.3, seed=2)
        P = consensus.metropolis_matrix(12, g.edges)
        assert consensus.spectral_gap(P) > 0

    def test_beta_min_positive(self):
        n = 6
        P = consensus.metropolis_matrix(n, [(0, 1), (2, 3)])
        beta = consensus.beta_min_positive([P])
        assert 0 < beta <= 0.5
