"""Bucketed dynamic active-sets: the lane-width ladder contract
(``Scheduler.active_buckets``), stream-order-preserving bucketed packing
(``BucketedSparseEventBatch``), the bucketed ``sparse_scan`` dispatch, and
the in-place scatter kernel with its carry-donation contract.

The bucketed path must be an *exact* re-execution of the dense compiled
scan: same scheduler seed ⇒ same ``(W, S, y, ptr)`` trajectory and recorded
history, while each event pays only for its bucket's lane width.  N is kept
small and the DSGD-AAU ladder forced fine (``buckets=(4, 8, 16)``) so the
stream genuinely crosses buckets every few events.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology
from repro.core.baselines import make_scheduler
from repro.core.runner import DecentralizedTrainer
from repro.core.scheduler import (BucketedSparseEventBatch,
                                  SparseEventBatch, bucket_index,
                                  geometric_buckets)
from repro.core.straggler import StragglerModel
from repro.data.synthetic import ClassificationData
from repro.kernels.sparse_gossip import (scatter_rows_pallas,
                                         sparse_scatter_rows,
                                         sparse_scatter_rows_ref)

N = 16
LADDER = (4, 8, 16)
DATA = ClassificationData(n_workers=N, d=16, n_classes=4,
                          samples_per_worker=64, seed=0)


def loss_fn(params, batch):
    logits = batch["x"] @ params["w"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], axis=1))


def init_fn(key):
    return {"w": jax.random.normal(key, (16, 4)) * 0.1}


def _sched(alg, seed=0, **kw):
    g = topology.erdos_renyi(N, 0.4, seed=3)
    sm = StragglerModel(n=N, straggler_prob=0.2, slowdown=6.0, seed=seed)
    return make_scheduler(alg, g, sm, **kw)


def _trainer(alg, mode, seed=0, sched_kw=None, **kw):
    return DecentralizedTrainer(
        _sched(alg, seed, **(sched_kw or {})), loss_fn, init_fn,
        lambda w, s: DATA.batch(w, s, batch_size=8),
        DATA.eval_batch(64), eta0=0.2, eta_decay=0.99, seed=seed,
        mode=mode, **kw)


def _aau_bucketed(evs, buckets=LADDER):
    return BucketedSparseEventBatch.from_events(evs, buckets=buckets)


class TestLadderContract:
    def test_geometric_buckets_defaults(self):
        assert geometric_buckets(256) == (16, 64, 256)
        assert geometric_buckets(1024) == (16, 64, 256, 1024)
        assert geometric_buckets(512) == (16, 64, 256, 512)
        assert geometric_buckets(16) == (16,)
        assert geometric_buckets(8) == (8,)
        assert geometric_buckets(300) == (16, 64, 256, 300)

    def test_bucket_index_picks_smallest_fitting_rung(self):
        buckets = (4, 8, 16)
        assert bucket_index(buckets, 1) == 0
        assert bucket_index(buckets, 4) == 0
        assert bucket_index(buckets, 5) == 1
        assert bucket_index(buckets, 16) == 2
        with pytest.raises(ValueError):
            bucket_index(buckets, 17)

    @pytest.mark.parametrize("alg", ["ad_psgd", "prague", "agp",
                                     "dsgd_sync"])
    def test_constant_size_schedulers_stay_single_bucket(self, alg):
        sched = _sched(alg)
        buckets = sched.active_buckets()
        assert len(buckets) == 1
        assert buckets[-1] == sched.active_bound()

    def test_aau_ladder_defaults_and_override(self):
        assert _sched("dsgd_aau").active_buckets() == (N,)  # n ≤ base rung
        sched = _sched("dsgd_aau", buckets=LADDER)
        assert sched.active_buckets() == LADDER
        assert sched.active_buckets()[-1] == sched.active_bound()

    def test_aau_ladder_must_end_at_n(self):
        with pytest.raises(ValueError, match="must end at n"):
            _sched("dsgd_aau", buckets=(4, 8))


class TestBucketedPacking:
    def test_round_trip_reconstructs_stream_order(self):
        sched = _sched("dsgd_aau", buckets=LADDER)
        evs = list(itertools.islice(sched.events(), 24))
        bucketed = _aau_bucketed(evs)
        assert bucketed.E == 24
        assert len(set(bucketed.event_bucket.tolist())) > 1  # truly mixed
        for orig, back in zip(evs, bucketed.to_events(N)):
            assert back.k == orig.k
            assert back.time == pytest.approx(orig.time)
            np.testing.assert_array_equal(back.grad_workers,
                                          orig.grad_workers)
            np.testing.assert_array_equal(back.restart_workers,
                                          orig.restart_workers)
            np.testing.assert_allclose(back.P, orig.P)
            assert back.active_edges == orig.active_edges
            assert back.param_copies_sent == orig.param_copies_sent

    def test_events_land_in_smallest_fitting_bucket(self):
        sched = _sched("dsgd_aau", buckets=LADDER)
        evs = list(itertools.islice(sched.events(), 24))
        bucketed = _aau_bucketed(evs)
        for ev, b in zip(evs, bucketed.event_bucket):
            size = int(ev.grad_workers.sum())
            assert bucket_index(LADDER, size) == b
            assert size <= LADDER[b]

    def test_segments_tile_the_stream_in_order(self):
        sched = _sched("dsgd_aau", buckets=LADDER)
        evs = list(itertools.islice(sched.events(), 32))
        bucketed = _aau_bucketed(evs)
        covered = []
        prev_bucket = None
        for b, start, stop in bucketed.segments():
            assert stop > start
            assert b != prev_bucket  # maximal runs: no adjacent repeats
            prev_bucket = b
            assert (bucketed.event_bucket[start:stop] == b).all()
            covered.extend(range(start, stop))
        assert covered == list(range(32))

    def test_segment_batches_match_per_event_sizes(self):
        sched = _sched("dsgd_aau", buckets=LADDER)
        evs = list(itertools.islice(sched.events(), 32))
        bucketed = _aau_bucketed(evs)
        sizes = [int(ev.grad_workers.sum()) for ev in evs]
        seen = 0
        for b, off, seg in bucketed.segment_batches():
            assert seg.A == LADDER[b]
            np.testing.assert_array_equal(
                seg.n_workers, sizes[off:off + seg.E])
            seen += seg.E
        assert seen == 32

    def test_slice_is_a_stream_window(self):
        sched = _sched("ad_psgd")
        evs = list(itertools.islice(sched.events(), 10))
        batch = SparseEventBatch.from_events(evs, active_bound=2,
                                             edge_bound=1)
        part = batch.slice(3, 7)
        assert part.E == 4 and part.k0 == batch.k0 + 3
        np.testing.assert_array_equal(part.workers, batch.workers[3:7])
        np.testing.assert_array_equal(part.P_sub, batch.P_sub[3:7])
        for orig, back in zip(evs[3:7], part.to_events(N)):
            assert back.k == orig.k
            np.testing.assert_allclose(back.P, orig.P)

    def test_occupancy_accounts_for_every_event(self):
        sched = _sched("dsgd_aau", buckets=LADDER)
        evs = list(itertools.islice(sched.events(), 40))
        occ = _aau_bucketed(evs).occupancy()
        assert [o["A"] for o in occ] == list(LADDER)
        assert sum(o["events"] for o in occ) == 40
        for o in occ:
            if o["events"]:
                assert 0.0 < o["lane_fill"] <= 1.0

    def test_single_bucket_degenerates_to_plain_batch(self):
        sched = _sched("ad_psgd")
        evs = list(itertools.islice(sched.events(), 8))
        bucketed = BucketedSparseEventBatch.from_events(evs, buckets=(2,))
        segs = list(bucketed.segments())
        assert segs == [(0, 0, 8)]
        (b, off, seg), = bucketed.segment_batches()
        assert (b, off, seg.E) == (0, 0, 8)


class TestBucketedEquivalence:
    """Forced fine ladder at N=16 ⇒ the dispatch genuinely crosses buckets,
    and the result must still be bit-exact against the dense scan."""

    def test_bucketed_matches_dense_scan_and_per_event(self):
        per_event = _trainer("dsgd_aau", "per_event",
                             sched_kw={"buckets": LADDER})
        res_pe = per_event.run(max_events=40, eval_every=10)
        dense = _trainer("dsgd_aau", "scan", block_size=7, batch_pool=48,
                         sched_kw={"buckets": LADDER})
        res_dense = dense.run(max_events=40, eval_every=10)
        sparse = _trainer("dsgd_aau", "sparse_scan", block_size=7,
                          batch_pool=48, sched_kw={"buckets": LADDER})
        res_sparse = sparse.run(max_events=40, eval_every=10)

        for other, res_other, tol in ((dense, res_dense, 0.0),
                                      (per_event, res_pe, 1e-6)):
            for name, a, b in (("W", other.W, sparse.W),
                               ("S", other.S, sparse.S)):
                for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                    np.testing.assert_allclose(
                        np.asarray(la), np.asarray(lb), atol=tol,
                        err_msg=f"{name} vs {other.mode}")
            # push-sum weights and batch pointers must stay continuous
            # across every bucket-boundary dispatch split
            np.testing.assert_allclose(np.asarray(other.y),
                                       np.asarray(sparse.y), atol=tol)
            if other._ptr is not None:  # per_event keeps no batch pointers
                np.testing.assert_array_equal(np.asarray(other._ptr),
                                              np.asarray(sparse._ptr))
            assert len(res_other.history) == len(res_sparse.history)
            for p_o, p_s in zip(res_other.history, res_sparse.history):
                assert p_s.k == p_o.k
                assert p_s.time == pytest.approx(p_o.time)
                assert p_s.loss == pytest.approx(p_o.loss, abs=1e-5)
                assert p_s.comm_param_copies == p_o.comm_param_copies
            assert res_sparse.total_events == res_other.total_events

    def test_bucketed_warmup_leaves_state_unchanged(self):
        tr = _trainer("dsgd_aau", "sparse_scan",
                      sched_kw={"buckets": LADDER})
        W0 = jax.tree.map(lambda x: np.asarray(x).copy(), tr.W)
        tr.warmup()
        for a, b in zip(jax.tree.leaves(W0), jax.tree.leaves(tr.W)):
            np.testing.assert_array_equal(a, np.asarray(b))
        np.testing.assert_array_equal(np.asarray(tr._ptr), np.zeros(N))

    def test_bucket_caps_shrink_quadratically(self):
        cap = DecentralizedTrainer._bucket_cap
        buckets = (16, 64, 256)
        caps = [cap(buckets, b, 128) for b in range(3)]
        assert caps == [32, 2, 1]       # quantum · (b0/A)², floored at 1
        assert cap(buckets, 0, 8) == 8  # small targets bound the quantum

    def test_donated_carry_survives_repeated_runs(self):
        """same_init leaves S aliasing W; the sparse path must de-alias
        before donating the carry, and repeated dispatches must never
        reuse a donated buffer."""
        tr = _trainer("dsgd_aau", "sparse_scan", block_size=5,
                      batch_pool=48, sched_kw={"buckets": LADDER})
        tr.warmup()
        tr.run(max_events=25, eval_every=5)
        # every leaf is live — a donated-and-reused buffer would raise here
        for leaf in (jax.tree.leaves(tr.W) + jax.tree.leaves(tr.S)
                     + [tr.y, tr._ptr]):
            assert np.asarray(leaf).shape is not None
        assert not any(w is s for w, s in zip(jax.tree.leaves(tr.W),
                                              jax.tree.leaves(tr.S)))


class TestScatterKernel:
    def _case(self, n, d, A, pad, seed=0, worker0=False):
        key = jax.random.PRNGKey(seed)
        X = jax.random.normal(key, (n, d), jnp.float32)
        rows = jax.random.normal(jax.random.fold_in(key, 1), (A, d),
                                 jnp.float32)
        rng = np.random.default_rng(seed)
        w = np.full(A, -1, np.int32)
        m = A - pad
        pool = np.arange(1, n) if not worker0 else np.arange(n)
        pick = rng.choice(pool, size=m - worker0, replace=False)
        if worker0:
            pick = np.concatenate([[0], pick])
        w[:m] = np.sort(pick)
        return X, rows, jnp.asarray(w)

    @pytest.mark.parametrize("n,d,A,pad,worker0", [
        (16, 256, 2, 0, False),    # AD-PSGD pair, no padding
        (16, 256, 2, 1, False),    # isolated-worker event
        (16, 256, 4, 2, True),     # worker 0 active *and* padded lanes:
                                   # the row-0 writeback corner
        (64, 512, 8, 3, False),
        (256, 256, 16, 5, True),
    ])
    def test_matches_ref(self, n, d, A, pad, worker0):
        X, rows, w = self._case(n, d, A, pad, seed=n + A, worker0=worker0)
        out = scatter_rows_pallas(X, rows, w, block_d=256, interpret=True)
        ref = sparse_scatter_rows_ref(X, rows, w)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_all_padded_lanes_is_identity(self):
        X, rows, w = self._case(16, 256, 4, 0, seed=9)
        out = scatter_rows_pallas(X, rows, jnp.full_like(w, -1),
                                  block_d=256, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(X))

    def test_op_pads_lanes_and_feature_dim(self):
        """The ops wrapper handles A not a sublane multiple and D not a
        block_d multiple (pad lanes carry -1, pad columns are cropped)."""
        X, rows, w = self._case(16, 200, 3, 1, seed=4)
        Xc = jnp.array(X)  # keep an undonated copy for the oracle
        out = sparse_scatter_rows(X, rows, w, block_d=256)  # repro: disable=kernel-gate
        ref = sparse_scatter_rows_ref(Xc, rows, w)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_standalone_call_donates_the_carry(self):
        X, rows, w = self._case(16, 256, 4, 1, seed=2)
        X = jnp.array(X) + 0.0  # a buffer this test uniquely owns
        out = sparse_scatter_rows(X, rows, w, block_d=256)  # repro: disable=kernel-gate
        assert out.shape == (16, 256)
        # the donated-buffer read below is the point of the test
        assert X.is_deleted()   # repro: disable=use-after-donate
