"""Scenario subsystem: bit-exactness of paper_default, TimeModel protocol
conformance, stream compatibility, and distribution sanity.

The contracts pinned here:

- ``paper_default`` is *bit-exact* with the pre-scenario-engine
  ``StragglerModel``/``TimeSampler`` streams for all five schedulers — the
  scenario engine must never perturb recorded runs;
- every registered scenario satisfies the ``TimeModel`` surface the
  schedulers and the horizon batcher consume;
- ``sample_batch([w])`` and ``sample(w)`` consume the RNG stream
  identically (the m == 1 contract ``TimeSampler`` documents), so
  schedulers can mix the call styles without forking realizations;
- empirical moments/quantiles match each scenario's analytic
  ``mean_duration_factor`` and shape claims.
"""
import itertools

import numpy as np
import pytest

from repro.core import topology
from repro.core.baselines import make_scheduler
from repro.core.straggler import StragglerModel
from repro.scenarios import (Scenario, TimeModel, get_scenario,
                             scenario_names)
from repro.scenarios.library import (BimodalScenario, ChurnScenario,
                                     DiurnalScenario, HeavyTailScenario)

ALGS = ("dsgd_aau", "dsgd_sync", "ad_psgd", "prague", "agp")
N = 8
GRAPH = topology.erdos_renyi(N, 0.4, seed=3)


def _events_equal(a, b):
    assert a.k == b.k
    assert a.time == b.time
    np.testing.assert_array_equal(a.workers, b.workers)
    np.testing.assert_array_equal(a.P_sub, b.P_sub)
    np.testing.assert_array_equal(a.grad_lanes, b.grad_lanes)
    np.testing.assert_array_equal(a.restart_lanes, b.restart_lanes)
    np.testing.assert_array_equal(a.edges, b.edges)
    assert a.param_copies_sent == b.param_copies_sent


class TestPaperDefaultBitExact:
    """paper_default ≡ StragglerModel for every scheduler's event stream."""

    @pytest.mark.parametrize("alg", ALGS)
    def test_stream_bit_exact(self, alg):
        sm = StragglerModel(n=N, straggler_prob=0.2, slowdown=6.0, seed=5)
        sc = get_scenario("paper_default", n=N, seed=5,
                          straggler_prob=0.2, slowdown=6.0)
        ref = itertools.islice(make_scheduler(alg, GRAPH, sm).events(), 40)
        new = itertools.islice(make_scheduler(alg, GRAPH, sc).events(), 40)
        for a, b in zip(ref, new):
            _events_equal(a, b)

    def test_horizon_stream_bit_exact(self):
        sm = StragglerModel(n=N, straggler_prob=0.2, slowdown=6.0, seed=5)
        sc = get_scenario("paper_default", n=N, seed=5,
                          straggler_prob=0.2, slowdown=6.0)
        ref = itertools.islice(
            make_scheduler("ad_psgd", GRAPH, sm, horizon=8).events(), 40)
        new = itertools.islice(
            make_scheduler("ad_psgd", GRAPH, sc, horizon=8).events(), 40)
        for a, b in zip(ref, new):
            _events_equal(a, b)

    def test_heterogeneity_passthrough(self):
        sm = StragglerModel(n=N, heterogeneity=0.5, seed=2)
        sc = get_scenario("paper_default", n=N, seed=2, heterogeneity=0.5)
        np.testing.assert_array_equal(sm.make_sampler().base,
                                      sc.make_sampler().base)


class TestProtocolConformance:
    @pytest.mark.parametrize("name", scenario_names())
    def test_time_model_surface(self, name):
        sc = get_scenario(name, n=6, seed=1)
        assert isinstance(sc, Scenario)
        s = sc.make_sampler()
        assert isinstance(s, TimeModel)  # runtime-checkable protocol
        assert s.base.shape == (6,)
        assert float(s.sample(3)) > 0
        assert s.sample_batch([0, 2, 4]).shape == (3,)
        assert s.sample_horizon(5).shape == (5,)
        assert s.sample_all().shape == (6,)

    @pytest.mark.parametrize("name", scenario_names())
    def test_deterministic_given_seed(self, name):
        a = get_scenario(name, n=6, seed=7).make_sampler()
        b = get_scenario(name, n=6, seed=7).make_sampler()
        for _ in range(5):
            np.testing.assert_array_equal(a.sample_all(), b.sample_all())
        np.testing.assert_array_equal(a.sample_horizon(9),
                                      b.sample_horizon(9))

    @pytest.mark.parametrize("name", scenario_names())
    def test_sample_batch_stream_compatible_with_sample(self, name):
        """Driving one sampler by repeated sample(w) and another by the
        equivalent singleton sample_batch([w]) calls must produce identical
        value *streams* — the contract that lets scheduler hot loops mix
        the two call styles."""
        a = get_scenario(name, n=6, seed=3).make_sampler()
        b = get_scenario(name, n=6, seed=3).make_sampler()
        workers = [0, 3, 5, 1, 3, 0, 2, 4, 4, 1]
        va = [a.sample(w) for w in workers]
        vb = [float(b.sample_batch([w])[0]) for w in workers]
        np.testing.assert_array_equal(va, vb)

    def test_registry_rejects_unknowns(self):
        with pytest.raises(KeyError, match="unknown scenario"):
            get_scenario("nope", n=4)
        with pytest.raises(TypeError, match="no parameter"):
            get_scenario("heavy_tail", n=4, beta=2.0)

    def test_overrides_applied(self):
        sc = get_scenario("heavy_tail", n=4, alpha=2.5)
        assert sc.alpha == 2.5


class TestDistributionSanity:
    """Moments/quantiles of each scenario match its analytic description."""

    def _draws(self, sc, rounds=4000):
        s = sc.make_sampler()
        return np.concatenate([s.sample_all() for _ in range(rounds // sc.n)])

    @pytest.mark.parametrize("name", scenario_names())
    def test_empirical_mean_matches_mean_duration_factor(self, name):
        sc = get_scenario(name, n=8, seed=0)
        d = self._draws(sc, rounds=6000)
        if name == "heavy_tail":  # infinite-variance mean converges slowly
            assert abs(d.mean() - sc.mean_duration_factor()) \
                < 0.35 * sc.mean_duration_factor()
        else:
            assert d.mean() == pytest.approx(
                sc.mean_duration_factor() * sc.base_time, rel=0.12)

    def test_heavy_tail_quantiles(self):
        sc = HeavyTailScenario(n=8, seed=0, alpha=2.5)
        d = self._draws(sc, rounds=8000)
        assert d.min() >= 1.0  # x_m = base_time floor
        assert np.median(d) == pytest.approx(2 ** (1 / 2.5), rel=0.05)
        # the tail really is heavy: P[X > 4] = 4^-2.5 ≈ 3.1%
        assert np.mean(d > 4.0) == pytest.approx(4.0 ** -2.5, abs=0.015)

    def test_bimodal_clusters_are_persistent(self):
        sc = BimodalScenario(n=16, seed=0, slow_frac=0.25, slow_factor=5.0)
        s = sc.make_sampler()
        assert len(s.slow_workers) == 4
        draws = np.stack([s.sample_all() for _ in range(200)])
        slow_mean = draws[:, s.slow_workers].mean()
        fast = np.setdiff1d(np.arange(16), s.slow_workers)
        assert slow_mean == pytest.approx(5.0 * draws[:, fast].mean(),
                                          rel=0.05)

    def test_diurnal_intensity_varies_with_phase(self):
        sc = DiurnalScenario(n=4, seed=0, straggler_prob=0.6, slowdown=10.0,
                             period=64.0, jitter=0.0)
        s = sc.make_sampler()
        draws = np.stack([s.sample_all() for _ in range(256)])  # 4 periods
        # worker 0 (phase 0): straggler intensity peaks around draw 16 of
        # each 64-draw period (sin ≈ 1 ⇒ p ≈ 0.6) and bottoms around draw
        # 48 (sin ≈ −1 ⇒ p ≈ 0); compare the two quarter-period windows
        w0 = draws[:, 0].reshape(4, 64)
        peak, trough = w0[:, 8:24], w0[:, 40:56]
        assert (peak > 5).mean() > 0.3
        assert (trough > 5).mean() < 0.18
        assert (peak > 5).mean() > 2.5 * max((trough > 5).mean(), 1e-9)

    def test_churn_downtime_shape(self):
        sc = ChurnScenario(n=8, seed=0, churn_prob=0.05, downtime=25.0,
                           jitter=0.0)
        d = self._draws(sc, rounds=8000)
        down = d > 5.0  # an offline period dwarfs a normal computation
        assert down.mean() == pytest.approx(0.05, abs=0.012)
        # offline durations are exponential with the configured mean
        assert (d[down] - 1.0).mean() == pytest.approx(25.0, rel=0.25)


class TestSchedulerIntegration:
    @pytest.mark.parametrize("name", scenario_names())
    @pytest.mark.parametrize("alg", ALGS)
    def test_streams_well_formed(self, name, alg):
        sc = get_scenario(name, n=N, seed=1)
        sched = make_scheduler(alg, GRAPH, sc)
        evs = list(itertools.islice(sched.events(), 20))
        assert [e.k for e in evs] == list(range(20))
        assert all(e.time > 0 for e in evs)
        assert all(len(e.workers) <= sched.active_bound() for e in evs)

    @pytest.mark.parametrize("name", scenario_names())
    def test_horizon_batcher_works(self, name):
        sc = get_scenario(name, n=N, seed=1)
        evs = list(itertools.islice(
            make_scheduler("agp", GRAPH, sc, horizon=8).events(), 30))
        assert [e.k for e in evs] == list(range(30))
