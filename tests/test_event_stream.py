"""Compiled event stream: EventBatch packing, scan/per-event equivalence,
and the batched/masked gossip kernels.

The block-compiled trainer (core/runner.py ``mode="scan"``) must be an
*exact* re-execution of the legacy per-event interpreter: same scheduler
seed ⇒ same ``(W, S, y)`` trajectory and the same recorded history.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aau, topology
from repro.core.baselines import make_scheduler
from repro.core.consensus import metropolis_matrix
from repro.core.runner import DecentralizedTrainer
from repro.core.scheduler import EventBatch
from repro.core.straggler import StragglerModel
from repro.data.synthetic import ClassificationData
from repro.kernels.gossip_mix import (gossip_mix_batched,
                                      gossip_mix_batched_ref,
                                      masked_gossip_mix, masked_gossip_ref)

N = 8
DATA = ClassificationData(n_workers=N, d=16, n_classes=4,
                          samples_per_worker=64, seed=0)


def loss_fn(params, batch):
    logits = batch["x"] @ params["w"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], axis=1))


def init_fn(key):
    return {"w": jax.random.normal(key, (16, 4)) * 0.1}


def _sched(alg, seed=0):
    g = topology.erdos_renyi(N, 0.4, seed=3)
    sm = StragglerModel(n=N, straggler_prob=0.2, slowdown=6.0, seed=seed)
    return make_scheduler(alg, g, sm)


def _trainer(alg, mode, seed=0, **kw):
    return DecentralizedTrainer(
        _sched(alg, seed), loss_fn, init_fn,
        lambda w, s: DATA.batch(w, s, batch_size=8),
        DATA.eval_batch(64), eta0=0.2, eta_decay=0.99, seed=seed,
        mode=mode, **kw)


class TestEventBatchPacking:
    @pytest.mark.parametrize("alg", ["dsgd_aau", "ad_psgd", "prague", "agp"])
    def test_round_trip(self, alg):
        sched = _sched(alg)
        evs = list(itertools.islice(sched.events(), 12))
        batch = EventBatch.from_events(evs, edge_bound=sched.edge_bound())
        assert batch.E == 12 and batch.n == N
        assert batch.edges.shape[1] == sched.edge_bound()
        for orig, back in zip(evs, batch.to_events()):
            assert back.k == orig.k
            assert back.time == pytest.approx(orig.time)
            np.testing.assert_array_equal(back.grad_workers, orig.grad_workers)
            np.testing.assert_array_equal(back.restart_workers,
                                          orig.restart_workers)
            np.testing.assert_allclose(back.P, orig.P)
            assert back.active_edges == orig.active_edges
            assert back.param_copies_sent == orig.param_copies_sent

    def test_event_batches_api(self):
        sched = _sched("ad_psgd")
        batches = list(itertools.islice(sched.event_batches(5), 3))
        assert [b.E for b in batches] == [5, 5, 5]
        assert batches[1].k0 == 5  # consecutive packing
        # AD-PSGD's compact-edge form is one edge per event, not O(n²)
        assert batches[0].edges.shape == (5, 1, 2)

    def test_pad_to_shapes(self):
        sched = _sched("dsgd_aau")
        evs = list(itertools.islice(sched.events(), 3))
        batch = EventBatch.from_events(evs).pad_to(8)
        assert batch.E == 8
        assert not batch.grad_workers[3:].any()
        assert not batch.restart_workers[3:].any()
        np.testing.assert_allclose(batch.P[4], np.eye(N))
        # padded events move no bytes
        assert batch.param_copies_sent[3:].sum() == 0
        assert (batch.n_edges[3:] == 0).all()

    def test_identity_padding_is_noop_on_device(self):
        """A block of pure no-op events leaves (W, S, y, ptr) bit-exact."""
        tr = _trainer("dsgd_aau", "scan")
        tr._ensure_scan()
        W0 = jax.tree.map(lambda x: np.asarray(x).copy(), tr.W)
        sched = _sched("dsgd_aau")
        ev = itertools.islice(sched.events(), 1)
        noop = EventBatch.from_events(list(ev), edge_bound=sched.edge_bound())
        off = np.zeros_like(noop.grad_workers)
        import dataclasses
        noop = dataclasses.replace(
            noop, grad_workers=off, restart_workers=off,
            P=np.eye(N, dtype=np.float32)[None],
            edges=np.full_like(noop.edges, -1),
            n_edges=np.zeros_like(noop.n_edges))
        tr._dispatch_block(noop.pad_to(tr.block_size), rounds=0)
        for a, b in zip(jax.tree.leaves(W0), jax.tree.leaves(tr.W)):
            np.testing.assert_array_equal(a, np.asarray(b))
        np.testing.assert_array_equal(np.asarray(tr._ptr), np.zeros(N))


class TestScanEquivalence:
    """Same scheduler seed ⇒ the compiled scan path replays the per-event
    trainer exactly (fp32): parameters, snapshots, push-sum weights, history."""

    @pytest.mark.parametrize("alg", ["dsgd_aau", "ad_psgd", "agp"])
    def test_matches_per_event(self, alg):
        ref = _trainer(alg, "per_event")
        res_ref = ref.run(max_events=40, eval_every=10)
        # block_size deliberately not dividing eval_every: exercises the
        # eval-boundary snapping + identity padding
        scan = _trainer(alg, "scan", block_size=7, batch_pool=48)
        res_scan = scan.run(max_events=40, eval_every=10)

        for name, a, b in (("W", ref.W, scan.W), ("S", ref.S, scan.S)):
            for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                np.testing.assert_allclose(
                    np.asarray(la), np.asarray(lb), atol=1e-6, err_msg=name)
        np.testing.assert_allclose(np.asarray(ref.y), np.asarray(scan.y),
                                   atol=1e-6)  # push-sum weights (AGP ≠ 1)
        assert len(res_ref.history) == len(res_scan.history)
        for p_ref, p_scan in zip(res_ref.history, res_scan.history):
            assert p_scan.k == p_ref.k
            assert p_scan.time == pytest.approx(p_ref.time)
            assert p_scan.loss == pytest.approx(p_ref.loss, abs=1e-5)
            assert p_scan.comm_param_copies == p_ref.comm_param_copies
            assert p_scan.n_active_mean == pytest.approx(p_ref.n_active_mean)
        assert res_scan.total_events == res_ref.total_events
        assert res_scan.total_time == pytest.approx(res_ref.total_time)

    def test_agp_pushsum_debias_survives_scan(self):
        scan = _trainer("agp", "scan", block_size=8, batch_pool=48)
        scan.run(max_events=30, eval_every=30)
        y = np.asarray(scan.y)
        assert not np.allclose(y, 1.0)        # row-stochastic pushes moved mass
        assert y.sum() == pytest.approx(N, rel=1e-4)  # total mass conserved

    def test_max_time_bound(self):
        ref = _trainer("dsgd_aau", "per_event").run(max_time=20.0, eval_every=10)
        scan = _trainer("dsgd_aau", "scan", block_size=4).run(
            max_time=20.0, eval_every=10)
        assert scan.total_events == ref.total_events
        assert scan.final_loss == pytest.approx(ref.final_loss, abs=1e-5)

    def test_warmup_leaves_state_unchanged(self):
        tr = _trainer("dsgd_aau", "scan")
        W0 = jax.tree.map(lambda x: np.asarray(x).copy(), tr.W)
        tr.warmup()
        for a, b in zip(jax.tree.leaves(W0), jax.tree.leaves(tr.W)):
            np.testing.assert_array_equal(a, np.asarray(b))

    def test_eval_buffer_growth_matches_per_event(self):
        """A max_time-bounded run has no up-front eval count, so the scan
        modes start from a small device eval buffer (16 rows) and must grow
        it mid-run; the recorded history has to stay point-for-point equal
        to the per-event path across the growth boundary."""
        ref = _trainer("ad_psgd", "per_event")
        res_ref = ref.run(max_time=8.0, eval_every=1)
        scan = _trainer("ad_psgd", "scan", block_size=4)
        res_scan = scan.run(max_time=8.0, eval_every=1)
        assert len(res_ref.history) > 16  # the initial cap was outgrown
        assert len(res_scan.history) == len(res_ref.history)
        for p_ref, p_scan in zip(res_ref.history, res_scan.history):
            assert p_scan.k == p_ref.k
            assert p_scan.time == pytest.approx(p_ref.time)
            assert p_scan.loss == pytest.approx(p_ref.loss, abs=1e-5)
            assert p_scan.metric == pytest.approx(p_ref.metric, abs=1e-5)
            assert p_scan.comm_param_copies == p_ref.comm_param_copies
            assert p_scan.n_active_mean == pytest.approx(p_ref.n_active_mean)


class TestBatchedMaskedKernels:
    @pytest.mark.parametrize("n,d", [(8, 128), (13, 257), (16, 640)])
    def test_masked_matches_ref(self, n, d):
        k1, k2 = jax.random.split(jax.random.PRNGKey(n * d))
        W = jax.random.normal(k1, (n, d))
        G = jax.random.normal(k2, (n, d))
        P = jnp.asarray(metropolis_matrix(
            n, [(i, (i + 1) % n) for i in range(n - 1)]), jnp.float32)
        mask = (jnp.arange(n) % 2).astype(jnp.float32) * 0.1
        out = masked_gossip_mix(W, G, P, mask, block_d=256)
        ref = masked_gossip_ref(W, G, P, mask)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    def test_masked_zero_mask_is_plain_mix(self):
        n, d = 8, 256
        W = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        P = jnp.asarray(metropolis_matrix(
            n, [(i, (i + 1) % n) for i in range(n)]), jnp.float32)
        out = masked_gossip_mix(W, jnp.ones_like(W), P, jnp.zeros(n))
        ref = masked_gossip_ref(W, jnp.zeros_like(W), P, jnp.zeros(n))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)

    @pytest.mark.parametrize("E,n,d", [(3, 8, 256), (5, 12, 384)])
    def test_batched_matches_ref(self, E, n, d):
        W = jax.random.normal(jax.random.PRNGKey(E + n), (E, n, d))
        mats = [metropolis_matrix(
            n, [(i, (i + e) % n) for i in range(n - 1) if i != (i + e) % n])
            for e in range(1, E + 1)]
        P = jnp.asarray(np.stack(mats), jnp.float32)
        out = gossip_mix_batched(W, P, block_d=128)
        ref = gossip_mix_batched_ref(W, P)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    def test_fused_step_matches_unfused(self):
        n, d = 16, 640
        key = jax.random.PRNGKey(7)
        W = {"w": jax.random.normal(key, (n, d))}
        G = {"w": jax.random.normal(jax.random.fold_in(key, 1), (n, d))}
        P = jnp.asarray(metropolis_matrix(
            n, [(i, (i + 1) % n) for i in range(n)]), jnp.float32)
        gm = jnp.arange(n) % 2 == 0
        y = jnp.ones(n)
        eta = jnp.float32(0.1)
        ref = aau.masked_gossip_step(W, W, y, G, P, gm, gm, eta,
                                     use_kernel=False)
        fused = aau.masked_gossip_step(W, W, y, G, P, gm, gm, eta,
                                       use_kernel=True)
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(fused)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-5)

    def test_scan_with_kernel_matches_plain_scan(self):
        ref = _trainer("dsgd_aau", "scan", block_size=4, batch_pool=24)
        res_ref = ref.run(max_events=12, eval_every=12)
        fused = _trainer("dsgd_aau", "scan", block_size=4, batch_pool=24,
                         use_kernel=True)
        res_fused = fused.run(max_events=12, eval_every=12)
        assert res_fused.final_loss == pytest.approx(res_ref.final_loss,
                                                     abs=1e-4)
