"""Input-shape table, SWA long-context variants, and abstract spec coverage."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.launch import shapes as SH
from repro.models import decode_step, init_decode_state, init_model
from repro.models.layers import KVCache


class TestShapeTable:
    def test_assigned_shapes(self):
        assert SH.SHAPES["train_4k"].seq_len == 4096
        assert SH.SHAPES["train_4k"].global_batch == 256
        assert SH.SHAPES["prefill_32k"].seq_len == 32768
        assert SH.SHAPES["prefill_32k"].global_batch == 32
        assert SH.SHAPES["decode_32k"].global_batch == 128
        assert SH.SHAPES["long_500k"].seq_len == 524288
        assert SH.SHAPES["long_500k"].global_batch == 1

    @pytest.mark.parametrize("name", ASSIGNED)
    def test_long500k_variant_is_subquadratic(self, name):
        cfg = SH.shape_config(get_config(name), SH.SHAPES["long_500k"])
        if cfg.family == "ssm":
            assert cfg.attn_window is None           # O(1) state, no attention
        else:
            assert cfg.attn_window is not None       # native (hybrid) or SWA
            assert cfg.attn_window <= SH.SWA_WINDOW

    @pytest.mark.parametrize("name", ASSIGNED)
    def test_decode_state_memory_is_windowed(self, name):
        """long_500k decode state must NOT scale with the 524k history."""
        cfg = SH.shape_config(get_config(name), SH.SHAPES["long_500k"])
        state = jax.eval_shape(
            lambda: init_decode_state(cfg, 1, SH.SHAPES["long_500k"].seq_len,
                                      filled=True))
        total = sum(np.prod(l.shape) * l.dtype.itemsize
                    for l in jax.tree.leaves(state))
        # window-bounded: << seq_len × kv × dh × layers at full length
        assert total < 4e9, f"{name}: {total/2**30:.1f} GiB decode state"

    def test_train_specs_worker_stacked(self):
        from repro.launch.mesh import TrainAxes
        cfg = get_config("qwen3-8b")
        axes = TrainAxes(pod=None, worker="worker", fsdp="fsdp", model="model")
        batch, specs = SH.train_input_specs(cfg, SH.SHAPES["train_4k"], 4, axes)
        assert batch["tokens"].shape == (4, 64, 4096)
        assert tuple(specs["tokens"])[0] == "worker"

    def test_train_specs_reject_indivisible_workers(self):
        from repro.launch.mesh import TrainAxes
        cfg = get_config("qwen3-8b")
        axes = TrainAxes(pod=None, worker="worker", fsdp=None, model="model")
        with pytest.raises(ValueError):
            SH.train_input_specs(cfg, SH.SHAPES["train_4k"], 7, axes)


class TestLongContextDecode:
    """Numerical long-context decode on reduced configs: rolling-window SWA
    must equal full attention restricted to the window."""

    def test_swa_decode_matches_windowed_reference(self):
        cfg = get_config("mistral-nemo-12b").reduced()
        cfg = dataclasses.replace(cfg, attn_window=16)
        params = init_model(jax.random.PRNGKey(0), cfg)
        B, T = 1, 40   # decode well past the window
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                  cfg.vocab_size)
        # reference: same model, full-length cache (window mask still applies)
        big = init_decode_state(dataclasses.replace(cfg, attn_window=None),
                                B, T)
        # rolling: window-sized cache
        small = init_decode_state(cfg, B, T)
        # cache sizes differ: rolling is window-bounded
        size_small = small.k.shape[2] if hasattr(small, "k") else \
            jax.tree.leaves(small)[0].shape
        lg_roll = None
        st = small
        cfg_full = dataclasses.replace(cfg)  # same window in both paths
        st_full = init_decode_state(
            dataclasses.replace(cfg, attn_window=10**9), B, T)
        stf = st_full
        outs_roll, outs_full = [], []
        for t in range(T):
            lr, st = decode_step(params, cfg, toks[:, t], st, jnp.int32(t))
            lf, stf = decode_step(params, cfg_full, toks[:, t], stf,
                                  jnp.int32(t))
            outs_roll.append(lr)
            outs_full.append(lf)
        # rolling-window logits == full-cache logits (mask equivalence)
        np.testing.assert_allclose(np.asarray(jnp.stack(outs_roll)),
                                   np.asarray(jnp.stack(outs_full)), atol=2e-4)

    def test_rwkv_state_constant_memory(self):
        cfg = get_config("rwkv6-1.6b").reduced()
        s1 = jax.eval_shape(lambda: init_decode_state(cfg, 1, 1024))
        s2 = jax.eval_shape(lambda: init_decode_state(cfg, 1, 524288))
        n1 = sum(np.prod(l.shape) for l in jax.tree.leaves(s1))
        n2 = sum(np.prod(l.shape) for l in jax.tree.leaves(s2))
        assert n1 == n2  # O(1) in history length
