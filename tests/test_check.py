"""The repro.check lint engine: rules, pragmas, reporters, CLI, clean tree.

The fixture corpus under ``tests/fixtures/check/`` seeds one violation per
rule family with ``# expect: <rule>`` markers on the exact lines findings
must anchor to — the parametrized test asserts the finding set equals the
expectation set, so a rule that over-fires (extra lines) or under-fires
(missed lines) both fail.  Pragma-suppressed duplicates in the same
fixtures carry no marker, which *is* the suppression assertion.
"""
import io
import json
import re
from pathlib import Path

import pytest

from repro.check import CheckConfig, check_paths, check_source
from repro.check.__main__ import main as check_main
from repro.check.reporters import report_json, report_text

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "check"
_EXPECT = re.compile(r"#\s*expect:\s*(?P<rules>[\w\-]+(?:\s*,\s*[\w\-]+)*)")

# fixtures are seeded violations: lint them with the path exclude lifted
FIXTURE_CFG = CheckConfig(exclude=())


def _expectations(source: str):
    exp = {}
    for lineno, line in enumerate(source.splitlines(), 1):
        m = _EXPECT.search(line)
        if m:
            exp[lineno] = {r.strip() for r in m.group("rules").split(",")}
    return exp


@pytest.mark.parametrize(
    "fixture", sorted(FIXTURES.glob("*.py")), ids=lambda p: p.stem)
def test_fixture_findings_match_expectations(fixture):
    source = fixture.read_text()
    expected = _expectations(source)
    assert expected, f"fixture {fixture.name} declares no expectations"
    findings = check_source(source, str(fixture), FIXTURE_CFG)
    got = {}
    for f in findings:
        got.setdefault(f.line, set()).add(f.rule)
    assert got == expected, (
        f"{fixture.name}: findings {got} != expected {expected}")


def test_every_rule_family_has_a_fixture():
    rules_seen = set()
    for fixture in FIXTURES.glob("*.py"):
        for lines in _expectations(fixture.read_text()).values():
            rules_seen |= lines
    assert {
        "use-after-donate", "missing-alias-break", "pallas-alias",
        "kernel-gate", "host-sync", "rng-order", "global-rng",
        "jit-in-loop", "unhashable-static", "loop-varying-static",
    } <= rules_seen


def test_blanket_pragma_suppresses_all_rules():
    src = "import numpy as np\nx = np.random.rand(3)  # repro: disable\n"
    assert check_source(src, "t.py", FIXTURE_CFG) == []
    src_wrong = "import numpy as np\nx = np.random.rand(3)  # repro: disable=host-sync\n"
    assert [f.rule for f in check_source(src_wrong, "t.py", FIXTURE_CFG)] == [
        "global-rng"]


def test_pragma_inside_string_literal_is_inert():
    src = ('import numpy as np\n'
           's = "# repro: disable=global-rng"\n'
           'x = np.random.rand(3)\n')
    assert [f.rule for f in check_source(src, "t.py", FIXTURE_CFG)] == [
        "global-rng"]


def test_rule_selection_config():
    fixture = FIXTURES / "rng_order_violations.py"
    cfg = CheckConfig(exclude=(), enabled_rules=("global-rng",))
    # rng-order is an alias of the same rule instance: selection is by the
    # rule's primary id, so enabling either family id enables the family
    findings = check_source(fixture.read_text(), str(fixture), cfg)
    assert findings == []
    cfg = CheckConfig(exclude=(), enabled_rules=("rng-order",))
    findings = check_source(fixture.read_text(), str(fixture), cfg)
    assert {f.rule for f in findings} == {"rng-order", "global-rng"}


def test_parse_error_is_reported_not_raised():
    findings = check_source("def broken(:\n", "t.py", FIXTURE_CFG)
    assert [f.rule for f in findings] == ["parse-error"]


def test_repo_tree_is_clean():
    """The acceptance gate: zero findings on src/tests/benchmarks."""
    findings = check_paths([str(REPO / "src"), str(REPO / "tests"),
                            str(REPO / "benchmarks")])
    assert findings == [], "\n".join(f.format() for f in findings)


def test_default_config_excludes_fixture_corpus():
    findings = check_paths([str(FIXTURES)])
    assert findings == []
    assert check_paths([str(FIXTURES)], FIXTURE_CFG), (
        "lifting the exclude must surface the seeded violations")


def test_text_and_json_reporters():
    fixture = FIXTURES / "host_sync_violations.py"
    findings = check_source(fixture.read_text(), str(fixture), FIXTURE_CFG)
    assert findings
    out = io.StringIO()
    report_text(findings, out)
    text = out.getvalue()
    assert "[host-sync]" in text and f"{len(findings)} finding(s)" in text
    out = io.StringIO()
    report_json(findings, out)
    doc = json.loads(out.getvalue())
    assert doc["total"] == len(findings)
    assert doc["counts"]["host-sync"] == len(findings)
    assert {f["rule"] for f in doc["findings"]} == {"host-sync"}
    assert all(f["path"].endswith("host_sync_violations.py")
               for f in doc["findings"])


def test_cli_exit_codes(capsys):
    assert check_main([str(REPO / "src")]) == 0
    capsys.readouterr()
    rc = check_main(["--include-fixtures", "--format", "json",
                     str(FIXTURES / "recompile_violations.py")])
    assert rc == 1
    doc = json.loads(capsys.readouterr().out)
    assert doc["counts"]["jit-in-loop"] == 1
