"""End-to-end convergence: the paper's headline claims at test scale.

These are the system-level behaviour tests — DSGD-AAU must (i) converge,
(ii) match synchronous DSGD per-iteration while being much faster in virtual
wall-clock under stragglers, and (iii) beat the fully-asynchronous baselines
for a fixed virtual-time budget (Fig. 3/4 & Table 2 at miniature scale).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology
from repro.core.baselines import make_scheduler
from repro.core.runner import DecentralizedTrainer
from repro.core.straggler import StragglerModel
from repro.data.synthetic import ClassificationData

N = 16
DATA = ClassificationData(n_workers=N, d=32, n_classes=10,
                          partition="label_shard", classes_per_worker=5,
                          samples_per_worker=256, seed=0)


def loss_fn(params, batch):
    logits = batch["x"] @ params["w1"]
    logits = jax.nn.relu(logits) @ params["w2"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], axis=1))


def eval_fn(params, batch):
    logits = jax.nn.relu(batch["x"] @ params["w1"]) @ params["w2"]
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
    return loss_fn(params, batch), acc


def init_fn(key):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (32, 64)) * 0.1,
            "w2": jax.random.normal(k2, (64, 10)) * 0.1}


def _trainer(alg, seed=0, **kw):
    g = topology.erdos_renyi(N, 0.3, seed=3)
    sm = StragglerModel(n=N, straggler_prob=0.15, slowdown=8.0, seed=seed)
    sched = make_scheduler(alg, g, sm, **kw)
    return DecentralizedTrainer(
        sched, loss_fn, init_fn,
        lambda w, s: DATA.batch(w, s, batch_size=32),
        DATA.eval_batch(512), eval_fn=eval_fn, eta0=0.2, seed=seed)


class TestConvergence:
    def test_aau_converges(self):
        res = _trainer("dsgd_aau").run(max_events=150, eval_every=50)
        first = res.history[0].loss
        assert res.final_loss < first * 0.7
        assert res.final_metric > 0.4

    def test_aau_matches_sync_per_virtual_time_budget(self):
        """For an equal virtual-time budget, AAU reaches lower loss than the
        straggler-stalled synchronous baseline (paper Fig. 4)."""
        budget = 120.0
        aau = _trainer("dsgd_aau").run(max_time=budget, eval_every=50)
        syn = _trainer("dsgd_sync").run(max_time=budget, eval_every=50)
        assert aau.final_loss < syn.final_loss

    def test_aau_beats_async_baselines_per_iteration(self):
        """Fig. 3: per-iteration, AAU's larger adaptive active sets dominate
        the single-worker updates of AD-PSGD / AGP."""
        res = {alg: _trainer(alg).run(max_events=60, eval_every=60)
               for alg in ("dsgd_aau", "ad_psgd", "agp")}
        assert res["dsgd_aau"].final_loss < res["ad_psgd"].final_loss
        assert res["dsgd_aau"].final_loss < res["agp"].final_loss

    def test_aau_beats_prague_and_sync_in_time_budget(self):
        """Fig. 4 / Table 2: for a fixed virtual wall-clock budget AAU beats
        the barrier-bound algorithms (sync; Prague's group barriers)."""
        budget = 120.0
        res = {alg: _trainer(alg).run(max_time=budget, eval_every=100)
               for alg in ("dsgd_aau", "prague", "dsgd_sync")}
        assert res["dsgd_aau"].final_loss < res["prague"].final_loss
        assert res["dsgd_aau"].final_loss < res["dsgd_sync"].final_loss

    def test_communication_accounting(self):
        res = _trainer("dsgd_aau").run(max_events=50, eval_every=25)
        assert res.total_comm_copies > 0
        assert res.comm_bytes() == res.total_comm_copies * res.param_count * 4

    def test_consensus_across_workers(self):
        """After training, worker parameters are near consensus (bounded
        disagreement — the quantity Theorem 1's proof controls)."""
        tr = _trainer("dsgd_aau")
        tr.run(max_events=200, eval_every=200)
        W = np.asarray(tr.W["w1"])
        mean = W.mean(0)
        disagreement = np.max(np.linalg.norm(W - mean, axis=(1, 2)))
        assert disagreement < 0.5 * np.linalg.norm(mean)

    def test_deterministic_runs(self):
        r1 = _trainer("dsgd_aau", seed=5).run(max_events=30, eval_every=30)
        r2 = _trainer("dsgd_aau", seed=5).run(max_events=30, eval_every=30)
        assert r1.final_loss == pytest.approx(r2.final_loss, rel=1e-5)
