"""Figure 9/10 ablation: how straggler probability & slow-down affect each
algorithm's accuracy at a fixed virtual-time budget.

  PYTHONPATH=src python examples/straggler_ablation.py
"""
import sys

sys.path.insert(0, "benchmarks") if "benchmarks" not in sys.path else None

from benchmarks.common import make_classification_trainer

BUDGET = 50.0

print("== straggler probability sweep (slowdown 10x) ==")
print(f"{'prob':>6s}  " + "  ".join(f"{a:>10s}" for a in ("dsgd_aau", "ad_psgd", "prague")))
for prob in (0.05, 0.1, 0.2, 0.4):
    accs = []
    for alg in ("dsgd_aau", "ad_psgd", "prague"):
        res = make_classification_trainer(alg, 16, straggler_prob=prob).run(
            max_time=BUDGET, eval_every=10**6)
        accs.append(res.final_metric)
    print(f"{prob:6.2f}  " + "  ".join(f"{a:10.4f}" for a in accs))

print("== slow-down sweep (prob 10%) ==")
for slow in (5.0, 10.0, 20.0, 40.0):
    accs = []
    for alg in ("dsgd_aau", "ad_psgd", "prague"):
        res = make_classification_trainer(alg, 16, slowdown=slow).run(
            max_time=BUDGET, eval_every=10**6)
        accs.append(res.final_metric)
    print(f"{slow:5.0f}x  " + "  ".join(f"{a:10.4f}" for a in accs))
