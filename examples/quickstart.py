"""Quickstart: straggler-resilient decentralized training in ~30 lines.

Trains the paper's 2-NN on synthetic non-iid data with all five algorithms
under a 10×-slowdown straggler model and prints the Table-2-style comparison.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topology
from repro.core.baselines import make_scheduler
from repro.core.runner import DecentralizedTrainer
from repro.core.straggler import StragglerModel
from repro.data import ClassificationData

N_WORKERS = 16
data = ClassificationData(n_workers=N_WORKERS, d=64, partition="label_shard",
                          classes_per_worker=5, samples_per_worker=256)
graph = topology.erdos_renyi(N_WORKERS, 0.3, seed=1)         # the paper's
stragglers = StragglerModel(n=N_WORKERS, straggler_prob=0.1,  # experimental
                            slowdown=10.0)                    # protocol


def loss_fn(params, batch):
    h = jax.nn.relu(batch["x"] @ params["w1"])
    logits = h @ params["w2"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], axis=1))


def eval_fn(params, batch):
    h = jax.nn.relu(batch["x"] @ params["w1"])
    acc = jnp.mean((jnp.argmax(h @ params["w2"], -1) == batch["y"]).astype(jnp.float32))
    return loss_fn(params, batch), acc


def init_fn(key):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (64, 256)) * 0.1,
            "w2": jax.random.normal(k2, (256, 10)) * 0.1}


print(f"{'algorithm':12s} {'acc@t=50':>9s} {'loss':>8s} {'iters':>6s} {'comm-GiB':>9s}")
for alg in ("dsgd_aau", "dsgd_sync", "ad_psgd", "prague", "agp"):
    trainer = DecentralizedTrainer(
        make_scheduler(alg, graph, stragglers), loss_fn, init_fn,
        lambda w, s: data.batch(w, s, 32), data.eval_batch(1024),
        eval_fn=eval_fn, eta0=0.2)
    res = trainer.run(max_time=50.0, eval_every=10**6)
    print(f"{alg:12s} {res.final_metric:9.4f} {res.final_loss:8.4f} "
          f"{res.total_events:6d} {res.comm_bytes()/2**30:9.3f}")
