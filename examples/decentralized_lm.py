"""End-to-end driver: decentralized training of an assigned-architecture LM.

Trains a qwen3-family decoder with DSGD-AAU over N workers on non-iid
synthetic token streams.  ``--preset 100m`` builds a ~100M-parameter model
(12 layers, d_model 768) and runs a few hundred steps — the deliverable-(b)
configuration; the default preset is laptop-sized so the example finishes in
about a minute.

  PYTHONPATH=src python examples/decentralized_lm.py                 # tiny
  PYTHONPATH=src python examples/decentralized_lm.py --preset 100m --events 300
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.core import topology
from repro.core.baselines import make_scheduler
from repro.core.runner import DecentralizedTrainer
from repro.core.straggler import StragglerModel
from repro.data import TokenStream, TokenStreamConfig
from repro.models import init_model, lm_loss, param_count

PRESETS = {
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                 d_ff=256, vocab_size=512),
    "20m": dict(n_layers=6, d_model=384, n_heads=6, n_kv_heads=2,
                d_ff=1152, vocab_size=8192),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=2304, vocab_size=16384),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--events", type=int, default=60)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--algorithm", default="dsgd_aau")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("qwen3-8b"), name=f"qwen3-{args.preset}",
        param_dtype="float32", compute_dtype="float32", **PRESETS[args.preset])
    print(f"model: {cfg.name}  params={param_count(cfg)/1e6:.1f}M  "
          f"workers={args.workers}  alg={args.algorithm}")

    stream = TokenStream(TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch * args.workers, n_workers=args.workers))
    g = topology.erdos_renyi(args.workers, 0.4, seed=1)
    sm = StragglerModel(n=args.workers, straggler_prob=0.1, slowdown=10.0)
    trainer = DecentralizedTrainer(
        make_scheduler(args.algorithm, g, sm),
        lambda p, b: lm_loss(p, cfg, b),
        lambda k: init_model(k, cfg),
        lambda w, s: stream.worker_batch(w, s),
        stream.worker_batch(0, 10**9),
        eta0=0.3, eta_decay=0.999)

    t0 = time.time()
    res = trainer.run(max_events=args.events, eval_every=max(args.events // 6, 1))
    for h in res.history:
        print(f"  iter {h.k:5d}  vclock {h.time:8.1f}  loss {h.loss:.4f}  "
              f"active {h.n_active_mean:.1f}")
    print(f"done: {res.total_events} events in {time.time()-t0:.1f}s wall, "
          f"final loss {res.final_loss:.4f}, comm {res.comm_bytes()/2**20:.1f} MiB")


if __name__ == "__main__":
    main()
