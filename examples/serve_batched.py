"""End-to-end serving driver: batched requests against an assigned arch.

Spins up the BatchedServer with a reduced rwkv6 (O(1) decode state — the
long-context family), submits a wave of mixed-length prompts, decodes
greedily, and reports per-request outputs + throughput.

  PYTHONPATH=src python examples/serve_batched.py [--arch qwen3-8b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import BatchedServer, Request
from repro.models import init_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    server = BatchedServer(cfg, params, batch_slots=args.slots, cache_len=256)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(4, 24))).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    server.run(reqs)
    dt = time.time() - t0
    for r in reqs:
        print(f"req {r.rid}: prompt_len={len(r.prompt):2d} -> "
              f"{' '.join(map(str, r.out[:10]))} ...")
    tok = sum(len(r.out) for r in reqs)
    print(f"\n{args.arch} ({cfg.name}): {len(reqs)} requests, {tok} tokens, "
          f"{dt:.2f}s ({tok/dt:.1f} tok/s greedy, slots={args.slots})")


if __name__ == "__main__":
    main()
