"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run JSONs,
and the paper-figure tables (speedup-vs-N, dtype policy, convergence CSV)
from BENCH_paper_figures.json:

  python experiments/render_tables.py paper_figures [path/to/artifact.json]
"""
import json
import math
import sys


def load(path):
    return {(r["arch"], r["shape"]): r for r in json.load(open(path))
            if "error" not in r}


def _f(v):
    """Artifact floats serialize NaN/Inf as strings (allow_nan=False);
    float() parses both plain numbers and those strings."""
    return float(v)


def speedup_table(artifact):
    """Markdown pivot: rows scenario × N, one column per algorithm."""
    rows = artifact["speedup_vs_n"]
    algs = sorted({r["algorithm"] for r in rows})
    cells = {}
    for r in rows:
        m, s = _f(r["speedup_mean"]), _f(r["speedup_std"])
        cells[(r["scenario"], r["n"], r["algorithm"])] = (
            "unreached" if math.isnan(m) else f"{m:.2f} ± {s:.2f}")
    out = ["| scenario | N | " + " | ".join(algs) + " |",
           "|---|---:|" + "---:|" * len(algs)]
    for scen, n in sorted({(r["scenario"], r["n"]) for r in rows}):
        vals = [cells.get((scen, n, a), "—") for a in algs]
        out.append(f"| {scen} | {n} | " + " | ".join(vals) + " |")
    return "\n".join(out)


def dtype_table(artifact):
    rows = artifact.get("dtype_policy", [])
    if not rows:
        return "(no dtype rows recorded)"
    out = ["| dtype | algorithm | N | events | final loss | events/s |",
           "|---|---|---:|---:|---:|---:|"]
    for r in rows:
        out.append(f"| {r['dtype']} | {r['algorithm']} | {r['n']} "
                   f"| {r['events']} | {_f(r['final_loss']):.4f} "
                   f"| {_f(r['events_per_s']):.1f} |")
    return "\n".join(out)


def telemetry_report(artifact):
    """Per-scenario utilization table + log2-binned staleness histogram.

    Rendered from the artifact's ``telemetry`` section (present when the
    sweep ran with ``--telemetry``): one utilization row per (scenario, N,
    algorithm) — mean/min worker utilization (busy / (busy + idle) on the
    virtual clock), staleness stats, the DSGD-AAU 2N−4 bound check — then
    one histogram block per scenario (counts of gradient firings whose
    staleness s falls in [2^b − 1, 2^{b+1} − 1)).
    """
    rows = artifact.get("telemetry", [])
    if not rows:
        return "(no telemetry recorded — run with --telemetry)"
    out = ["| scenario | N | algorithm | util mean | util min | "
           "stale mean | stale max | bound | comm copies |",
           "|---|---:|---|---:|---:|---:|---:|---|---:|"]
    for r in sorted(rows, key=lambda r: (r["scenario"], r["n"],
                                         r["algorithm"])):
        b = r.get("staleness_bound")
        bound = "—" if b is None else (
            f"{b['observed_max']}/{b['bound']} "
            + ("ok" if b["ok"] else "**VIOLATED**"))
        out.append(
            f"| {r['scenario']} | {r['n']} | {r['algorithm']} "
            f"| {_f(r['utilization_mean']):.3f} "
            f"| {_f(r['utilization_min']):.3f} "
            f"| {_f(r['stale_mean']):.2f} | {r['stale_max']} "
            f"| {bound} | {r['comm_copies']} |")
    out.append("")
    out.append("#### Staleness histograms (gradient firings per log2 bin)")
    out.append("")
    for scen in sorted({r["scenario"] for r in rows}):
        scen_rows = [r for r in rows if r["scenario"] == scen]
        nbins = max((len(r["stale_hist"]) for r in scen_rows), default=0)
        # drop all-zero tail bins shared by every algorithm in the scenario
        last = max((max((i for i, v in enumerate(r["stale_hist"]) if v),
                        default=0) for r in scen_rows), default=0)
        hdr = [f"[{2**b - 1},{2**(b + 1) - 2}]" if b < nbins - 1 else "tail"
               for b in range(last + 1)]
        out.append(f"**{scen}**")
        out.append("")
        out.append("| N | algorithm | s∈" + " | s∈".join(hdr) + " |")
        out.append("|---:|---|" + "---:|" * (last + 1))
        for r in sorted(scen_rows, key=lambda r: (r["n"], r["algorithm"])):
            vals = [str(v) for v in r["stale_hist"][:last + 1]]
            out.append(f"| {r['n']} | {r['algorithm']} | "
                       + " | ".join(vals) + " |")
        out.append("")
        occ = [(r, r["bucket_occupancy"]) for r in scen_rows
               if r.get("bucket_occupancy")]
        for r, rungs in occ:
            per = "; ".join(f"A={o['A']}: {o['events']} ev, "
                            f"{100 * _f(o['lane_fill']):.1f}% lanes"
                            for o in rungs)
            out.append(f"- bucket occupancy {r['algorithm']}/N{r['n']}: "
                       f"{per}")
        if occ:
            out.append("")
    return "\n".join(out)


def straggler_tax_table(artifact):
    """Per-algorithm wait-blame / straggler-tax table.

    Rendered from the artifact's ``trace`` section (present when the sweep
    ran with ``--trace``): one row per (scenario, N, algorithm) with the
    straggler tax (wait / (busy + wait) on the virtual clock), the
    blame/residual split from the critical-path attribution
    (repro/obs/critical_path — blame is wait charged to a causing worker,
    residual is lock/serialization wait with no worker to blame), blame
    concentration (largest single worker's share of total blame) and the
    critical path's wait fraction.
    """
    rows = artifact.get("trace", [])
    if not rows:
        return "(no trace recorded — run with --trace)"
    out = ["| scenario | N | algorithm | straggler tax | blame t | "
           "residual t | blame conc. | top blamed | cp wait frac |",
           "|---|---:|---|---:|---:|---:|---:|---|---:|"]
    for r in sorted(rows, key=lambda r: (r["scenario"], r["n"],
                                         r["algorithm"])):
        top = "; ".join(f"w{b['worker']}:{100 * _f(b['share']):.0f}%"
                        for b in r.get("blame_top", [])[:3]) or "—"
        out.append(
            f"| {r['scenario']} | {r['n']} | {r['algorithm']} "
            f"| {_f(r['straggler_tax_mean']):.3f} "
            f"| {_f(r['blame_total_mean']):.2f} "
            f"| {_f(r['residual_wait_mean']):.2f} "
            f"| {_f(r['blame_concentration']):.2f} | {top} "
            f"| {_f(r['cp_wait_frac_mean']):.3f} |")
    return "\n".join(out)


def trace_tables(path="BENCH_trace.json"):
    artifact = json.load(open(path))
    print("### Straggler tax (wait-blame attribution, mean over seeds)\n")
    print(straggler_tax_table(artifact))


def convergence_csv(artifact):
    """Flat CSV of the seed-averaged convergence curves (plotting input)."""
    out = ["scenario,n,algorithm,k,time_mean,loss_mean,loss_std,metric_mean"]
    for c in artifact["convergence"]:
        for p in c["points"]:
            out.append(
                f"{c['scenario']},{c['n']},{c['algorithm']},{p['k']},"
                f"{_f(p['time_mean'])},{_f(p['loss_mean'])},"
                f"{_f(p['loss_std'])},{_f(p['metric_mean'])}")
    return "\n".join(out)


def paper_figures(path="BENCH_paper_figures.json"):
    artifact = json.load(open(path))
    print("### Speedup vs N (× over synchronous DSGD, mean ± std over seeds)\n")
    print(speedup_table(artifact))
    print("\n### dtype policy (fp32 vs bf16 worker state)\n")
    print(dtype_table(artifact))
    print("\n### Telemetry (per-worker utilization and staleness)\n")
    print(telemetry_report(artifact))
    if artifact.get("trace"):  # tolerate artifacts recorded without --trace
        print("\n### Straggler tax (wait-blame attribution)\n")
        print(straggler_tax_table(artifact))
    print("\n### Convergence curves (CSV)\n")
    print(convergence_csv(artifact))


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def roofline_table(single, baseline=None):
    hdr = ("| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
           "dominant | useful | peak GiB/dev |")
    sep = "|---|---|---:|---:|---:|---|---:|---:|"
    out = [hdr, sep]
    for (a, s), r in sorted(single.items()):
        out.append(
            f"| {a} | {s} | {r['compute_s']*1e3:,.1f} | {r['memory_s']*1e3:,.1f} "
            f"| {r['collective_s']*1e3:,.1f} | {r['dominant']} "
            f"| {r['useful_flops_ratio']:.2f} | {fmt_bytes(r['peak_bytes_per_device'])} |")
    return "\n".join(out)


def dryrun_table(single, multi):
    hdr = ("| arch | shape | mesh 16×16 peak GiB | coll GiB/dev | "
           "mesh 2×16×16 peak GiB | coll GiB/dev |")
    sep = "|---|---|---:|---:|---:|---:|"
    out = [hdr, sep]
    for (a, s) in sorted(single):
        r1, r2 = single[(a, s)], multi.get((a, s))
        c1 = r1["coll_bytes"] / 2**30
        c2 = r2["coll_bytes"] / 2**30 if r2 else float("nan")
        out.append(
            f"| {a} | {s} | {fmt_bytes(r1['peak_bytes_per_device'])} | {c1:.2f} "
            f"| {fmt_bytes(r2['peak_bytes_per_device']) if r2 else '—'} | {c2:.2f} |")
    return "\n".join(out)


def before_after(baseline, opt, pairs):
    hdr = ("| pair | term | baseline (ms) | optimized (ms) | Δ |")
    sep = "|---|---|---:|---:|---:|"
    out = [hdr, sep]
    for a, s in pairs:
        b, o = baseline.get((a, s)), opt.get((a, s))
        if not (b and o):
            continue
        for term in ("compute_s", "memory_s", "collective_s"):
            bv, ov = b[term] * 1e3, o[term] * 1e3
            d = (1 - ov / bv) * 100 if bv else 0
            out.append(f"| {a}×{s} | {term[:-2]} | {bv:,.1f} | {ov:,.1f} "
                       f"| {d:+.0f}% |")
        out.append(f"| {a}×{s} | peak GiB | "
                   f"{b['peak_bytes_per_device']/2**30:.1f} | "
                   f"{o['peak_bytes_per_device']/2**30:.1f} | "
                   f"{(1-o['peak_bytes_per_device']/b['peak_bytes_per_device'])*100:+.0f}% |")
    return "\n".join(out)


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "paper_figures":
        paper_figures(*sys.argv[2:3])
        sys.exit(0)
    if len(sys.argv) > 1 and sys.argv[1] == "trace":
        trace_tables(*sys.argv[2:3])
        sys.exit(0)
    single = load("experiments/dryrun_single.json")
    multi = load("experiments/dryrun_multi.json")
    base = load("experiments/baseline_single.json")
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("roofline", "all"):
        print("### Roofline (single-pod 16×16)\n")
        print(roofline_table(single))
    if which in ("dryrun", "all"):
        print("\n### Dry-run (both meshes)\n")
        print(dryrun_table(single, multi))
    if which in ("perf", "all"):
        print("\n### Before/after (hillclimbed pairs + spillover)\n")
        print(before_after(base, single, [
            ("deepseek-67b", "prefill_32k"),
            ("minicpm-2b", "train_4k"),
            ("arctic-480b", "prefill_32k"),
            ("deepseek-67b", "train_4k"),
            ("arctic-480b", "train_4k"),
            ("qwen3-8b", "train_4k"),
        ]))
