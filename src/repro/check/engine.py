"""The lint engine: rule protocol, pragma handling, config, file walking.

A :class:`Rule` owns one invariant.  The engine parses each file once,
hands the module AST to every enabled rule, collects :class:`Finding`
objects, and drops any finding whose line carries a
``# repro: disable=<rule>`` pragma (or the blanket ``# repro: disable``).
Pragmas attach to the physical line of the flagged node, so they read
exactly like ``# noqa`` / ``# type: ignore`` comments.

Per-rule configuration rides in :class:`CheckConfig`: path excludes (the
seeded-violation fixtures under ``tests/fixtures/check`` must not fail the
repo-wide run), per-rule scope restrictions, and the donation/dispatch
tables the repo-specific rules consume.  Everything has working defaults
for this repository; tests construct bespoke configs.
"""
from __future__ import annotations

import ast
import dataclasses
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Mapping, Sequence, Set, Tuple

_PRAGMA_RE = re.compile(r"#\s*repro:\s*disable(?:=(?P<rules>[\w,\-]+))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"

    def to_json(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class CheckConfig:
    """Engine + rule configuration (defaults match this repository).

    ``donating_callees`` maps a *callee suffix* (the trailing dotted-name
    component of the call, e.g. ``_sparse`` for ``self._sparse(...)``) to
    the tuple of donated positional-argument indices.  ``donating_builders``
    names the factory functions whose results are donate-jitted blocks and
    therefore require the documented alias-break
    (``jax.tree.map(jnp.array, ...)``) in any function that both builds and
    feeds them aliased state.  ``host_sync_scopes`` are regexes selecting
    the function names whose bodies count as block-dispatch loops for the
    host-sync rule.  ``rng_surface_attr`` is the class attribute a scheduler
    uses to declare its sampler surface for the rng-order rule.
    """

    enabled_rules: Tuple[str, ...] = ()  # empty = all registered rules
    exclude: Tuple[str, ...] = ("tests/fixtures/",)
    donating_callees: Mapping[str, Tuple[int, ...]] = dataclasses.field(
        default_factory=lambda: {
            # runner-held compiled blocks: build_sparse_event_scan donates
            # the (W, S, y, ptr) carry (positions 0-3; the telemetry
            # variant also donates M at 4 but position 4 is pools in the
            # plain variant, so only the common prefix is tracked here),
            # build_fused_pair_scan donates (W, S, y, ptr, times,
            # lock_free, comm) = (0,1,2,3,5,6,7).
            "_sparse": (0, 1, 2, 3),
            "_fused": (0, 1, 2, 3, 5, 6, 7),
            "sparse_scatter_rows": (0,),
        }
    )
    donating_builders: Tuple[str, ...] = (
        "build_sparse_event_scan",
        "build_fused_pair_scan",
    )
    host_sync_scopes: Tuple[str, ...] = (
        r"^_dispatch_\w+$",
        r"^_run_scan$",
        r"^_run_sparse_stream$",
        r"^_run_fused$",
        r"^_record_eval$",
        r"^_fused_record$",
        r"^_warn_pool_wrap$",
        r"^warmup$",
        # virtual-time tracing (repro.obs.trace): the drain is the one
        # sanctioned host fetch per traced run; the recorders run on the
        # hot dispatch path and must stay sync-free
        r"^_trace_summary$",
        r"^drain_fused_payload$",
        r"^record_(event|events|sparse|chunk|fused)$",
    )
    rng_surface_attr: str = "rng_methods"
    kernel_gate_flag: str = "use_kernel"
    kernel_gated_calls: Tuple[str, ...] = ("sparse_scatter_rows",)

    def rule_enabled(self, rule_id: str) -> bool:
        return not self.enabled_rules or rule_id in self.enabled_rules

    def path_excluded(self, path: str) -> bool:
        norm = path.replace("\\", "/")
        return any(part in norm for part in self.exclude)


class Rule:
    """Base class for one lint rule family.

    Subclasses set ``rule_id`` (+ optionally ``aliases`` for findings they
    emit under secondary ids — pragma suppression honours the finding's own
    id) and implement :meth:`check`, returning findings for one module.
    """

    rule_id: str = ""
    aliases: Tuple[str, ...] = ()

    def check(
        self, tree: ast.Module, path: str, config: CheckConfig
    ) -> List[Finding]:
        raise NotImplementedError

    def ids(self) -> Tuple[str, ...]:
        return (self.rule_id, *self.aliases)


def _disabled_rules_by_line(source: str) -> Dict[int, Set[str]]:
    """Map line number -> set of rule ids disabled there ('*' = all).

    Uses the token stream rather than a per-line regex so pragmas inside
    string literals don't suppress anything.
    """
    disabled: Dict[int, Set[str]] = {}
    try:
        tokens = tokenize.generate_tokens(iter(source.splitlines(True)).__next__)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _PRAGMA_RE.search(tok.string)
            if not m:
                continue
            rules = m.group("rules")
            ids = {"*"} if rules is None else {r.strip() for r in rules.split(",")}
            disabled.setdefault(tok.start[0], set()).update(ids)
    except tokenize.TokenError:
        pass
    return disabled


def check_source(
    source: str,
    path: str,
    config: CheckConfig | None = None,
    rules: Sequence[Rule] | None = None,
) -> List[Finding]:
    """Lint one file's source text; returns pragma-filtered findings."""
    from repro.check.rules import default_rules

    cfg = config if config is not None else CheckConfig()
    active = [
        r
        for r in (rules if rules is not None else default_rules())
        if cfg.rule_enabled(r.rule_id)
    ]
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [
            Finding(
                rule="parse-error",
                path=path,
                line=exc.lineno or 1,
                col=exc.offset or 0,
                message=f"could not parse: {exc.msg}",
            )
        ]
    disabled = _disabled_rules_by_line(source)
    findings: List[Finding] = []
    for rule in active:
        findings.extend(rule.check(tree, path, cfg))
    kept = []
    for f in findings:
        at_line = disabled.get(f.line, set())
        if "*" in at_line or f.rule in at_line:
            continue
        kept.append(f)
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return kept


def iter_python_files(paths: Iterable[str], config: CheckConfig) -> Iterator[Path]:
    for entry in paths:
        p = Path(entry)
        if p.is_file() and p.suffix == ".py":
            if not config.path_excluded(str(p)):
                yield p
        elif p.is_dir():
            for sub in sorted(p.rglob("*.py")):
                if "__pycache__" in sub.parts:
                    continue
                if config.path_excluded(str(sub)):
                    continue
                yield sub


def check_paths(
    paths: Sequence[str],
    config: CheckConfig | None = None,
    rules: Sequence[Rule] | None = None,
) -> List[Finding]:
    """Lint every ``.py`` file under ``paths`` (files or directories)."""
    cfg = config if config is not None else CheckConfig()
    findings: List[Finding] = []
    for file in iter_python_files(paths, cfg):
        try:
            source = file.read_text(encoding="utf-8")
        except (OSError, UnicodeDecodeError) as exc:
            findings.append(
                Finding(
                    rule="read-error",
                    path=str(file),
                    line=1,
                    col=0,
                    message=str(exc),
                )
            )
            continue
        findings.extend(check_source(source, str(file), cfg, rules))
    return findings


# --- shared AST helpers used by several rules -------------------------------


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` -> 'a.b.c' for Name/Attribute chains, else None."""
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return None


def call_suffix(call: ast.Call) -> str | None:
    """The final dotted component of a call's callee (``self._sparse`` ->
    '_sparse'), or None for non-name callees."""
    name = dotted_name(call.func)
    if name is None:
        return None
    return name.rsplit(".", 1)[-1]


def walk_functions(
    tree: ast.Module,
) -> Iterator[Tuple[ast.FunctionDef | ast.AsyncFunctionDef, List[ast.AST]]]:
    """Yield (function node, ancestor stack) for every function in the module."""

    def _walk(node: ast.AST, stack: List[ast.AST]) -> Iterator[
        Tuple[ast.FunctionDef | ast.AsyncFunctionDef, List[ast.AST]]
    ]:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield child, stack
                yield from _walk(child, stack + [child])
            elif isinstance(child, ast.ClassDef):
                yield from _walk(child, stack + [child])
            else:
                yield from _walk(child, stack)

    yield from _walk(tree, [])
