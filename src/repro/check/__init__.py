"""repro.check — invariant lints + runtime sanitizers for the repro codebase.

The static half is a small AST lint engine (:mod:`repro.check.engine`) with
five repo-specific rule families (:mod:`repro.check.rules`) protecting the
contracts that keep per_event / scan / sparse_scan / bucketed bit-exact:

- ``use-after-donate`` / ``missing-alias-break`` — donated scan carries
- ``pallas-alias`` / ``kernel-gate`` — Pallas ``input_output_aliases``
- ``host-sync`` — implicit device→host transfers in block dispatch
- ``rng-order`` / ``global-rng`` — scheduler sampler-surface contract
- ``jit-in-loop`` / ``unhashable-static`` — recompile churn

Run it as ``python -m repro.check src tests benchmarks``.

The runtime half (:mod:`repro.check.runtime`) stacks ``jax.checking_leaks``
and a device→host transfer guard around compiled dispatch and counts
compiles per bucket rung — enabled in the trainer via ``REPRO_SANITIZE=1``
or ``DecentralizedTrainer(sanitize=True)``.
"""
from __future__ import annotations

from repro.check.engine import (
    CheckConfig,
    Finding,
    Rule,
    check_paths,
    check_source,
)

__all__ = [
    "CheckConfig",
    "Finding",
    "Rule",
    "check_paths",
    "check_source",
]
