"""host-sync: no implicit device→host transfers in block dispatch.

The block-dispatch loops are the hot host path: one compiled call per
packed block, everything else stays on device (PR 2/3/6/7).  An implicit
transfer — ``float()``, ``int()``, ``bool()``, ``.item()``,
``np.asarray``/``np.array`` applied to a jax value — blocks on the device
inside the loop, the ~100 µs/event thunk-overhead class the ROADMAP pins
as the end-to-end ceiling.  The sanctioned form is one *explicit*
``jax.device_get(...)`` per block (batched, self-documenting, and legal
under the runtime sanitizer's device→host transfer guard); everything
downstream of it is host data and passes this rule.

The rule only looks inside the dispatch-loop scopes configured in
``CheckConfig.host_sync_scopes`` (function-name regexes): eval-time or
drain-time syncs outside the loops are deliberate and cheap.
"""
from __future__ import annotations

import ast
import re
from typing import List, Set

from repro.check.engine import (
    CheckConfig,
    Finding,
    Rule,
    dotted_name,
    walk_functions,
)

_CONVERTERS = {"float", "int", "bool", "complex"}
_NP_CONVERTERS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}
# Explicit-fetch escapes: values produced by these are host data.
_SANCTIONED = {"jax.device_get", "jax.block_until_ready"}


def _is_jax_derived(node: ast.AST, derived: Set[str]) -> bool:
    """Conservative taint: does this expression hold a jax array?"""
    if isinstance(node, ast.Call):
        name = dotted_name(node.func)
        if name is not None:
            if name in _SANCTIONED:
                return False
            root = name.split(".", 1)[0]
            if root in ("jnp", "jax", "lax"):
                return True
            if name.rsplit(".", 1)[-1] in ("device_get",):
                return False
        # np.max(jax_value) etc. stays device-backed only conceptually;
        # numpy ufuncs on jax arrays sync — propagate through the args.
        return any(_is_jax_derived(a, derived) for a in node.args)
    if isinstance(node, ast.Name):
        return node.id in derived
    if isinstance(node, ast.Attribute):
        name = dotted_name(node)
        return name in derived if name is not None else False
    if isinstance(node, ast.BinOp):
        return _is_jax_derived(node.left, derived) or _is_jax_derived(
            node.right, derived
        )
    if isinstance(node, ast.UnaryOp):
        return _is_jax_derived(node.operand, derived)
    if isinstance(node, ast.Subscript):
        return _is_jax_derived(node.value, derived)
    if isinstance(node, (ast.Tuple, ast.List)):
        return any(_is_jax_derived(e, derived) for e in node.elts)
    if isinstance(node, ast.IfExp):
        return _is_jax_derived(node.body, derived) or _is_jax_derived(
            node.orelse, derived
        )
    return False


class HostSyncRule(Rule):
    rule_id = "host-sync"

    def check(
        self, tree: ast.Module, path: str, config: CheckConfig
    ) -> List[Finding]:
        scopes = [re.compile(p) for p in config.host_sync_scopes]
        findings: List[Finding] = []
        for fn, _stack in walk_functions(tree):
            if not any(p.match(fn.name) for p in scopes):
                continue
            findings.extend(self._check_scope(fn, path))
        return findings

    def _check_scope(
        self, fn: ast.FunctionDef | ast.AsyncFunctionDef, path: str
    ) -> List[Finding]:
        # one linear pass in source order so taint propagates through
        # local assignments (``x = jnp.max(...); float(x)``)
        derived: Set[str] = set()
        findings: List[Finding] = []
        nodes = sorted(
            (n for n in ast.walk(fn) if hasattr(n, "lineno")),
            key=lambda n: (n.lineno, n.col_offset),
        )
        for node in nodes:
            if isinstance(node, ast.Assign):
                if _is_jax_derived(node.value, derived):
                    for target in node.targets:
                        name = dotted_name(target)
                        if name is not None:
                            derived.add(name)
                else:
                    for target in node.targets:
                        name = dotted_name(target)
                        if name is not None:
                            derived.discard(name)
            elif isinstance(node, ast.Call):
                finding = self._check_call(node, derived, path)
                if finding is not None:
                    findings.append(finding)
        return findings

    def _check_call(
        self, node: ast.Call, derived: Set[str], path: str
    ) -> Finding | None:
        name = dotted_name(node.func)
        # x.item() — an attribute call on a jax-derived receiver
        if (
            isinstance(node.func, ast.Attribute)
            and node.func.attr == "item"
            and _is_jax_derived(node.func.value, derived)
        ):
            return self._finding(node, path, ".item()")
        if name is None:
            return None
        is_converter = name in _CONVERTERS or name in _NP_CONVERTERS
        if not is_converter or not node.args:
            return None
        if _is_jax_derived(node.args[0], derived):
            return self._finding(node, path, f"{name}()")
        return None

    def _finding(self, node: ast.Call, path: str, what: str) -> Finding:
        return Finding(
            rule=self.rule_id,
            path=path,
            line=node.lineno,
            col=node.col_offset,
            message=(
                f"implicit device→host sync via {what} on a jax value inside "
                "a block-dispatch scope; fetch once with an explicit "
                "`jax.device_get(...)` instead (~100 µs/event class, and the "
                "runtime transfer guard rejects it)"
            ),
        )
