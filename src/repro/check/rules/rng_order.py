"""rng-order: every scheduler RNG draw routes through a declared surface.

The bit-exact event streams pinned since PR 2 are a *draw-order* contract:
per_event ≡ scan ≡ sparse_scan ≡ bucketed holds because each scheduler
consumes its ``np.random.default_rng(seed)`` stream in one canonical order.
A draw added anywhere else — a debug sample, a new code path calling
``self._rng.random()`` directly — silently forks the stream and every
equivalence test downstream starts comparing different trajectories.

The contract is made machine-checkable by declaration: any class that owns
a generator (assigns ``self._rng``/``self.rng = np.random.default_rng(...)``)
must carry a class attribute (default name ``rng_methods``) listing the
methods allowed to draw from it.  This rule flags

- ``rng-order``: an owning class with no surface declaration, or a
  ``self._rng.<draw>()`` / ``self.rng.<draw>()`` call in a method outside
  the declared surface (``__init__`` is implicitly allowed: construction
  draws are pinned by the constructor seed);
- ``global-rng``: any ``np.random.<fn>()`` draw through the legacy global
  generator — unseedable per-scheduler, so never part of a pinned stream
  (``np.random.default_rng``/``Generator``/``SeedSequence`` construction
  is the sanctioned use of the namespace).
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set, Tuple

from repro.check.engine import (
    CheckConfig,
    Finding,
    Rule,
    dotted_name,
    walk_functions,
)

_RNG_ATTRS = ("_rng", "rng")
_GLOBAL_OK = {"default_rng", "Generator", "SeedSequence", "PCG64", "Philox"}


def _owns_rng(cls: ast.ClassDef) -> bool:
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            callee = dotted_name(node.value.func)
            if callee is None or not callee.endswith("default_rng"):
                continue
            for target in node.targets:
                name = dotted_name(target)
                if name in tuple(f"self.{a}" for a in _RNG_ATTRS):
                    return True
    return False


def _declared_surface(
    cls: ast.ClassDef, attr: str
) -> Optional[Tuple[int, Set[str]]]:
    """(decl line, method names) of the class-level surface attr, if any."""
    for stmt in cls.body:
        target = None
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            target, value = stmt.targets[0], stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            target, value = stmt.target, stmt.value
        else:
            continue
        if isinstance(target, ast.Name) and target.id == attr:
            try:
                val = ast.literal_eval(value)
            except (ValueError, SyntaxError):
                return stmt.lineno, set()
            if isinstance(val, (tuple, list, set, frozenset)):
                return stmt.lineno, {str(v) for v in val}
    return None


class RngOrderRule(Rule):
    rule_id = "rng-order"
    aliases = ("global-rng",)

    def check(
        self, tree: ast.Module, path: str, config: CheckConfig
    ) -> List[Finding]:
        findings: List[Finding] = []
        findings.extend(self._check_global_draws(tree, path))
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                findings.extend(self._check_class(node, path, config))
        return findings

    def _check_global_draws(self, tree: ast.Module, path: str) -> List[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name is None:
                continue
            parts = name.split(".")
            if (
                len(parts) == 3
                and parts[0] in ("np", "numpy")
                and parts[1] == "random"
                and parts[2] not in _GLOBAL_OK
            ):
                findings.append(
                    Finding(
                        rule="global-rng",
                        path=path,
                        line=node.lineno,
                        col=node.col_offset,
                        message=(
                            f"`{name}` draws from numpy's global generator; "
                            "streams must come from a per-scheduler "
                            "`np.random.default_rng(seed)` to stay pinned"
                        ),
                    )
                )
        return findings

    def _check_class(
        self, cls: ast.ClassDef, path: str, config: CheckConfig
    ) -> List[Finding]:
        surface = _declared_surface(cls, config.rng_surface_attr)
        owns = _owns_rng(cls)
        if not owns and surface is None:
            return []
        findings: List[Finding] = []
        if owns and surface is None:
            findings.append(
                Finding(
                    rule=self.rule_id,
                    path=path,
                    line=cls.lineno,
                    col=cls.col_offset,
                    message=(
                        f"class `{cls.name}` owns an RNG (assigns self._rng) "
                        f"but declares no sampler surface; add "
                        f"`{config.rng_surface_attr} = (<draw methods>,)` so "
                        "the draw-order contract is machine-checked"
                    ),
                )
            )
            return findings
        assert surface is not None
        _line, allowed = surface
        for method in cls.body:
            if not isinstance(method, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if method.name == "__init__" or method.name in allowed:
                continue
            for node in ast.walk(method):
                if not isinstance(node, ast.Call):
                    continue
                name = dotted_name(node.func)
                if name is None:
                    continue
                parts = name.split(".")
                if (
                    len(parts) == 3
                    and parts[0] == "self"
                    and parts[1] in _RNG_ATTRS
                ):
                    findings.append(
                        Finding(
                            rule=self.rule_id,
                            path=path,
                            line=node.lineno,
                            col=node.col_offset,
                            message=(
                                f"raw `self.{parts[1]}.{parts[2]}()` draw in "
                                f"`{cls.name}.{method.name}`, which is not in "
                                f"the declared sampler surface "
                                f"{sorted(allowed)}; route it through a "
                                "declared method or extend the surface "
                                "(draw order is the bit-exact contract)"
                            ),
                        )
                    )
        return findings
