"""Rule registry for repro.check."""
from __future__ import annotations

from typing import List

from repro.check.engine import Rule
from repro.check.rules.aliasing import PallasAliasRule
from repro.check.rules.donation import UseAfterDonateRule
from repro.check.rules.host_sync import HostSyncRule
from repro.check.rules.recompile import RecompileChurnRule
from repro.check.rules.rng_order import RngOrderRule

__all__ = [
    "PallasAliasRule",
    "UseAfterDonateRule",
    "HostSyncRule",
    "RecompileChurnRule",
    "RngOrderRule",
    "default_rules",
]


def default_rules() -> List[Rule]:
    """Fresh instances of every registered rule (rules are stateless, but a
    fresh list keeps callers free to mutate it)."""
    return [
        UseAfterDonateRule(),
        PallasAliasRule(),
        HostSyncRule(),
        RngOrderRule(),
        RecompileChurnRule(),
    ]
