"""recompile churn: jit construction in loops, bad static args.

The sparse path's performance contract (PR 6) is one compiled block
program per bucket rung: shapes are fixed per rung by ``_bucket_cap``, so
the jit cache holds exactly one entry per (A, E) and nothing recompiles in
steady state.  Two anti-patterns silently break that:

- ``jit-in-loop``: constructing a jitted callable (``jax.jit(...)`` or
  ``functools.partial(jax.jit, ...)``) inside a ``for``/``while`` body —
  each iteration builds a fresh callable with an empty cache, so every
  call compiles;
- ``unhashable-static`` / ``loop-varying-static``: feeding a
  ``static_argnums`` position an unhashable value (list/dict/set literal,
  ``np.array``) — a ``TypeError`` at best, a per-call retrace at worst —
  or a loop variable, which compiles once per distinct iteration value.
  Static-jitted callables are discovered locally (same module), like the
  runner's ``jax.jit(fused_metrics_fold, static_argnums=(5,))``.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.check.engine import (
    CheckConfig,
    Finding,
    Rule,
    call_suffix,
    dotted_name,
)

_UNHASHABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
               ast.SetComp, ast.GeneratorExp)
_UNHASHABLE_CALLS = {"array", "asarray", "zeros", "ones", "arange"}


def _is_jit_construction(call: ast.Call) -> bool:
    name = dotted_name(call.func)
    if name in ("jax.jit", "jit"):
        return True
    if name in ("functools.partial", "partial") and call.args:
        inner = dotted_name(call.args[0])
        return inner in ("jax.jit", "jit")
    return False


def _static_argnums(call: ast.Call) -> Tuple[int, ...] | None:
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            try:
                val = ast.literal_eval(kw.value)
            except (ValueError, SyntaxError):
                return None
            if isinstance(val, int):
                return (val,)
            if isinstance(val, (tuple, list)):
                return tuple(int(v) for v in val)
    return None


def _static_jitted_names(tree: ast.Module) -> Dict[str, Tuple[int, ...]]:
    """Locally visible name -> static arg positions of its jit."""
    found: Dict[str, Tuple[int, ...]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            if not _is_jit_construction(node.value):
                continue
            nums = _static_argnums(node.value)
            if nums is None:
                continue
            for target in node.targets:
                name = dotted_name(target)
                if name is not None:
                    found[name.rsplit(".", 1)[-1]] = nums
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call) and _is_jit_construction(dec):
                    nums = _static_argnums(dec)
                    if nums is not None:
                        found[node.name] = nums
    return found


class RecompileChurnRule(Rule):
    rule_id = "jit-in-loop"
    aliases = ("unhashable-static", "loop-varying-static")

    def check(
        self, tree: ast.Module, path: str, config: CheckConfig
    ) -> List[Finding]:
        findings: List[Finding] = []
        static_names = _static_jitted_names(tree)

        def visit(node: ast.AST, loop_depth: int, loop_vars: Set[str]) -> None:
            for child in ast.iter_child_nodes(node):
                depth, lvars = loop_depth, loop_vars
                if isinstance(child, (ast.For, ast.AsyncFor)):
                    names = {
                        n.id
                        for n in ast.walk(child.target)
                        if isinstance(n, ast.Name)
                    }
                    depth, lvars = loop_depth + 1, loop_vars | names
                elif isinstance(child, ast.While):
                    depth = loop_depth + 1
                elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                        ast.Lambda)):
                    # a def inside a loop runs once per call of the outer
                    # fn, not per iteration of an enclosing textual loop
                    depth, lvars = 0, set()
                if isinstance(child, ast.Call):
                    self._check_call(
                        child, path, depth, lvars, static_names, findings
                    )
                visit(child, depth, lvars)

        visit(tree, 0, set())
        return findings

    def _check_call(
        self,
        call: ast.Call,
        path: str,
        loop_depth: int,
        loop_vars: Set[str],
        static_names: Dict[str, Tuple[int, ...]],
        findings: List[Finding],
    ) -> None:
        if _is_jit_construction(call) and loop_depth > 0:
            findings.append(
                Finding(
                    rule="jit-in-loop",
                    path=path,
                    line=call.lineno,
                    col=call.col_offset,
                    message=(
                        "jax.jit constructed inside a loop body: each "
                        "iteration gets a fresh callable with an empty "
                        "compile cache, so every call recompiles — hoist "
                        "the jit out of the loop"
                    ),
                )
            )
            return
        suffix = call_suffix(call)
        if suffix not in static_names:
            return
        for idx in static_names[suffix]:
            if idx >= len(call.args):
                continue
            arg = call.args[idx]
            if isinstance(arg, _UNHASHABLE) or (
                isinstance(arg, ast.Call)
                and (name := dotted_name(arg.func)) is not None
                and name.rsplit(".", 1)[-1] in _UNHASHABLE_CALLS
            ):
                findings.append(
                    Finding(
                        rule="unhashable-static",
                        path=path,
                        line=arg.lineno,
                        col=arg.col_offset,
                        message=(
                            f"static arg {idx} of `{suffix}` is unhashable "
                            "(list/dict/set/ndarray); static_argnums keys "
                            "the compile cache by hash — pass a tuple or "
                            "scalar"
                        ),
                    )
                )
            elif isinstance(arg, ast.Name) and arg.id in loop_vars:
                findings.append(
                    Finding(
                        rule="loop-varying-static",
                        path=path,
                        line=arg.lineno,
                        col=arg.col_offset,
                        message=(
                            f"static arg {idx} of `{suffix}` is the loop "
                            f"variable `{arg.id}`: every distinct value "
                            "compiles a fresh program (recompile churn); "
                            "make it a traced arg or hoist the distinct "
                            "values"
                        ),
                    )
                )
