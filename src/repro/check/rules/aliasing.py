"""pallas-alias: keep ``input_output_aliases`` consistent with the call.

The in-place Pallas scatter (PR 6, ``scatter_rows_pallas``) aliases its
carry operand straight through to the output.  Three things must agree or
the kernel silently corrupts the carry:

- the alias **indices** — operand indices count the scalar-prefetch argument
  (``PrefetchScalarGridSpec(num_scalar_prefetch=k)``), so every alias key
  must point past the prefetch operands and inside the actual operand list
  of the immediate ``pl.pallas_call(...)(...)`` call site, and every alias
  value must name a real output;
- the aliased operand's **shape/dtype** must match ``out_shape`` — XLA
  rejects mismatched aliases at lowering time on TPU but interpret mode
  masks it, so the lint requires ``out_shape``'s dtype to be derived from
  the aliased operand (``X.dtype``) and its shape to be unpacked from the
  same operand (``N, D = X.shape`` or literally ``X.shape``);
- the ``kernel-gate`` finding: the kernel scatter scales with N in
  interpret mode (PR 6's profile verdict), so calls to the in-place scatter
  outside ``kernels/`` must stay behind the ``use_kernel`` TPU flag.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.check.engine import (
    CheckConfig,
    Finding,
    Rule,
    call_suffix,
    dotted_name,
    walk_functions,
)


def _alias_map(call: ast.Call) -> Optional[Dict[int, int]]:
    for kw in call.keywords:
        if kw.arg == "input_output_aliases":
            try:
                val = ast.literal_eval(kw.value)
            except (ValueError, SyntaxError):
                return None
            if isinstance(val, dict):
                return {int(k): int(v) for k, v in val.items()}
    return None


def _num_outputs(call: ast.Call) -> Optional[int]:
    for kw in call.keywords:
        if kw.arg == "out_shape":
            if isinstance(kw.value, (ast.Tuple, ast.List)):
                return len(kw.value.elts)
            return 1
    return None


def _out_shape_struct(call: ast.Call, out_idx: int) -> Optional[ast.Call]:
    """The ``jax.ShapeDtypeStruct(...)`` node for output ``out_idx``."""
    for kw in call.keywords:
        if kw.arg == "out_shape":
            node = kw.value
            if isinstance(node, (ast.Tuple, ast.List)):
                if out_idx < len(node.elts):
                    node = node.elts[out_idx]
                else:
                    return None
            if isinstance(node, ast.Call):
                name = dotted_name(node.func)
                if name is not None and name.endswith("ShapeDtypeStruct"):
                    return node
    return None


def _prefetch_count(fn: ast.AST) -> int:
    """num_scalar_prefetch of any PrefetchScalarGridSpec built in ``fn``."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            name = dotted_name(node.func)
            if name is not None and name.endswith("PrefetchScalarGridSpec"):
                for kw in node.keywords:
                    if kw.arg == "num_scalar_prefetch":
                        try:
                            return int(ast.literal_eval(kw.value))
                        except (ValueError, SyntaxError):
                            return 0
    return 0


def _shape_unpack_sources(fn: ast.AST) -> Dict[str, str]:
    """Map shape-component name -> operand name for ``N, D = X.shape``."""
    sources: Dict[str, str] = {}
    for node in ast.walk(fn):
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        src = dotted_name(node.value)
        if src is None or not src.endswith(".shape"):
            continue
        operand = src[: -len(".shape")]
        target = node.targets[0]
        if isinstance(target, ast.Tuple):
            for elt in target.elts:
                if isinstance(elt, ast.Name):
                    sources[elt.id] = operand
        elif isinstance(target, ast.Name):
            sources[target.id] = operand
    return sources


class PallasAliasRule(Rule):
    rule_id = "pallas-alias"
    aliases = ("kernel-gate",)

    def check(
        self, tree: ast.Module, path: str, config: CheckConfig
    ) -> List[Finding]:
        findings: List[Finding] = []
        for fn, _stack in walk_functions(tree):
            findings.extend(self._check_pallas_calls(fn, path))
        norm = path.replace("\\", "/")
        if "/kernels/" not in norm and not norm.startswith("kernels/"):
            findings.extend(self._check_kernel_gating(tree, path, config))
        return findings

    # -- alias index / shape / dtype validation ---------------------------
    def _check_pallas_calls(self, fn: ast.AST, path: str) -> List[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(fn):
            # the idiomatic immediate call: pl.pallas_call(...)(operands...)
            if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Call)):
                continue
            inner = node.func
            if call_suffix(inner) != "pallas_call":
                continue
            aliases = _alias_map(inner)
            if aliases is None:
                continue
            n_operands = len(node.args)
            n_outputs = _num_outputs(inner)
            prefetch = _prefetch_count(fn)
            shape_sources = _shape_unpack_sources(fn)
            for op_idx, out_idx in aliases.items():
                if op_idx >= n_operands:
                    findings.append(self._finding(
                        inner, path,
                        f"alias operand index {op_idx} out of range: the call "
                        f"site passes {n_operands} operands"))
                    continue
                if op_idx < prefetch:
                    findings.append(self._finding(
                        inner, path,
                        f"alias operand index {op_idx} points at a "
                        f"scalar-prefetch operand (num_scalar_prefetch="
                        f"{prefetch}); prefetch args count in the index but "
                        "cannot be aliased"))
                    continue
                if n_outputs is not None and out_idx >= n_outputs:
                    findings.append(self._finding(
                        inner, path,
                        f"alias output index {out_idx} out of range: "
                        f"out_shape declares {n_outputs} output(s)"))
                    continue
                operand = dotted_name(node.args[op_idx])
                struct = _out_shape_struct(inner, out_idx)
                if operand is None or struct is None:
                    continue
                findings.extend(self._check_struct_agreement(
                    inner, path, operand, struct, shape_sources))
        return findings

    def _check_struct_agreement(
        self,
        call: ast.Call,
        path: str,
        operand: str,
        struct: ast.Call,
        shape_sources: Dict[str, str],
    ) -> List[Finding]:
        findings: List[Finding] = []
        args: List[ast.AST] = list(struct.args)
        for kw in struct.keywords:
            if kw.arg in ("shape", "dtype"):
                args.append(kw.value)
        shape_expr = args[0] if args else None
        dtype_expr = args[1] if len(args) > 1 else None
        # dtype must come off the aliased operand: X.dtype
        dtype_name = dotted_name(dtype_expr) if dtype_expr is not None else None
        if dtype_name != f"{operand}.dtype":
            findings.append(self._finding(
                call, path,
                f"aliased operand `{operand}` must supply out_shape's dtype "
                f"(`{operand}.dtype`); got "
                f"`{dtype_name or 'a non-operand expression'}` — dtype "
                "mismatch through an alias corrupts the donated buffer"))
        # shape: either literally X.shape, or names unpacked from X.shape
        ok = False
        if shape_expr is not None:
            shape_name = dotted_name(shape_expr)
            if shape_name == f"{operand}.shape":
                ok = True
            elif isinstance(shape_expr, (ast.Tuple, ast.List)):
                ok = all(
                    isinstance(elt, ast.Name)
                    and shape_sources.get(elt.id) == operand
                    for elt in shape_expr.elts
                )
        if not ok:
            findings.append(self._finding(
                call, path,
                f"out_shape's shape must be derived from the aliased operand "
                f"(`{operand}.shape` or names unpacked from it); an aliased "
                "output with a different shape is an XLA lowering error the "
                "interpret path masks"))
        return findings

    def _finding(self, node: ast.AST, path: str, message: str) -> Finding:
        return Finding(
            rule=self.rule_id,
            path=path,
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0),
            message=message,
        )

    # -- use_kernel gating outside kernels/ -------------------------------
    def _check_kernel_gating(
        self, tree: ast.Module, path: str, config: CheckConfig
    ) -> List[Finding]:
        findings: List[Finding] = []
        flag = config.kernel_gate_flag

        def guarded(stack: List[ast.AST]) -> bool:
            for anc in stack:
                if isinstance(anc, ast.If):
                    for sub in ast.walk(anc.test):
                        name = dotted_name(sub)
                        if name is not None and name.split(".")[-1] == flag:
                            return True
            return False

        def visit(node: ast.AST, stack: List[ast.AST]) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.Call):
                    suffix = call_suffix(child)
                    if suffix in config.kernel_gated_calls and not guarded(stack):
                        findings.append(Finding(
                            rule="kernel-gate",
                            path=path,
                            line=child.lineno,
                            col=child.col_offset,
                            message=(
                                f"`{suffix}` (in-place Pallas scatter) called "
                                f"without a `{flag}` guard: the kernel path "
                                "is TPU-only; interpret mode scales with N "
                                "(see PR 6 profile)"),
                        ))
                visit(child, stack + [child])

        visit(tree, [])
        return findings
