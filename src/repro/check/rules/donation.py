"""use-after-donate: donated buffers are dead after the dispatch call.

``build_sparse_event_scan`` / ``build_fused_pair_scan`` compile blocks with
``donate_argnums`` over the ``(W, S, y, ptr, ...)`` carry (PR 6): XLA reuses
the donated buffers for the outputs, so any read of the *argument* after the
call observes freed (or silently overwritten) memory.  The sanctioned shape
is the runner's self-clearing assignment::

    self.W, self.S, self.y, self._ptr = self._sparse(self.W, self.S, ...)

which this rule accepts (the assignment rebinds every donated name on the
same statement).  It flags

- ``use-after-donate``: a read of a donated argument name after the donating
  call, with no intervening rebind — including reads on error/warning paths,
  which is exactly where these bugs hide (the happy path rebinds, the
  ``raise``/log path reads the stale name);
- ``missing-alias-break``: a function that builds one of the donating block
  factories without the documented alias-break
  (``jax.tree.map(jnp.array, ...)``) — with ``same_init`` the snapshot S
  *is* W, and donating one buffer through two arguments is an XLA error.

Donating callees come from :class:`~repro.check.engine.CheckConfig`
(``donating_callees``) plus any locally visible ``jax.jit(...,
donate_argnums=...)`` / ``functools.partial(jax.jit, donate_argnums=...)``
definitions discovered in the module.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.check.engine import (
    CheckConfig,
    Finding,
    Rule,
    call_suffix,
    dotted_name,
    walk_functions,
)

# Event priorities at identical source positions: the donating call *reads*
# its arguments legitimately, and the enclosing assignment rebinds them
# after the call returns.
_READ, _DONATE, _ASSIGN = 0, 1, 2


def _donate_argnums_from_call(call: ast.Call) -> Tuple[int, ...] | None:
    """``jax.jit(f, donate_argnums=(0, 1))`` -> (0, 1); None if absent."""
    callee = dotted_name(call.func)
    if callee not in ("jax.jit", "jit", "functools.partial", "partial"):
        return None
    if callee in ("functools.partial", "partial"):
        if not call.args:
            return None
        inner = dotted_name(call.args[0])
        if inner not in ("jax.jit", "jit"):
            return None
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            try:
                val = ast.literal_eval(kw.value)
            except (ValueError, SyntaxError):
                return None
            if isinstance(val, int):
                return (val,)
            if isinstance(val, (tuple, list)):
                return tuple(int(v) for v in val)
    return None


def _local_donating_names(tree: ast.Module) -> Dict[str, Tuple[int, ...]]:
    """Names bound in this module to donate-jitted callables.

    Catches ``fn = jax.jit(step, donate_argnums=(0,))`` assignments and
    ``@functools.partial(jax.jit, donate_argnums=(0,))``-decorated defs.
    """
    found: Dict[str, Tuple[int, ...]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            nums = _donate_argnums_from_call(node.value)
            if nums is not None:
                for target in node.targets:
                    name = dotted_name(target)
                    if name is not None:
                        found[name.rsplit(".", 1)[-1]] = nums
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call):
                    nums = _donate_argnums_from_call(dec)
                    if nums is not None:
                        found[node.name] = nums
    return found


def _assigned_names(target: ast.AST) -> List[str]:
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[str] = []
        for elt in target.elts:
            out.extend(_assigned_names(elt))
        return out
    if isinstance(target, ast.Starred):
        return _assigned_names(target.value)
    name = dotted_name(target)
    return [name] if name is not None else []


class UseAfterDonateRule(Rule):
    rule_id = "use-after-donate"
    aliases = ("missing-alias-break",)

    def check(
        self, tree: ast.Module, path: str, config: CheckConfig
    ) -> List[Finding]:
        donating: Dict[str, Tuple[int, ...]] = dict(config.donating_callees)
        donating.update(_local_donating_names(tree))
        findings: List[Finding] = []
        for fn, _stack in walk_functions(tree):
            findings.extend(self._check_function(fn, path, donating, config))
        return findings

    # -- per-function linear taint walk ----------------------------------
    def _check_function(
        self,
        fn: ast.FunctionDef | ast.AsyncFunctionDef,
        path: str,
        donating: Dict[str, Tuple[int, ...]],
        config: CheckConfig,
    ) -> List[Finding]:
        # (line, col, priority, kind, payload) events in source order
        events: List[Tuple[int, int, int, str, object]] = []
        builder_call: ast.Call | None = None
        has_alias_break = False
        # Skip nested defs: their bodies execute at call time, not at this
        # position in the enclosing function's flow.
        own_nodes: List[ast.AST] = []
        stack: List[ast.AST] = list(ast.iter_child_nodes(fn))
        while stack:
            node = stack.pop()
            own_nodes.append(node)
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                continue
            stack.extend(ast.iter_child_nodes(node))

        for node in own_nodes:
            if isinstance(node, (ast.Name, ast.Attribute)) and isinstance(
                getattr(node, "ctx", None), ast.Load
            ):
                name = dotted_name(node)
                if name is not None:
                    events.append((node.lineno, node.col_offset, _READ, "read", name))
            elif isinstance(node, ast.Call):
                suffix = call_suffix(node)
                if suffix in config.donating_builders:
                    builder_call = node
                if suffix == "map":
                    # jax.tree.map(jnp.array, ...) — the alias-break
                    first = dotted_name(node.args[0]) if node.args else None
                    if first is not None and first.rsplit(".", 1)[-1] in (
                        "array",
                        "asarray",
                        "copy",
                    ):
                        has_alias_break = True
                if suffix in donating:
                    nums = donating[suffix]
                    names = []
                    for idx in nums:
                        # a *args splat makes positional indices at or past
                        # it unresolvable — skip those donations
                        if any(
                            isinstance(a, ast.Starred)
                            for a in node.args[: idx + 1]
                        ):
                            continue
                        if idx < len(node.args):
                            arg_name = dotted_name(node.args[idx])
                            if arg_name is not None:
                                names.append(arg_name)
                    if names:
                        end = node.end_lineno or node.lineno
                        events.append(
                            (end, 10_000, _DONATE, "donate", (suffix, names))
                        )
            elif isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = (
                    node.targets
                    if isinstance(node, ast.Assign)
                    else [node.target]
                )
                names = []
                for t in targets:
                    names.extend(_assigned_names(t))
                if names:
                    end = node.end_lineno or node.lineno
                    events.append((end, 20_000, _ASSIGN, "assign", names))
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                names = _assigned_names(node.target)
                if names:
                    events.append(
                        (node.lineno, node.col_offset, _ASSIGN, "assign", names)
                    )

        events.sort(key=lambda e: (e[0], e[2], e[1]))
        tainted: Dict[str, Tuple[str, int]] = {}  # name -> (callee, line)
        findings: List[Finding] = []
        for line, col, _prio, kind, payload in events:
            if kind == "read":
                name = payload
                if name in tainted:
                    callee, at = tainted[name]
                    findings.append(
                        Finding(
                            rule=self.rule_id,
                            path=path,
                            line=line,
                            col=col,
                            message=(
                                f"`{name}` was donated to `{callee}(...)` on "
                                f"line {at} and read here without a rebind; "
                                "donated buffers are invalid after dispatch"
                            ),
                        )
                    )
            elif kind == "assign":
                for name in payload:
                    tainted.pop(name, None)
            elif kind == "donate":
                callee, names = payload
                for name in names:
                    tainted[name] = (callee, line)

        if builder_call is not None and not has_alias_break:
            findings.append(
                Finding(
                    rule="missing-alias-break",
                    path=path,
                    line=builder_call.lineno,
                    col=builder_call.col_offset,
                    message=(
                        "this factory compiles a donate_argnums block over "
                        "(W, S, ...); break the same_init W/S alias with "
                        "`jax.tree.map(jnp.array, ...)` before first dispatch "
                        "(see runner._ensure_sparse)"
                    ),
                )
            )
        return findings
