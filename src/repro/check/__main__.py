"""CLI: ``python -m repro.check [paths...]``.

Exit status 0 when clean, 1 when any finding survives pragma filtering,
2 on usage errors.  ``--format json`` emits a machine-readable document
(CI consumes the text form; tests the JSON one).
"""
from __future__ import annotations

import argparse
import sys
from typing import Sequence

from repro.check.engine import CheckConfig, check_paths
from repro.check.reporters import REPORTERS, report


def main(argv: Sequence[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.check",
        description="repro invariant lints (donation/aliasing/host-sync/"
        "rng-order/recompile)",
    )
    parser.add_argument(
        "paths",
        nargs="*",
        default=["src", "tests", "benchmarks"],
        help="files or directories to lint (default: src tests benchmarks)",
    )
    parser.add_argument(
        "--format",
        choices=sorted(REPORTERS),
        default="text",
        help="report format (default: text)",
    )
    parser.add_argument(
        "--rules",
        default="",
        help="comma-separated rule ids to enable (default: all)",
    )
    parser.add_argument(
        "--include-fixtures",
        action="store_true",
        help="also lint tests/fixtures/ (excluded by default: the check "
        "fixtures are seeded violations)",
    )
    args = parser.parse_args(argv)

    config = CheckConfig(
        enabled_rules=tuple(r for r in args.rules.split(",") if r),
        exclude=() if args.include_fixtures else CheckConfig().exclude,
    )
    findings = check_paths(args.paths, config)
    report(findings, args.format, sys.stdout)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
