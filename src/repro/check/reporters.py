"""Finding reporters: human text and machine JSON."""
from __future__ import annotations

import json
from collections import Counter
from typing import IO, List, Sequence

from repro.check.engine import Finding


def report_text(findings: Sequence[Finding], stream: IO[str]) -> None:
    """One `path:line:col: [rule] message` line per finding + a rule tally."""
    for f in findings:
        stream.write(f.format() + "\n")
    if findings:
        counts = Counter(f.rule for f in findings)
        tally = ", ".join(f"{rule}: {n}" for rule, n in sorted(counts.items()))
        stream.write(f"\n{len(findings)} finding(s)  ({tally})\n")
    else:
        stream.write("clean: no findings\n")


def report_json(findings: Sequence[Finding], stream: IO[str]) -> None:
    """A single JSON document: counts by rule + the full finding list."""
    counts = Counter(f.rule for f in findings)
    doc = {
        "findings": [f.to_json() for f in findings],
        "counts": dict(sorted(counts.items())),
        "total": len(findings),
    }
    json.dump(doc, stream, indent=2)
    stream.write("\n")


REPORTERS = {"text": report_text, "json": report_json}


def report(
    findings: List[Finding], fmt: str, stream: IO[str]
) -> None:
    REPORTERS[fmt](findings, stream)
