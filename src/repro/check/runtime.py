"""Runtime sanitizers: leak checking, transfer guard, compile counting.

The static rules in :mod:`repro.check.rules` catch what the AST shows; this
module catches the same contract violations at runtime, on a real run:

- :func:`sanitized` stacks ``jax.checking_leaks()`` (no tracer escapes a
  compiled block) and ``jax.transfer_guard_device_to_host("disallow")``
  around compiled dispatch.  ``"disallow"`` rejects *implicit* device→host
  transfers only — the runner's one explicit ``jax.device_get`` per
  block/drain stays legal, and host→device stays unguarded because feeding
  packed blocks via ``jnp.asarray(numpy)`` is the designed streaming
  direction.
- :class:`CompileCounter` reads the jit caches of the trainer's compiled
  blocks and asserts the one-compile-per-rung contract from PR 6: after
  warmup + a steady-state run, the sparse block's cache holds exactly one
  entry per bucket rung (fixed (A, E) shape per rung via ``_bucket_cap``),
  and nothing recompiles mid-run.

The trainer enables :func:`sanitized` around its driving loop when
constructed with ``sanitize=True`` or when ``REPRO_SANITIZE=1`` is set
(the CI smoke tier exports it); tests use both pieces directly.
"""
from __future__ import annotations

import contextlib
import os
from typing import Any, Dict, Iterator, Optional

import jax

_FALSEY = ("", "0", "false", "no", "off")


def sanitize_enabled(env: Optional[str] = None) -> bool:
    """True when the ``REPRO_SANITIZE`` flag asks for sanitized runs."""
    val = os.environ.get("REPRO_SANITIZE", "") if env is None else env
    return val.strip().lower() not in _FALSEY


# Implicit device→host conversion surface: every dunder/method numpy or
# python builtins go through when a jax array is consumed host-side.
_CONVERSIONS = ("__array__", "__float__", "__int__", "__bool__",
                "__complex__", "__index__", "item", "tolist")

# numpy 2.x converts jax arrays through the C-level buffer protocol, never
# touching the (patchable) ``__array__`` dunder — so the guard also wraps
# the numpy entry points and type-checks their first argument.
_NUMPY_ENTRIES = ("asarray", "array", "asanyarray", "ascontiguousarray")


class _HostConversionState:
    """Shared state of the (re-entrant) host-conversion guard."""

    def __init__(self) -> None:
        self.depth = 0          # guard nesting
        self.explicit = 0       # inside jax.device_get nesting
        self.violations: list = []  # (conversion name, shape) tuples


_state = _HostConversionState()


@contextlib.contextmanager
def host_conversion_guard(raise_on_violation: bool = True) -> Iterator[list]:
    """Reject *implicit* jax→host conversions; explicit device_get passes.

    The CPU-effective counterpart of ``jax.transfer_guard_device_to_host``:
    on the CPU backend nothing physically transfers, so jax's guard never
    fires — but the contract the runner pins is about *synchronization*,
    not bytes (an implicit ``float()`` blocks dispatch exactly the same).
    This guard patches the array type's conversion surface (``__array__``,
    ``__float__``, ``.item()``, ...) and raises on any call not nested
    inside an explicit ``jax.device_get``.  Yields the violation list (for
    ``raise_on_violation=False`` auditing: (conversion, shape) tuples).
    """
    import numpy as np

    impl = _array_impl()
    originals = {
        name: getattr(impl, name)
        for name in _CONVERSIONS
        if hasattr(impl, name)
    }
    np_originals = {
        name: getattr(np, name)
        for name in _NUMPY_ENTRIES
        if hasattr(np, name)
    }
    orig_device_get = jax.device_get

    def _explicit_device_get(x: Any) -> Any:
        _state.explicit += 1
        try:
            return orig_device_get(x)
        finally:
            _state.explicit -= 1

    def _violate(name: str, shape: Any) -> None:
        _state.violations.append((name, tuple(shape)))
        if raise_on_violation:
            raise RuntimeError(
                f"implicit device→host conversion `{name}` on a "
                f"jax array of shape {tuple(shape)} inside a "
                "sanitized block-dispatch region; fetch explicitly "
                "with jax.device_get(...) (repro.check.runtime)")

    def _wrap(name: str, orig: Any) -> Any:
        def guarded(self, *args: Any, **kwargs: Any) -> Any:
            if _state.depth > 0 and _state.explicit == 0:
                _violate(name, self.shape)
            return orig(self, *args, **kwargs)

        return guarded

    def _wrap_np(name: str, orig: Any) -> Any:
        def guarded(a: Any = None, *args: Any, **kwargs: Any) -> Any:
            if (isinstance(a, impl) and _state.depth > 0
                    and _state.explicit == 0):
                _violate(name, a.shape)
            return orig(a, *args, **kwargs)

        return guarded

    first = _state.depth == 0
    _state.depth += 1
    try:
        if first:
            for name, orig in originals.items():
                setattr(impl, name, _wrap(name, orig))
            for name, orig in np_originals.items():
                setattr(np, name, _wrap_np(name, orig))
            jax.device_get = _explicit_device_get
        yield _state.violations
    finally:
        _state.depth -= 1
        if first:
            for name, orig in originals.items():
                setattr(impl, name, orig)
            for name, orig in np_originals.items():
                setattr(np, name, orig)
            jax.device_get = orig_device_get
            _state.violations = []


def _array_impl() -> type:
    import jax.numpy as jnp

    return type(jnp.zeros(()))


@contextlib.contextmanager
def sanitized(
    check_leaks: bool = True,
    transfer_guard: Optional[str] = "disallow",
) -> Iterator[None]:
    """Context manager stacking the runtime sanitizers.

    ``transfer_guard`` is the device→host guard level (``"disallow"``,
    ``"log"``, ...) or None to leave transfers unguarded; jax's guard only
    fires on accelerator backends, so :func:`host_conversion_guard` rides
    along to enforce the same contract on CPU.  Tracing inside
    ``jax.checking_leaks()`` is slower; this is a smoke/test mode, not a
    production default.
    """
    with contextlib.ExitStack() as stack:
        if check_leaks:
            stack.enter_context(jax.checking_leaks())
        if transfer_guard is not None:
            stack.enter_context(
                jax.transfer_guard_device_to_host(transfer_guard))
            stack.enter_context(host_conversion_guard())
        yield


def jit_cache_size(fn: Any) -> Optional[int]:
    """Entries in a jitted callable's compile cache, or None if unreadable."""
    cache_size = getattr(fn, "_cache_size", None)
    if cache_size is None:
        return None
    try:
        return int(cache_size())
    except Exception:
        return None


class CompileCounter:
    """Track compiled-block jit caches and assert the per-rung contract.

    >>> counter = CompileCounter()
    >>> counter.track("sparse", trainer._sparse)   # after warmup/run
    >>> counter.assert_equals("sparse", len(trainer.scheduler.active_buckets()))
    """

    def __init__(self) -> None:
        self._tracked: Dict[str, Any] = {}
        self._baseline: Dict[str, int] = {}

    def track(self, name: str, fn: Any) -> None:
        if fn is None or jit_cache_size(fn) is None:
            return
        self._tracked[name] = fn
        self._baseline[name] = jit_cache_size(fn) or 0

    def counts(self) -> Dict[str, int]:
        return {
            name: (jit_cache_size(fn) or 0)
            for name, fn in self._tracked.items()
        }

    def grew(self) -> Dict[str, int]:
        """Cache growth per tracked fn since it was first tracked."""
        now = self.counts()
        return {n: now[n] - self._baseline.get(n, 0) for n in now}

    def assert_equals(self, name: str, expected: int) -> None:
        got = self.counts().get(name)
        if got is None:
            raise AssertionError(f"`{name}` is not tracked")
        if got != expected:
            raise AssertionError(
                f"compile-count contract violated for `{name}`: "
                f"{got} cache entries, expected {expected} "
                "(one compiled block program per bucket rung, PR 6)")

    def assert_steady_state(self, name: str) -> None:
        """No compiles since :meth:`track` — steady-state dispatch only."""
        growth = self.grew().get(name)
        if growth is None:
            raise AssertionError(f"`{name}` is not tracked")
        if growth != 0:
            raise AssertionError(
                f"`{name}` recompiled {growth}x after warmup: steady-state "
                "dispatch must hit the existing per-rung programs")
