"""Optimizers as pure (init, update) pairs over parameter pytrees.

DSGD-family algorithms use plain SGD at each worker (eq. 4) — momentum and
AdamW are provided for the centralized training drivers and beyond-paper
experiments (decentralized Adam keeps per-worker moments; only parameters are
gossiped, matching how Adam composes with consensus methods in practice).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable    # params -> opt_state
    update: Callable  # (grads, opt_state, params, eta) -> (updates, opt_state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, eta):
        return jax.tree.map(lambda g: -eta * g, grads), state

    return Optimizer(init, update)


def momentum(beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, m, params, eta):
        m = jax.tree.map(lambda mi, g: beta * mi + g, m, grads)
        if nesterov:
            upd = jax.tree.map(lambda mi, g: -eta * (beta * mi + g), m, grads)
        else:
            upd = jax.tree.map(lambda mi: -eta * mi, m)
        return upd, m

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: object
    nu: object
    count: jax.Array


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamState(mu=jax.tree.map(z, params),
                         nu=jax.tree.map(z, params),
                         count=jnp.zeros((), jnp.int32))

    def update(grads, state, params, eta):
        c = state.count + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        mh = jax.tree.map(lambda m: m / (1 - b1 ** c.astype(jnp.float32)), mu)
        vh = jax.tree.map(lambda v: v / (1 - b2 ** c.astype(jnp.float32)), nu)
        upd = jax.tree.map(
            lambda m, v, p: -eta * (m / (jnp.sqrt(v) + eps)
                                    + weight_decay * p.astype(jnp.float32)),
            mh, vh, params)
        return upd, AdamState(mu=mu, nu=nu, count=c)

    return Optimizer(init, update)


REGISTRY = {"sgd": sgd, "momentum": momentum, "adamw": adamw}


def make(name: str, **kw) -> Optimizer:
    return REGISTRY[name](**kw)
