from repro.optim import schedules
from repro.optim.optimizers import Optimizer, adamw, apply_updates, make, momentum, sgd
