"""Learning-rate schedules.

Includes the paper's exponentially-decayed rate η(k) = η₀·δᵏ (§6, η₀ = 0.1,
δ = 0.95 per round) and MiniCPM's WSD (Warmup-Stable-Decay) schedule
[arXiv:2404.06395] used by the minicpm-2b assigned architecture.
"""
from __future__ import annotations

import jax.numpy as jnp


def constant(eta0: float):
    return lambda step: jnp.float32(eta0)


def exponential(eta0: float, delta: float = 0.95, decay_every: int = 1):
    """The paper's η(k) = η₀ · δ^k (per ``decay_every`` rounds)."""
    def fn(step):
        return jnp.float32(eta0) * jnp.float32(delta) ** (step // decay_every)
    return fn


def cosine(eta0: float, total_steps: int, warmup: int = 0, eta_min: float = 0.0):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = eta0 * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1), 0, 1)
        cos = eta_min + 0.5 * (eta0 - eta_min) * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos).astype(jnp.float32)
    return fn


def wsd(eta0: float, total_steps: int, warmup_frac: float = 0.01,
        decay_frac: float = 0.1, eta_min_frac: float = 0.1):
    """Warmup-Stable-Decay (MiniCPM): linear warmup → flat → exponential decay."""
    warmup = max(1, int(warmup_frac * total_steps))
    decay_start = int(total_steps * (1 - decay_frac))
    eta_min = eta0 * eta_min_frac

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = eta0 * step / warmup
        stable = jnp.float32(eta0)
        prog = jnp.clip((step - decay_start)
                        / jnp.maximum(total_steps - decay_start, 1), 0, 1)
        decay = eta0 * (eta_min / eta0) ** prog
        out = jnp.where(step < warmup, warm,
                        jnp.where(step < decay_start, stable, decay))
        return out.astype(jnp.float32)
    return fn
