"""Wait-blame attribution and critical-path extraction over a Trace.

The event dependency DAG is implicit in the identity stream: event ``k``
commits when its **gate** — the restarting lane with the latest raw
completion clock — finishes (plus any serialization the scheduler's time
model adds on top, e.g. AD-PSGD's averaging lock).  Each gate's
computation started at its worker's previous restart, i.e. at an earlier
event, which is the DAG edge the critical path follows.

Per event with commit clock ``t``, restarting lanes ``i`` with raw
completions ``fin_i`` and gate ``g = argmax_i fin_i``:

- ``blame[g] += Σ_i (fin_g − fin_i)`` — virtual time the other restarting
  workers spent finished-and-waiting **on worker g**.  This is the
  straggler cost the paper's adaptive neighbor count targets: sync-DSGD
  concentrates it on the slowest workers (everyone waits for the global
  max), DSGD-AAU keeps it small (cliques of already-finished workers),
  and AD-PSGD's gate is always its own single finisher (zero blame).
- ``residual_wait += m·(t − fin_g)`` — wait even the gate itself incurred
  between finishing and committing (m = #restarting lanes): lock
  serialization / barrier-release cost, attributable to the *protocol*
  rather than to any worker.

``Σ blame + residual_wait ≡ Σ per-worker wait`` — and the per-worker
busy/wait vectors reproduce the telemetry layer's ``busy_t``/``idle_t``
accumulators exactly (same spans, f64 instead of f32; cross-checked in
tests/test_trace.py), so the blame table is a lossless *decomposition* of
the utilization numbers PR 8 already reports.

The critical path walks gates backward from the last event; its segments
tile ``[0, t_end]`` exactly (each segment spans the gate's previous
restart → its event's commit), so ``compute_t + wait_t = t_end`` is an
invariant the tests pin.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.obs.trace import Trace

__all__ = ["attribute_wait", "critical_path", "straggler_tax"]


def attribute_wait(trace: Trace) -> Dict[str, np.ndarray]:
    """One forward replay: per-worker blame/busy/wait + gate records.

    Returns a dict of arrays:

    - ``blame`` (n,) f64 — wait attributed to each worker as gate;
    - ``busy`` / ``wait`` (n,) f64 — per-worker compute / finished-waiting
      time (the f64 twins of telemetry's ``busy_t``/``idle_t``);
    - ``residual_wait`` () f64 — protocol wait no worker is blamed for;
    - ``gate_worker``/``gate_fin``/``gate_prev_ev``/``gate_prev_t`` (E,) —
      the per-event gate and its incoming DAG edge, consumed by
      :func:`critical_path` (``gate_worker`` is −1 for events with no
      restarting lane).
    """
    n, E = trace.n, trace.n_events
    gate_worker = np.full(E, -1, dtype=np.int64)
    gate_fin = np.zeros(E, dtype=np.float64)
    gate_prev_ev = np.full(E, -1, dtype=np.int64)
    gate_prev_t = np.zeros(E, dtype=np.float64)
    r = np.asarray(trace.lane_restart, dtype=bool)
    if E == 0 or not r.any():
        return {
            "blame": np.zeros(n), "busy": np.zeros(n), "wait": np.zeros(n),
            "residual_wait": np.float64(0.0),
            "gate_worker": gate_worker, "gate_fin": gate_fin,
            "gate_prev_ev": gate_prev_ev, "gate_prev_t": gate_prev_t,
        }
    # One vectorized pass over the restart lanes (already in ascending
    # event order).  The attribution runs inside every traced run's drain:
    # a per-event Python loop costs more than the fused block itself at
    # bench scale, which would break the < 1.10x overhead contract.
    ev = np.asarray(trace.lane_ev)[r]
    w = np.asarray(trace.lane_worker)[r].astype(np.int64)
    fin = np.asarray(trace.lane_fin)[r].astype(np.float64)
    t = np.asarray(trace.times, dtype=np.float64)[ev]

    # Incoming DAG edge per restart lane: the same worker's previous
    # restart event and its commit clock (0 / −1 before the first).  A
    # stable sort by worker keeps event order within each worker, so the
    # predecessor is simply the previous sorted element.
    order = np.argsort(w, kind="stable")
    prev_t_s = np.concatenate(([0.0], t[order][:-1]))
    prev_ev_s = np.concatenate(([-1], ev[order][:-1]))
    first = np.concatenate(([True], w[order][1:] != w[order][:-1]))
    prev_t_s[first] = 0.0
    prev_ev_s[first] = -1
    prev_t = np.empty_like(prev_t_s)
    prev_t[order] = prev_t_s
    prev_ev = np.empty_like(prev_ev_s)
    prev_ev[order] = prev_ev_s

    busy = np.bincount(w, weights=fin - prev_t, minlength=n)
    wait = np.bincount(w, weights=t - fin, minlength=n)

    # Per-event gate: the first-argmax of fin among the event's restart
    # lanes.  Restart lanes of one event are contiguous; lexsort (stable,
    # primary key ev, secondary −fin) puts the earliest max-fin lane at
    # each group's start — np.argmax tie-breaking, vectorized.
    starts = np.flatnonzero(np.concatenate(([True], ev[1:] != ev[:-1])))
    sizes = np.diff(np.concatenate((starts, [len(ev)])))
    gate = np.lexsort((-fin, ev))[starts]
    gev = ev[starts]
    gw, gfin = w[gate], fin[gate]
    sum_fin = np.add.reduceat(fin, starts)
    blame = np.bincount(gw, weights=sizes * gfin - sum_fin, minlength=n)
    residual = float(np.sum((t[starts] - gfin) * sizes))
    gate_worker[gev] = gw
    gate_fin[gev] = gfin
    gate_prev_ev[gev] = prev_ev[gate]
    gate_prev_t[gev] = prev_t[gate]
    return {
        "blame": blame, "busy": busy, "wait": wait,
        "residual_wait": np.float64(residual),
        "gate_worker": gate_worker, "gate_fin": gate_fin,
        "gate_prev_ev": gate_prev_ev, "gate_prev_t": gate_prev_t,
    }


def critical_path(trace: Trace,
                  attr: Optional[Dict[str, np.ndarray]] = None) -> Dict:
    """Walk the gate chain back from the last event.

    Each segment covers ``[gate's previous restart, event commit]`` on the
    gate worker — consecutive segments abut exactly (the previous restart
    *is* an earlier event's commit), so the path tiles ``[0, t_end]`` and
    ``compute_t + wait_t == t_end``.
    """
    if attr is None:
        attr = attribute_wait(trace)
    segments: List[Dict] = []
    k = trace.n_events - 1
    while k >= 0:
        gw = int(attr["gate_worker"][k])
        if gw < 0:
            break
        gfin = float(attr["gate_fin"][k])
        prev_t = float(attr["gate_prev_t"][k])
        t = float(trace.times[k])
        segments.append({
            "event": int(k), "worker": gw,
            "t_start": prev_t, "t_fin": gfin, "t_commit": t,
            "compute": gfin - prev_t, "wait": t - gfin,
        })
        k = int(attr["gate_prev_ev"][k])
    segments.reverse()
    compute_t = float(sum(s["compute"] for s in segments))
    wait_t = float(sum(s["wait"] for s in segments))
    return {
        "segments": segments,
        "events_on_path": len(segments),
        "compute_t": compute_t,
        "wait_t": wait_t,
        "t_end": float(trace.times[-1]) if trace.n_events else 0.0,
    }


def straggler_tax(trace: Trace, top_k: int = 3) -> Dict[str, object]:
    """The per-run blame summary (JSON-friendly; rides RunResult.trace).

    ``straggler_tax`` is the waiting share of total worker-time,
    ``wait / (busy + wait)`` — the exact complement of telemetry's mean
    utilization, now *decomposed* into per-worker blame plus the
    protocol residual.  The critical-path block reports how much of the
    end-to-end virtual makespan was wait rather than compute.
    """
    attr = attribute_wait(trace)
    cp = critical_path(trace, attr)
    busy_t = float(attr["busy"].sum())
    wait_t = float(attr["wait"].sum())
    span = busy_t + wait_t
    blame = attr["blame"]
    blame_total = float(blame.sum())
    order = np.argsort(blame)[::-1][:max(0, top_k)]
    blame_top = [
        {"worker": int(i), "blame_t": round(float(blame[i]), 6),
         "share": round(float(blame[i] / blame_total), 6)
         if blame_total > 0 else 0.0}
        for i in order if blame[i] > 0]
    return {
        "algorithm": trace.algorithm,
        "mode": trace.mode,
        "n": trace.n,
        "events": trace.n_events,
        "t_end": round(float(trace.times[-1]), 6) if trace.n_events else 0.0,
        "busy_t": round(busy_t, 6),
        "wait_t": round(wait_t, 6),
        "straggler_tax": round(wait_t / span, 6) if span > 0 else 0.0,
        "blame": [round(float(v), 6) for v in blame],
        "blame_total": round(blame_total, 6),
        "residual_wait": round(float(attr["residual_wait"]), 6),
        "blame_top": blame_top,
        "critical_path": {
            "events_on_path": cp["events_on_path"],
            "compute_t": round(cp["compute_t"], 6),
            "wait_t": round(cp["wait_t"], 6),
            "wait_frac": round(cp["wait_t"] / cp["t_end"], 6)
            if cp["t_end"] > 0 else 0.0,
        },
    }
