"""Virtual-time tracing: per-worker timelines from the drained event stream.

Every execution mode of :class:`~repro.core.runner.DecentralizedTrainer`
already materializes, per event, the identity tuple the fused scan streams
— *(event clock, participating workers, per-lane raw completion clocks,
grad/restart lanes, gossip edges, copies sent)*.  :class:`TraceRecorder`
buffers exactly that identity stream and normalizes it into a
:class:`Trace`: flat numpy arrays in stream order, from which per-worker
span timelines, the event dependency DAG and the wait-blame attribution
(:mod:`repro.obs.critical_path`) are all pure host-side derivations.

Recording cost follows the drain-once discipline of the telemetry layer
(PR 8):

- ``per_event`` / ``scan`` / ``sparse_scan`` / bucketed dispatch generate
  their streams host-side (``ScheduleEvent`` objects or packed
  ``SparseEventBatch`` arrays), so recording is **zero extra device work
  and zero host drains** — the recorder slices arrays that already exist.
  All four modes record the *pre-merge, pre-pad* stream, so their traces
  are bit-identical to the per-event reference (tests/test_trace.py).
- ``fused`` keeps the whole event process on device; the runner buffers
  each block's ``(t_ev, i, p, t_raw)`` scan outputs (the same payload
  telemetry folds) and :func:`drain_fused_payload` fetches the
  concatenation with **exactly one** explicit ``jax.device_get`` at run
  end.  The fused realization is a different-but-deterministic RNG
  realization of the stream (see core/fused.py), so its trace is
  internally consistent rather than event-matched to the host modes'.

The Chrome Trace Event Format exporter (:func:`chrome_trace`) renders two
process tracks, loadable in Perfetto / ``chrome://tracing``:

- **pid 0 — virtual time**: one thread per worker; ``compute`` spans
  (previous restart → raw completion), ``wait`` spans (completion → event
  commit, i.e. straggler/lock wait), and gossip edges as ``s``/``f`` flow
  arrows between the coupled workers at the commit instant.
- **pid 1 — wall clock**: built from :class:`~repro.obs.runlog.RunLogger`
  records (every record carries a wall-clock ``ts``); ``block_dispatch``
  spans on the dispatch thread, per-rung ``bucket_segment`` spans on one
  thread per lane width A, ``compile`` instants.  Virtual-time cost and
  wall-time cost per bucket rung sit side by side.

``python -m repro.obs.trace RUN_LOG.jsonl`` builds the wall-clock track
alone from a run-log file (no trainer needed).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import IO, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

__all__ = [
    "Trace",
    "TraceRecorder",
    "drain_fused_payload",
    "chrome_trace",
    "wall_track",
    "load_run_log",
    "main",
]


@dataclasses.dataclass
class Trace:
    """A run's normalized event-identity stream (host numpy, stream order).

    Events are indexed ``0..E-1`` in commit order.  Lanes are the ragged
    per-event participant records, flattened with ``lane_ev`` ascending
    (lanes of one event keep the event's worker order — ascending worker
    id for every generator in this repo).  Edges are the gossip pairs the
    event mixed over, as global worker-id endpoints.
    """

    n: int
    times: np.ndarray          # (E,) f64 event commit clocks
    copies: np.ndarray         # (E,) i64 param copies sent
    lane_ev: np.ndarray        # (L,) i64 owning event index, ascending
    lane_worker: np.ndarray    # (L,) i32 global worker id
    lane_fin: np.ndarray       # (L,) f64 raw completion clock (≤ commit)
    lane_grad: np.ndarray      # (L,) bool lane fires a gradient
    lane_restart: np.ndarray   # (L,) bool lane restarts its computation
    edge_ev: np.ndarray        # (M,) i64 owning event index, ascending
    edge_src: np.ndarray       # (M,) i32
    edge_dst: np.ndarray       # (M,) i32
    algorithm: str = ""
    mode: str = ""

    @property
    def n_events(self) -> int:
        return int(self.times.shape[0])

    @property
    def n_lanes(self) -> int:
        return int(self.lane_ev.shape[0])

    def event_bounds(self) -> np.ndarray:
        """(E+1,) lane-array offsets: event k's lanes are
        ``[bounds[k], bounds[k+1])``."""
        return np.searchsorted(self.lane_ev,
                               np.arange(self.n_events + 1, dtype=np.int64))


_EMPTY_CHUNK_KEYS = (
    "times", "copies", "lane_ev", "lane_worker", "lane_fin",
    "lane_grad", "lane_restart", "edge_ev", "edge_src", "edge_dst",
)


class TraceRecorder:
    """Accumulates identity chunks; :meth:`finalize` concatenates once.

    The record methods mirror the runner's per-mode stream forms and are
    all pure host work over arrays the driving loop already holds; the
    only device interaction in the whole trace path is the caller's single
    :func:`drain_fused_payload` fetch for ``mode="fused"``.
    """

    def __init__(self, n: int):
        self.n = int(n)
        self._chunks: List[Dict[str, np.ndarray]] = []
        self._k = 0  # events recorded so far (global stream index base)

    # -- per-mode recording ------------------------------------------------
    def record_event(self, ev) -> None:
        """One ``ScheduleEvent`` (``per_event`` mode)."""
        m = len(ev.workers)
        fin = (np.asarray(ev.finish_lanes, dtype=np.float64)
               if ev.finish_lanes is not None
               else np.full(m, ev.time, dtype=np.float64))
        e = len(ev.edges)
        self._chunks.append({
            "times": np.array([ev.time], dtype=np.float64),
            "copies": np.array([ev.param_copies_sent], dtype=np.int64),
            "lane_ev": np.full(m, self._k, dtype=np.int64),
            "lane_worker": np.asarray(ev.workers, dtype=np.int32),
            "lane_fin": fin,
            "lane_grad": np.asarray(ev.grad_lanes, dtype=bool),
            "lane_restart": np.asarray(ev.restart_lanes, dtype=bool),
            "edge_ev": np.full(e, self._k, dtype=np.int64),
            "edge_src": np.asarray(ev.edges[:, 0], dtype=np.int32)
            if e else np.zeros(0, dtype=np.int32),
            "edge_dst": np.asarray(ev.edges[:, 1], dtype=np.int32)
            if e else np.zeros(0, dtype=np.int32),
        })
        self._k += 1

    def record_events(self, events: Sequence) -> None:
        """A buffered block of ``ScheduleEvent``s (``scan`` mode), recorded
        *before* padding — the trace never sees no-op filler events."""
        for ev in events:
            self.record_event(ev)

    def record_sparse(self, batch) -> None:
        """One packed ``SparseEventBatch`` (sparse path), pre-merge/pad."""
        workers = batch.workers
        E, _A = workers.shape
        valid = workers >= 0
        rows, cols = np.nonzero(valid)
        fin = (batch.finish[rows, cols].astype(np.float64)
               if batch.finish is not None
               else batch.times[rows].astype(np.float64))
        emask = (np.arange(batch.edges.shape[1])[None, :]
                 < batch.n_edges[:, None])
        erows, ecols = np.nonzero(emask)
        self._chunks.append({
            "times": np.asarray(batch.times, dtype=np.float64),
            "copies": np.asarray(batch.param_copies_sent, dtype=np.int64),
            "lane_ev": self._k + rows.astype(np.int64),
            "lane_worker": workers[rows, cols].astype(np.int32),
            "lane_fin": fin,
            "lane_grad": batch.grad_workers[rows, cols].astype(bool),
            "lane_restart": batch.restart_workers[rows, cols].astype(bool),
            "edge_ev": self._k + erows.astype(np.int64),
            "edge_src": batch.edges[erows, ecols, 0].astype(np.int32),
            "edge_dst": batch.edges[erows, ecols, 1].astype(np.int32),
        })
        self._k += E

    def record_chunk(self, chunk) -> None:
        """A sparse-path stream chunk: plain or bucketed.

        A bucketed chunk is recorded segment-by-segment in stream order
        (``segment_batches`` yields the maximal same-bucket runs exactly
        as the dispatcher replays them), so event indices stay the global
        stream indices.
        """
        if hasattr(chunk, "segment_batches"):
            for _b, _off, seg in chunk.segment_batches():
                self.record_sparse(seg)
        else:
            self.record_sparse(chunk)

    def record_fused(self, t_ev: np.ndarray, i_seq: np.ndarray,
                     p_seq: np.ndarray, t_raw: np.ndarray,
                     copies_pair: int) -> None:
        """The fused run's drained identity stream (host arrays).

        Lane rebuild convention (matches ``fused_metrics_fold``): every
        event has one finisher ``i`` (grad = restart lane, completion at
        ``t_raw``) and, when ``p >= 0``, a gossip partner whose own
        computation is untouched — its lane is present (completion shown
        at the commit clock) but fires neither gradient nor restart.
        """
        t_ev = np.asarray(t_ev, dtype=np.float64)
        t_raw = np.asarray(t_raw, dtype=np.float64)
        i = np.asarray(i_seq, dtype=np.int32)
        p = np.asarray(p_seq, dtype=np.int32)
        E = t_ev.shape[0]
        has = p >= 0
        lo = np.where(has, np.minimum(i, p), i).astype(np.int32)
        hi = np.where(has, np.maximum(i, p), i).astype(np.int32)
        w2 = np.stack([lo, hi], axis=1)                   # (E, 2) ascending
        valid2 = np.stack([np.ones(E, dtype=bool), has], axis=1)
        grad2 = (w2 == i[:, None]) & valid2
        fin2 = np.where(grad2, t_raw[:, None], t_ev[:, None])
        rows, cols = np.nonzero(valid2)
        eidx = np.nonzero(has)[0]
        self._chunks.append({
            "times": t_ev,
            "copies": np.where(has, int(copies_pair), 0).astype(np.int64),
            "lane_ev": self._k + rows.astype(np.int64),
            "lane_worker": w2[rows, cols],
            "lane_fin": fin2[rows, cols],
            "lane_grad": grad2[rows, cols],
            "lane_restart": grad2[rows, cols],
            "edge_ev": self._k + eidx.astype(np.int64),
            "edge_src": lo[eidx],
            "edge_dst": hi[eidx],
        })
        self._k += E

    # -- drain -------------------------------------------------------------
    def finalize(self, algorithm: str = "", mode: str = "") -> Trace:
        cat: Dict[str, np.ndarray] = {}
        for key in _EMPTY_CHUNK_KEYS:
            parts = [c[key] for c in self._chunks]
            cat[key] = (np.concatenate(parts) if parts
                        else _empty_like_key(key))
        return Trace(n=self.n, algorithm=algorithm, mode=mode, **cat)


def _empty_like_key(key: str) -> np.ndarray:
    if key in ("times", "lane_fin"):
        return np.zeros(0, dtype=np.float64)
    if key in ("copies", "lane_ev", "edge_ev"):
        return np.zeros(0, dtype=np.int64)
    if key in ("lane_grad", "lane_restart"):
        return np.zeros(0, dtype=bool)
    return np.zeros(0, dtype=np.int32)


def drain_fused_payload(payload: Sequence) -> Tuple[np.ndarray, ...]:
    """Fetch the fused run's buffered identity blocks in ONE device read.

    ``payload`` is the runner's per-block list of ``(t_ev, i, p, t_raw)``
    device tuples; the blocks are concatenated on device and fetched with
    a single explicit ``jax.device_get`` — the whole trace subsystem's
    only device→host transfer (the host modes record from arrays the
    driving loop already holds).
    """
    import jax
    import jax.numpy as jnp

    t_ev, i_seq, p_seq, t_raw = (
        jnp.concatenate(xs) if len(xs) > 1 else xs[0]
        for xs in zip(*payload))
    return jax.device_get((t_ev, i_seq, p_seq, t_raw))


# -- Chrome Trace Event Format export ---------------------------------------

#: 1 unit of virtual time renders as 1 s (Chrome trace ``ts`` is in µs).
_VIRT_US = 1e6
#: Wall-clock ``ts`` fields are seconds since logger construction.
_WALL_US = 1e6


def chrome_trace(trace: Optional[Trace] = None,
                 run_log: Optional[Sequence[Dict]] = None) -> Dict:
    """Build a Chrome Trace Event Format document (JSON-serializable).

    ``trace`` fills the virtual-time process (pid 0, one thread per
    worker); ``run_log`` (a list of RunLogger records) fills the
    wall-clock process (pid 1).  Either may be omitted.
    """
    events: List[Dict] = []
    if trace is not None:
        events.extend(_virtual_track(trace))
    if run_log is not None:
        events.extend(wall_track(run_log))
    other = {}
    if trace is not None:
        other = {"algorithm": trace.algorithm, "mode": trace.mode,
                 "n": trace.n, "events": trace.n_events}
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": other}


def _virtual_track(trace: Trace, pid: int = 0) -> List[Dict]:
    out: List[Dict] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "args": {"name": f"virtual time · {trace.algorithm or 'run'}"
                 + (f" ({trace.mode})" if trace.mode else "")},
    }]
    for w in range(trace.n):
        out.append({"name": "thread_name", "ph": "M", "pid": pid,
                    "tid": w, "args": {"name": f"worker {w}"}})
    last_restart = np.zeros(trace.n, dtype=np.float64)
    ev = trace.lane_ev
    for j in range(trace.n_lanes):
        if not trace.lane_restart[j]:
            continue
        k = int(ev[j])
        w = int(trace.lane_worker[j])
        fin = float(trace.lane_fin[j])
        t = float(trace.times[k])
        start = float(last_restart[w])
        out.append({
            "name": "compute", "cat": "compute", "ph": "X", "pid": pid,
            "tid": w, "ts": start * _VIRT_US,
            "dur": max(fin - start, 0.0) * _VIRT_US,
            "args": {"event": k},
        })
        if t > fin:
            out.append({
                "name": "wait", "cat": "wait", "ph": "X", "pid": pid,
                "tid": w, "ts": fin * _VIRT_US,
                "dur": (t - fin) * _VIRT_US,
                "args": {"event": k},
            })
        last_restart[w] = t
    for j in range(trace.edge_ev.shape[0]):
        k = int(trace.edge_ev[j])
        ts = float(trace.times[k]) * _VIRT_US
        fid = int(j) + 1
        a, b = int(trace.edge_src[j]), int(trace.edge_dst[j])
        out.append({"name": "gossip", "cat": "gossip", "ph": "s",
                    "pid": pid, "tid": a, "ts": ts, "id": fid,
                    "args": {"event": k}})
        out.append({"name": "gossip", "cat": "gossip", "ph": "f",
                    "bp": "e", "pid": pid, "tid": b, "ts": ts, "id": fid,
                    "args": {"event": k}})
    return out


#: Wall-track thread ids: dispatch spans on tid 0; a bucketed run's
#: per-rung segments each get the rung's lane width A as their tid.
_WALL_DISPATCH_TID = 0


def wall_track(records: Sequence[Dict], pid: int = 1) -> List[Dict]:
    """Wall-clock spans from RunLogger records (each carries ``ts``).

    ``block_dispatch`` / ``bucket_segment`` records mark span *starts*;
    a span's duration is the gap to the next timestamped record (the
    dispatch loop logs before launching each block, so consecutive
    records bracket the launch + host packing work).  ``compile`` and the
    remaining lifecycle records render as instants.
    """
    recs = [r for r in records if isinstance(r.get("ts"), (int, float))]
    recs.sort(key=lambda r: r["ts"])
    out: List[Dict] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "args": {"name": "wall clock (run log)"},
    }, {
        "name": "thread_name", "ph": "M", "pid": pid,
        "tid": _WALL_DISPATCH_TID, "args": {"name": "dispatch"},
    }]
    rungs = sorted({int(r["A"]) for r in recs
                    if r.get("event") == "bucket_segment" and "A" in r})
    for a in rungs:
        out.append({"name": "thread_name", "ph": "M", "pid": pid,
                    "tid": a, "args": {"name": f"rung A={a}"}})
    for idx, rec in enumerate(recs):
        ts = float(rec["ts"]) * _WALL_US
        nxt = (float(recs[idx + 1]["ts"]) * _WALL_US
               if idx + 1 < len(recs) else ts)
        kind = rec.get("event", "?")
        args = {k: v for k, v in rec.items() if k not in ("event", "ts")}
        if kind == "block_dispatch":
            out.append({
                "name": f"dispatch:{rec.get('mode', '?')}",
                "cat": "dispatch", "ph": "X", "pid": pid,
                "tid": _WALL_DISPATCH_TID, "ts": ts,
                "dur": max(nxt - ts, 0.0), "args": args,
            })
        elif kind == "bucket_segment":
            out.append({
                "name": f"segment A={rec.get('A', '?')}",
                "cat": "dispatch", "ph": "X", "pid": pid,
                "tid": int(rec.get("A", 0)), "ts": ts,
                "dur": max(nxt - ts, 0.0), "args": args,
            })
        else:
            out.append({
                "name": kind, "cat": "lifecycle", "ph": "i", "pid": pid,
                "tid": _WALL_DISPATCH_TID, "ts": ts, "s": "t",
                "args": args,
            })
    return out


# -- run-log CLI -------------------------------------------------------------

def load_run_log(path_or_fh: Union[str, IO[str]]) -> List[Dict]:
    """Parse a RunLogger JSONL file; malformed lines are skipped."""
    if hasattr(path_or_fh, "read"):
        lines = path_or_fh.read().splitlines()
    else:
        with open(path_or_fh, "r", encoding="utf-8") as fh:
            lines = fh.read().splitlines()
    records = []
    for line in lines:
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            continue
        if isinstance(rec, dict):
            records.append(rec)
    return records


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.trace",
        description="Convert a RunLogger JSONL run log into a Chrome Trace "
                    "Event Format file (wall-clock track) for Perfetto / "
                    "chrome://tracing.")
    ap.add_argument("run_log", help="path to the run log (JSONL)")
    ap.add_argument("-o", "--out", default=None,
                    help="output path (default: <run_log>.trace.json)")
    args = ap.parse_args(argv)
    records = load_run_log(args.run_log)
    doc = chrome_trace(run_log=records)
    out = args.out or (args.run_log + ".trace.json")
    with open(out, "w", encoding="utf-8") as fh:
        json.dump(doc, fh)
    spans = sum(1 for e in doc["traceEvents"] if e.get("ph") == "X")
    print(f"wrote {out}: {len(doc['traceEvents'])} trace events "
          f"({spans} spans) from {len(records)} log records")
    return 0


if __name__ == "__main__":
    sys.exit(main())
