"""Device-resident telemetry accumulators for the event-stream simulators.

:class:`MetricsCarry` is a NamedTuple of small device arrays that rides the
``(W, S, y, ptr)`` carry of every execution mode's scan (dense, sparse,
bucketed, fused) and of the per-event interpreter's jitted step.  Updates
are **order-exact across representations**: every accumulator uses only
operations whose result is independent of how the stream is chunked or
merged —

- integer adds / maxes and integer scatter-adds (exact, commutative);
- boolean participation tests derived from the consensus matrix itself
  (``P`` row/column off-diagonal support — identical floats in the dense
  stack and the active-set submatrix, see core/scheduler.py);
- per-worker float32 adds where non-participants contribute an exact
  ``+0.0`` (``x + 0.0 == x`` bitwise for the non-negative accumulators),
  at most one add per worker per scan step (merged rows have pairwise
  disjoint worker sets by construction — ``merge_event_groups``);
- a pure-integer log2 binning for the staleness histogram (no float
  ``log2`` whose rounding could differ between chunkings).

so the drained counters are **bit-identical** across ``per_event``,
``scan``, ``sparse_scan`` and bucketed dispatch of the same stream, which
tests/test_telemetry.py pins.  (``fused`` is a different-but-deterministic
RNG realization of the stream — see core/fused.py — so its counters are
internally consistent and deterministic, not event-matched to the host
generators'.)

Staleness semantics: a worker's gradient is evaluated at the snapshot it
took at its previous restart, so when worker ``w`` fires a gradient at
event ``k`` its staleness is ``s = k − last_restart_k[w] − 1`` — 0 when it
participated in the immediately preceding event, and ``k`` on its first
participation (``last_restart_k`` initializes to −1: the initial snapshot
predates event 0).

For DSGD-AAU, Pathsearch's per-epoch commit bound B ≤ N−1 (paper Remark 4)
induces a hard event-staleness bound of **2N−4**: every event commits at
least one novel edge, an epoch holds at most N−1 of them, and no epoch can
*complete* until every worker has joined V (which requires participating).
So between worker w's consecutive participations at most N−2 events can
drain the current epoch's remaining unions, and at most N−2 more can merge
the other N−1 workers in the next epoch before any further union needs w
as an endpoint — the next event necessarily includes w.  The runtime
monitor checks the drained ``stale_max`` against 2N−4; heavy-tailed
straggler scenarios empirically *reach* it (the bound is tight), which is
what makes it a real invariant check rather than a slack one.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

#: Histogram rungs: bin b counts gradient firings with staleness s where
#: ``floor(log2(s + 1)) == b`` — bin 0 is s = 0, the last bin absorbs
#: everything from 2^15 − 1 up.
STALE_HIST_BINS = 16


class MetricsCarry(NamedTuple):
    """Per-worker / scalar telemetry accumulators (all device arrays)."""

    grad_steps: jax.Array       # (n,) int32 — gradient firings per worker
    mix_count: jax.Array        # (n,) int32 — gossip participations (events
                                #   where the worker's P row/col mixed mass)
    last_restart_k: jax.Array   # (n,) int32 — event index of the last
                                #   restart; −1 before the first
    last_mix_t: jax.Array       # (n,) f32 — virtual clock of the last mix
    last_restart_t: jax.Array   # (n,) f32 — virtual clock of the last restart
    busy_t: jax.Array           # (n,) f32 — Σ local-computation time
    idle_t: jax.Array           # (n,) f32 — Σ wait time (finish → event)
    stale_max: jax.Array        # () int32 — max observed gradient staleness
    stale_sum: jax.Array        # () int32 — Σ staleness over gradient firings
    stale_hist: jax.Array       # (STALE_HIST_BINS,) int32 — log2-binned
    comm_copies: jax.Array      # () int32 — Σ parameter copies sent


def init_metrics(n: int) -> MetricsCarry:
    return MetricsCarry(
        grad_steps=jnp.zeros((n,), dtype=jnp.int32),
        mix_count=jnp.zeros((n,), dtype=jnp.int32),
        last_restart_k=jnp.full((n,), -1, dtype=jnp.int32),
        last_mix_t=jnp.zeros((n,), dtype=jnp.float32),
        last_restart_t=jnp.zeros((n,), dtype=jnp.float32),
        busy_t=jnp.zeros((n,), dtype=jnp.float32),
        idle_t=jnp.zeros((n,), dtype=jnp.float32),
        stale_max=jnp.int32(0),
        stale_sum=jnp.int32(0),
        stale_hist=jnp.zeros((STALE_HIST_BINS,), dtype=jnp.int32),
        comm_copies=jnp.int32(0),
    )


def _stale_bins(s: jax.Array) -> jax.Array:
    """``floor(log2(s + 1))`` via pure integer comparisons (exact).

    ``bin = Σ_j [s + 1 >= 2^j]`` for j = 1..STALE_HIST_BINS−1: no float
    log whose rounding could differ between the dense and sparse update
    shapes.  Negative ``s`` (masked-out lanes) maps to bin 0 — callers
    gate the histogram add on the gradient mask, so the value never lands.
    """
    thresholds = 2 ** jnp.arange(1, STALE_HIST_BINS, dtype=jnp.int32)
    return jnp.sum((s[..., None] + 1) >= thresholds, axis=-1).astype(jnp.int32)


def _staleness(M: MetricsCarry, last_k: jax.Array, gm: jax.Array,
               ks: jax.Array):
    """(stale_max', stale_sum', hist delta bins, per-slot counts)."""
    s = ks - last_k - 1
    stale_max = jnp.maximum(
        M.stale_max, jnp.max(jnp.where(gm, s, -1)).astype(jnp.int32))
    stale_sum = M.stale_sum + jnp.sum(jnp.where(gm, s, 0)).astype(jnp.int32)
    return stale_max, stale_sum, _stale_bins(s)


def dense_metrics_update(M: MetricsCarry, P: jax.Array, gm: jax.Array,
                         rm: jax.Array, t: jax.Array, fin: jax.Array,
                         k: jax.Array, copies: jax.Array) -> MetricsCarry:
    """One dense event's telemetry: the (n,)-shaped sibling of the sparse
    update below (``per_event`` and ``scan`` modes).

    P: (n, n) consensus matrix; gm/rm: (n,) bool masks; t: scalar f32
    event clock; fin: (n,) f32 raw completion clocks (only read where
    ``rm``); k: scalar int32 event index; copies: scalar int32.
    """
    n = P.shape[0]
    offdiag = P * (1.0 - jnp.eye(n, dtype=P.dtype))
    coupled = jnp.any(offdiag != 0, axis=1) | jnp.any(offdiag != 0, axis=0)
    gi = gm.astype(jnp.int32)
    stale_max, stale_sum, bins = _staleness(M, M.last_restart_k, gm, k)
    return MetricsCarry(
        grad_steps=M.grad_steps + gi,
        mix_count=M.mix_count + coupled.astype(jnp.int32),
        last_restart_k=jnp.where(rm, k, M.last_restart_k),
        last_mix_t=jnp.where(coupled, t, M.last_mix_t),
        last_restart_t=jnp.where(rm, t, M.last_restart_t),
        busy_t=M.busy_t + jnp.where(rm, fin - M.last_restart_t,
                                    jnp.float32(0.0)),
        idle_t=M.idle_t + jnp.where(rm, t - fin, jnp.float32(0.0)),
        stale_max=stale_max,
        stale_sum=stale_sum,
        stale_hist=M.stale_hist.at[bins].add(gi),
        comm_copies=M.comm_copies + copies,
    )


def sparse_metrics_update(M: MetricsCarry, workers: jax.Array,
                          P_sub: jax.Array, gm: jax.Array, rm: jax.Array,
                          ts: jax.Array, fin: jax.Array, ks: jax.Array,
                          copies: jax.Array) -> MetricsCarry:
    """One active-set scan step's telemetry (``sparse_scan`` / bucketed /
    merged rows / ``fused``).

    workers: (A,) int32, −1-padded; P_sub: (A, A); gm/rm: (A,) per-lane
    bools; ts/fin: (A,) f32 per-lane event / raw-completion clocks (merged
    rows carry each member event's own clock); ks: (A,) int32 per-lane
    event indices; copies: scalar int32 (a merged row carries the group
    sum — same total, exactly).

    A worker appears in at most one lane per step (events within a merged
    row have pairwise disjoint active sets), so every scatter touches each
    accumulator slot at most once — adds and sets land in stream order
    across steps, which is what makes the drained counters bit-identical
    to the dense per-event updates.
    """
    n = M.grad_steps.shape[0]
    A = workers.shape[0]
    valid = workers >= 0
    gidx = jnp.where(valid, workers, 0)
    sidx = jnp.where(valid, workers, n)         # OOB ⇒ scatter drops the lane
    gmv = gm & valid
    rmv = rm & valid
    offdiag = P_sub * (1.0 - jnp.eye(A, dtype=P_sub.dtype))
    coupled = (jnp.any(offdiag != 0, axis=1)
               | jnp.any(offdiag != 0, axis=0)) & valid
    gi = gmv.astype(jnp.int32)
    stale_max, stale_sum, bins = _staleness(
        M, M.last_restart_k[gidx], gmv, ks)
    return MetricsCarry(
        grad_steps=M.grad_steps.at[sidx].add(gi, mode="drop"),
        mix_count=M.mix_count.at[sidx].add(coupled.astype(jnp.int32),
                                           mode="drop"),
        last_restart_k=M.last_restart_k.at[
            jnp.where(rmv, workers, n)].set(ks, mode="drop"),
        last_mix_t=M.last_mix_t.at[
            jnp.where(coupled, workers, n)].set(ts, mode="drop"),
        last_restart_t=M.last_restart_t.at[
            jnp.where(rmv, workers, n)].set(ts, mode="drop"),
        busy_t=M.busy_t.at[sidx].add(
            jnp.where(rmv, fin - M.last_restart_t[gidx], jnp.float32(0.0)),
            mode="drop"),
        idle_t=M.idle_t.at[sidx].add(
            jnp.where(rmv, ts - fin, jnp.float32(0.0)), mode="drop"),
        stale_max=stale_max,
        stale_sum=stale_sum,
        # masked lanes add an exact integer 0 at their (garbage) bin
        stale_hist=M.stale_hist.at[bins].add(gi),
        comm_copies=M.comm_copies + copies,
    )


def block_metrics_update(M: MetricsCarry, workers: jax.Array,
                         gm: jax.Array, rm: jax.Array, coupled: jax.Array,
                         ts: jax.Array, fin: jax.Array, ks: jax.Array,
                         copies: jax.Array) -> MetricsCarry:
    """Fold a whole block of E events into the carry in one vectorized pass.

    The amortized sibling of :func:`sparse_metrics_update`: the only
    genuinely sequential state — each worker's last restart — is recovered
    with an exclusive ``lax.cummax`` prefix over the block, and every
    accumulator lands in a single flattened scatter per block, so
    telemetry cost is O(E·n) vectorized work amortized over E events.
    This is the *generic* block fold (arbitrary lane payloads); it serves
    as the tested bridge between the sequential per-event updates and
    :func:`fused_metrics_fold`, the O(E) specialization the fused runner
    actually drains through.

    workers: (E, A) int32, −1-padded; gm/rm/coupled: (E, A) bools (the
    caller derives ``coupled`` from its payload structure); ts: (E,) f32
    per-event clocks; fin: (E, A) f32 raw completion clocks; ks: (E,)
    int32 **consecutive** event indices (``ks[0] + arange(E)`` — the
    prefix gather maps event index → block position by subtraction);
    copies: (E,) int32 per-event copy counts.

    Integer counters are bit-identical to the sequential fold; the f32
    busy/idle accumulators sum a block's contributions in scatter order
    before adding to the carry, so they are deterministic but not
    add-order-identical to the per-event fold.
    """
    n = M.grad_steps.shape[0]
    E, A = workers.shape
    valid = workers >= 0
    gmv = gm & valid
    rmv = rm & valid
    cpl = coupled & valid
    gidx = jnp.where(valid, workers, 0)
    k0 = ks[0]
    # (E, n) "worker w restarted at event e" → exclusive last-restart prefix
    hot_r = jnp.any((workers[:, :, None]
                     == jnp.arange(n, dtype=jnp.int32))
                    & rmv[:, :, None], axis=1)
    rk = jnp.where(hot_r, ks[:, None], jnp.int32(-1))
    cmax = jax.lax.cummax(rk, axis=0)
    prefix = jnp.concatenate(
        [jnp.full((1, n), -1, dtype=jnp.int32), cmax[:-1]])
    in_blk = prefix >= k0
    pos = jnp.clip(prefix - k0, 0, E - 1)
    eff_k = jnp.where(in_blk, prefix, M.last_restart_k[None, :])
    eff_t = jnp.where(in_blk, ts[pos], M.last_restart_t[None, :])
    lk = jnp.take_along_axis(eff_k, gidx, axis=1)       # (E, A)
    lt = jnp.take_along_axis(eff_t, gidx, axis=1)
    s = ks[:, None] - lk - 1
    stale_max = jnp.maximum(
        M.stale_max, jnp.max(jnp.where(gmv, s, -1)).astype(jnp.int32))
    stale_sum = M.stale_sum + jnp.sum(jnp.where(gmv, s, 0)).astype(jnp.int32)
    gi = gmv.astype(jnp.int32)
    sidx = jnp.where(valid, workers, n).ravel()         # OOB ⇒ dropped
    fin_k = cmax[-1]                                    # latest in-block restart
    fin_in = fin_k >= k0
    mix_k = jnp.max(jnp.where(
        jnp.any((workers[:, :, None] == jnp.arange(n, dtype=jnp.int32))
                & cpl[:, :, None], axis=1),
        ks[:, None], jnp.int32(-1)), axis=0)
    return MetricsCarry(
        grad_steps=M.grad_steps.at[sidx].add(gi.ravel(), mode="drop"),
        mix_count=M.mix_count.at[sidx].add(cpl.astype(jnp.int32).ravel(),
                                           mode="drop"),
        last_restart_k=jnp.where(fin_in, fin_k, M.last_restart_k),
        last_mix_t=jnp.where(mix_k >= k0,
                             ts[jnp.clip(mix_k - k0, 0, E - 1)],
                             M.last_mix_t),
        last_restart_t=jnp.where(fin_in,
                                 ts[jnp.clip(fin_k - k0, 0, E - 1)],
                                 M.last_restart_t),
        busy_t=M.busy_t.at[sidx].add(
            jnp.where(rmv, fin - lt, jnp.float32(0.0)).ravel(),
            mode="drop"),
        idle_t=M.idle_t.at[sidx].add(
            jnp.where(rmv, ts[:, None] - fin, jnp.float32(0.0)).ravel(),
            mode="drop"),
        stale_max=stale_max,
        stale_sum=stale_sum,
        stale_hist=M.stale_hist.at[_stale_bins(s).ravel()].add(gi.ravel()),
        comm_copies=M.comm_copies + jnp.sum(copies).astype(jnp.int32),
    )


def fused_metrics_fold(M: MetricsCarry, i_seq: jax.Array, p_seq: jax.Array,
                       t_raw: jax.Array, t_ev: jax.Array,
                       copies_pair: int, k0: jax.Array) -> MetricsCarry:
    """Drain-time fold of a fused run's streamed event identities.

    The fused event process has structure the generic block fold cannot
    assume: every event has exactly **one** gradient = restart worker (the
    finisher ``i_seq[e]``), the coupled set is ``{i, p}`` iff a partner
    exists (``p_seq[e] >= 0``), the finisher's busy interval ends at its
    raw completion ``t_raw`` (its idle is the lock wait ``t_ev − t_raw``)
    and a pair event ships ``copies_pair`` copies.  That collapses every
    accumulator to an O(E) scatter except the last-restart prefix, which
    stays one (E, n) compare + ``lax.cummax``.  The fused scan therefore
    only streams out ``(t_ev, i, p, t_raw)`` per event — no per-block
    metrics work at all — and the runner calls this **once per run** over
    the concatenated blocks, making telemetry's in-run cost just the three
    extra scan outputs.

    i_seq/p_seq: (E,) int32 finisher / partner (−1 when isolated);
    t_raw/t_ev: (E,) f32 raw and lock-shifted event clocks; copies_pair:
    static int; k0: scalar int32 index of the first event (the run's
    event indices are ``k0 + arange(E)``).

    Equivalent to rebuilding the 2-lane payloads and folding them through
    :func:`block_metrics_update` (tests/test_telemetry.py pins this); the
    same f32 caveat applies — busy/idle sums are deterministic but not
    add-order-identical to the per-event fold.
    """
    n = M.grad_steps.shape[0]
    E = i_seq.shape[0]
    ks = k0 + jnp.arange(E, dtype=jnp.int32)
    has = p_seq >= 0
    # exclusive per-worker last-restart prefix: the finisher restarts at
    # its own event, so the (E, n) one-hot is a single compare
    hot_r = i_seq[:, None] == jnp.arange(n, dtype=jnp.int32)
    rk = jnp.where(hot_r, ks[:, None], jnp.int32(-1))
    cmax = jax.lax.cummax(rk, axis=0)
    prefix = jnp.concatenate(
        [jnp.full((1, n), -1, dtype=jnp.int32), cmax[:-1]])
    in_run = prefix >= k0
    pos = jnp.clip(prefix - k0, 0, E - 1)
    eff_k = jnp.where(in_run, prefix, M.last_restart_k[None, :])
    eff_t = jnp.where(in_run, t_ev[pos], M.last_restart_t[None, :])
    lk = jnp.take_along_axis(eff_k, i_seq[:, None], axis=1)[:, 0]
    lt = jnp.take_along_axis(eff_t, i_seq[:, None], axis=1)[:, 0]
    s = ks - lk - 1                                     # every event fires
    # both coupled lanes in one flattened scatter; isolated events route
    # both slots to the dropped n bucket
    midx = jnp.concatenate([jnp.where(has, i_seq, n),
                            jnp.where(has, p_seq, n)])
    mix_k = jnp.full((n + 1,), -1, dtype=jnp.int32).at[midx].max(
        jnp.concatenate([ks, ks]))[:n]
    fin_k = cmax[-1]
    fin_in = fin_k >= k0
    return MetricsCarry(
        grad_steps=M.grad_steps.at[i_seq].add(1),
        mix_count=M.mix_count.at[midx].add(1, mode="drop"),
        last_restart_k=jnp.where(fin_in, fin_k, M.last_restart_k),
        last_mix_t=jnp.where(mix_k >= k0,
                             t_ev[jnp.clip(mix_k - k0, 0, E - 1)],
                             M.last_mix_t),
        last_restart_t=jnp.where(fin_in,
                                 t_ev[jnp.clip(fin_k - k0, 0, E - 1)],
                                 M.last_restart_t),
        busy_t=M.busy_t.at[i_seq].add(t_raw - lt),
        idle_t=M.idle_t.at[i_seq].add(t_ev - t_raw),
        stale_max=jnp.maximum(M.stale_max, jnp.max(s)).astype(jnp.int32),
        stale_sum=(M.stale_sum + jnp.sum(s)).astype(jnp.int32),
        stale_hist=M.stale_hist.at[_stale_bins(s)].add(1),
        comm_copies=M.comm_copies
        + jnp.sum(jnp.where(has, copies_pair, 0)).astype(jnp.int32),
    )


def metrics_summary(M: MetricsCarry, t_end: float,
                    n_minus_1_bound: bool = False) -> Dict[str, object]:
    """Drain the carry to host (one fetch) and derive the report fields.

    Returns a JSON-friendly dict: per-worker arrays as lists plus derived
    scalars — mean utilization (busy / (busy + idle)), mean staleness,
    per-worker virtual age since the last mix.  With ``n_minus_1_bound``
    (DSGD-AAU) the dict carries a ``staleness_bound`` sub-dict checking
    ``stale_max ≤ 2N − 4`` — the event-staleness bound induced by the
    per-epoch commit bound B ≤ N−1 (see the module docstring).
    """
    host = jax.device_get(M)
    n = int(host.grad_steps.shape[0])
    busy = np.asarray(host.busy_t, dtype=np.float64)
    idle = np.asarray(host.idle_t, dtype=np.float64)
    span = busy + idle
    util = np.divide(busy, span, out=np.zeros_like(busy), where=span > 0)
    grads_total = int(host.grad_steps.sum())
    out: Dict[str, object] = {
        "grad_steps": [int(v) for v in host.grad_steps],
        "mix_count": [int(v) for v in host.mix_count],
        "busy_t": [round(float(v), 6) for v in busy],
        "idle_t": [round(float(v), 6) for v in idle],
        "utilization": [round(float(v), 6) for v in util],
        "utilization_mean": float(util.mean()) if n else 0.0,
        "mix_age": [round(float(t_end) - float(v), 6)
                    for v in host.last_mix_t],
        "stale_max": int(host.stale_max),
        "stale_mean": (float(host.stale_sum) / grads_total
                       if grads_total else 0.0),
        "stale_hist": [int(v) for v in host.stale_hist],
        "comm_copies": int(host.comm_copies),
    }
    if n_minus_1_bound:
        bound = max(0, 2 * n - 4)
        out["staleness_bound"] = {
            "bound": bound,
            "edges_per_epoch_bound": max(0, n - 1),
            "observed_max": int(host.stale_max),
            "ok": bool(int(host.stale_max) <= bound),
        }
    return out
