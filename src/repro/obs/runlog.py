"""Structured JSONL run logging.

:class:`RunLogger` replaces bare ``warnings.warn`` / stderr prints with a
machine-readable event stream: one JSON object per line, each carrying an
``event`` tag plus free-form fields.  The trainer always owns a logger;
with no path it is a cheap no-op (a single attribute check per call), so
the hot dispatch loops can log unconditionally.

Events the trainer emits (the log schema, also documented in README):

``run_start``      n, mode, algorithm-ish metadata the caller passes
``block_dispatch`` mode, events, rounds — one per compiled block launch
``bucket_segment`` bucket (lane width), events, offset — bucketed path
``compile``        key — first-time build of a jitted block (cache miss)
``pool_wrap``      the batch-pool reuse warning (also a ``warnings.warn``)
``rng_order``      horizon-batcher RNG-order notice (log-only)
``staleness_bound`` DSGD-AAU runtime monitor result (ok / exceeded)
``run_end``        rounds, t, comm — final totals

Every record additionally carries ``ts`` — wall-clock seconds since the
logger was constructed (monotonic clock) — which is what lets
``python -m repro.obs.trace`` rebuild a wall-time Perfetto track
(per-block dispatch spans, per-rung segment spans, compile instants)
from the log alone.

``warn_once(key, message, warn=True)`` dedupes by key for the logger's
lifetime and forwards to :func:`warnings.warn` (stacklevel raised so the
caller's caller is blamed) — keeping the stderr contract tests rely on
while the JSONL file gets the structured copy.
"""
from __future__ import annotations

import json
import time
import warnings
from typing import IO, Optional, Set, Union


class RunLogger:
    """Append-only JSONL event log; no-op when constructed without a path."""

    def __init__(self, path: Optional[Union[str, IO[str]]] = None):
        self._fh: Optional[IO[str]] = None
        self._own = False
        if path is None:
            pass
        elif hasattr(path, "write"):
            self._fh = path                      # caller-owned stream
        else:
            self._fh = open(path, "a", encoding="utf-8")
            self._own = True
        self._seen: Set[str] = set()
        self._t0 = time.monotonic()

    @property
    def enabled(self) -> bool:
        return self._fh is not None

    def log(self, event: str, **fields) -> None:
        if self._fh is None:
            return
        rec = {"event": event,
               "ts": round(time.monotonic() - self._t0, 6)}
        rec.update(fields)
        self._fh.write(json.dumps(rec) + "\n")
        self._fh.flush()

    def warn_once(self, key: str, message: str, warn: bool = True) -> None:
        """Emit ``message`` at most once per run.

        Always recorded in the JSONL log (when enabled); additionally sent
        through :func:`warnings.warn` unless ``warn=False`` (notices that
        predate no stderr contract stay log-only).
        """
        if key in self._seen:
            return
        self._seen.add(key)
        self.log(key, message=message)
        if warn:
            warnings.warn(message, stacklevel=3)

    def close(self) -> None:
        if self._fh is not None and self._own:
            self._fh.close()
        self._fh = None
