"""Observability: device-resident telemetry, tracing + structured logging.

``repro.obs`` is the measurement layer the paper's argument needs at
runtime — per-worker staleness (Pathsearch's B ≤ N−1 bound, Remark 4),
gossip participation, busy/idle virtual time, and dtype-aware
communication accounting — implemented as a :class:`MetricsCarry` of
device accumulator arrays that rides the ``(W, S, y, ptr)`` scan carries
of every execution mode and is drained to host once per run (never per
event: after PR 7 fused generation and consumption into one compiled
scan, any per-event host sync would reintroduce the dispatch overhead
PRs 3–7 removed).

On top of the aggregate counters, the tracing layer
(:mod:`repro.obs.trace` + :mod:`repro.obs.critical_path`) buffers the
full event-identity stream under the same drain-once discipline and
reconstructs per-worker virtual-time timelines (Chrome Trace Event
Format, loadable in Perfetto), the event dependency DAG's critical path,
and a per-worker wait-blame decomposition — the "straggler tax" table
that quantifies what DSGD-AAU's adaptive neighbor count saves.

Around the device core, :class:`RunLogger` writes structured JSONL run
logs (block dispatches, bucket-rung choices, compile events, pool-wrap
warnings — every record wall-clock timestamped) replacing bare
``warnings.warn``, and ``jax.named_scope`` annotations on the kernels
and update bodies make ``--profile`` traces legible.
"""
from repro.obs.critical_path import (attribute_wait, critical_path,
                                     straggler_tax)
from repro.obs.metrics import (MetricsCarry, block_metrics_update,
                               dense_metrics_update, fused_metrics_fold,
                               init_metrics, metrics_summary,
                               sparse_metrics_update)
from repro.obs.runlog import RunLogger
from repro.obs.trace import (Trace, TraceRecorder, chrome_trace,
                             drain_fused_payload, load_run_log, wall_track)

__all__ = [
    "MetricsCarry", "RunLogger", "Trace", "TraceRecorder",
    "attribute_wait", "block_metrics_update", "chrome_trace",
    "critical_path", "dense_metrics_update", "drain_fused_payload",
    "fused_metrics_fold", "init_metrics", "load_run_log",
    "metrics_summary", "sparse_metrics_update", "straggler_tax",
    "wall_track",
]
