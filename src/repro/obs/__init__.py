"""Observability: device-resident telemetry + structured run logging.

``repro.obs`` is the measurement layer the paper's argument needs at
runtime — per-worker staleness (Pathsearch's B ≤ N−1 bound, Remark 4),
gossip participation, busy/idle virtual time, and dtype-aware
communication accounting — implemented as a :class:`MetricsCarry` of
device accumulator arrays that rides the ``(W, S, y, ptr)`` scan carries
of every execution mode and is drained to host once per run (never per
event: after PR 7 fused generation and consumption into one compiled
scan, any per-event host sync would reintroduce the dispatch overhead
PRs 3–7 removed).

Around the device core, :class:`RunLogger` writes structured JSONL run
logs (block dispatches, bucket-rung choices, compile events, pool-wrap
warnings) replacing bare ``warnings.warn``, and ``jax.named_scope``
annotations on the kernels and update bodies make ``--profile`` traces
legible.
"""
from repro.obs.metrics import (MetricsCarry, block_metrics_update,
                               dense_metrics_update, fused_metrics_fold,
                               init_metrics, metrics_summary,
                               sparse_metrics_update)
from repro.obs.runlog import RunLogger

__all__ = [
    "MetricsCarry", "RunLogger", "block_metrics_update",
    "dense_metrics_update", "fused_metrics_fold", "init_metrics",
    "metrics_summary", "sparse_metrics_update",
]
