"""Distributed step functions: decentralized train_step, serve_step, prefill_step.

``build_train_step`` produces the production DSGD-AAU update:

  1. per-worker forward/backward (remat-scanned layers, chunked CE) — workers
     stacked on the leading axis, vmapped; each worker sees its own non-iid
     batch shard (in_shardings place one worker per ``worker`` mesh slice);
  2. masked local SGD  W ← W − η·g  (paper eq. 4, plain SGD per worker);
  3. gossip mixing along the worker axis via ``lax.ppermute`` ring (+ an
     inter-pod edge on the multi-pod mesh) with step-dependent Metropolis
     weights streamed from the host scheduler — the paper's time-varying
     P(k) restricted to the physical ring/bridge topology.

Gossip weights are traced scalars, so the *same compiled step* serves every
AAU iteration: a zero weight deactivates an edge (the collective still moves
bytes — the dry-run therefore reports worst-case gossip traffic).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import TrainAxes
from repro.utils.compat import shard_map
from repro.models.transformer import decode_step as _decode
from repro.models.transformer import init_model, lm_loss
from repro.models.transformer import prefill as _prefill


def stacked_init(cfg: ModelConfig, n_workers: int):
    """init fn for worker-stacked parameters (same init across workers)."""
    def init(key):
        p = init_model(key, cfg)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_workers,) + x.shape), p)
    return init


def gossip_weights_spec():
    """Abstract gossip weights: (self, left, right, pod_gamma) f32 scalars."""
    s = jax.ShapeDtypeStruct((), jnp.float32)
    return {"self": s, "left": s, "right": s, "pod": s}


def default_gossip_weights(n_workers_per_pod: int, multi_pod: bool):
    if n_workers_per_pod >= 3:
        w = {"self": 1 / 3, "left": 1 / 3, "right": 1 / 3}
    elif n_workers_per_pod == 2:
        w = {"self": 0.5, "left": 0.25, "right": 0.25}
    else:
        w = {"self": 1.0, "left": 0.0, "right": 0.0}
    w["pod"] = 0.25 if multi_pod else 0.0
    return {k: jnp.float32(v) for k, v in w.items()}


def _tree_gossip(W, axes: TrainAxes, w_per_pod: int, weights):
    """Ring gossip over the worker axis + optional inter-pod edge.

    Runs under shard_map: leaves are local blocks with worker-axis size
    w_per_pod / mesh_size (=1 when fully sharded); ppermute moves whole
    blocks.  Mixing is linear and elementwise over parameters, so it commutes
    with the fsdp/model shardings of the replica (DESIGN.md §4).
    """
    n = w_per_pod
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [((i + 1) % n, i) for i in range(n)]

    # Doubly stochastic composition: out = (1−γ)·ring_mix + γ·other_pod_same_idx
    def mix2(x):
        dt = x.dtype
        ring = weights["self"].astype(dt) * x
        if n > 1:
            ring = ring + weights["left"].astype(dt) * jax.lax.ppermute(
                x, axes.worker, fwd)
            ring = ring + weights["right"].astype(dt) * jax.lax.ppermute(
                x, axes.worker, bwd)
        if axes.pod is not None:
            other = jax.lax.ppermute(x, axes.pod, [(0, 1), (1, 0)])
            g = weights["pod"].astype(dt)
            ring = (1 - g) * ring + g * other
        return ring

    return jax.tree.map(mix2, W)


def build_train_step(cfg: ModelConfig, n_workers: int, axes: TrainAxes,
                     mesh, param_specs, *, microbatch: int = 1,
                     logit_chunk: int = 512, remat: bool = True) -> Callable:
    """Returns train_step(W, batch, eta, gossip_weights) -> (W, loss)."""
    w_per_pod = n_workers // (2 if axes.pod else 1)

    def worker_loss(params, tokens, prefix):
        b = {"tokens": tokens}
        if prefix is not None:
            b["prefix"] = prefix
        return lm_loss(params, cfg, b, remat=remat, logit_chunk=logit_chunk)

    def worker_grad(params, tokens, prefix):
        if microbatch > 1:
            tb = tokens.reshape(microbatch, -1, tokens.shape[-1])
            pb = (prefix.reshape((microbatch, -1) + prefix.shape[1:])
                  if prefix is not None else None)

            def mb_body(carry, i):
                tot, acc = carry
                pf = pb[i] if pb is not None else None
                l, g = jax.value_and_grad(worker_loss)(params, tb[i], pf)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), acc, g)
                return (tot + l, acc), None

            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (tot, acc), _ = jax.lax.scan(
                mb_body, (jnp.float32(0), acc0), jnp.arange(microbatch))
            g = jax.tree.map(lambda a, p: (a / microbatch).astype(p.dtype),
                             acc, params)
            return tot / microbatch, g
        l, g = jax.value_and_grad(worker_loss)(params, tokens, prefix)
        return l, g

    gossip_sm = shard_map(
        lambda W, wt: _tree_gossip(W, axes, w_per_pod, wt),
        mesh=mesh, in_specs=(param_specs, P()), out_specs=param_specs,
        check_vma=False)

    def train_step(W, batch, eta, gossip_w):
        tokens = batch["tokens"]
        prefix = batch.get("prefix")
        if prefix is not None:
            losses, grads = jax.vmap(worker_grad)(W, tokens, prefix)
        else:
            losses, grads = jax.vmap(
                lambda p, t: worker_grad(p, t, None))(W, tokens)
        W = jax.tree.map(
            lambda w, g: (w - eta.astype(jnp.float32)
                          * g.astype(jnp.float32)).astype(w.dtype), W, grads)
        W = gossip_sm(W, gossip_w)
        return W, jnp.mean(losses)

    return train_step


def build_serve_step(cfg: ModelConfig) -> Callable:
    """serve_step(params, token, state, pos) -> (logits, new_state)."""
    def serve_step(params, token, state, pos):
        return _decode(params, cfg, token, state, pos)
    return serve_step


def build_prefill_step(cfg: ModelConfig, cache_len: int) -> Callable:
    """prefill_step(params, batch) -> (last logits, decode state)."""
    def prefill_step(params, batch):
        return _prefill(params, cfg, batch["tokens"], cache_len,
                        prefix_embeds=batch.get("prefix"))
    return prefill_step
