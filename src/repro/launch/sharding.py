"""Sharding policy: parameter/batch/cache PartitionSpecs for every arch.

A path-based rule engine assigns each parameter leaf a PartitionSpec over
(fsdp, model) — "contracting-in" matrices shard (fsdp → model), "projecting-
out" matrices shard (model → fsdp), expert stacks shard E over model when
divisible (expert parallelism), everything else falls back toward replication
when a dimension does not divide the axis size.  The same rules serve
training (fsdp axis = "fsdp" inside a worker replica) and serving (fsdp axis
= "data" — ZeRO-style fully-sharded inference).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


# matrices whose *first* matmul dim is the big contraction (out-projections)
_OUT_PROJ = ("wo", "w_down", "w_out", "w_v")   # w_v = rwkv channel-mix down-proj
_SMALL = ("ln", "norm", "bias", "mu_", "decay_w0", "lam", "bonus_u",
          "conv_kernel", "conv_bias", "b_a", "b_x", "router", "decay_A",
          "decay_B")


def _div(dim: int, mesh: Mesh, axis) -> Optional[object]:
    """axis if dim divides its (product) size and exists in the mesh, else
    None.  ``axis`` may be a name or a tuple of names (e.g. ("pod","data") —
    multi-pod serving treats both as one data-like axis)."""
    if axis is None:
        return None
    axes = axis if isinstance(axis, tuple) else (axis,)
    size = 1
    for a in axes:
        if a not in mesh.shape:
            return None
        size *= mesh.shape[a]
    return axis if dim % size == 0 else None


def leaf_spec(path_s: str, shape: Tuple[int, ...], mesh: Mesh,
              fsdp: Optional[str], model: str,
              stacked_layers: bool, embed_vocab_shard: bool = False) -> P:
    """PartitionSpec for one (un-worker-stacked) parameter leaf."""
    nd = len(shape)
    leading: Tuple[Optional[str], ...] = ()
    body = shape
    if stacked_layers and nd >= 3 and not any(s in path_s for s in ("embed", "head")):
        leading = (None,)            # layer-stack axis
        body = shape[1:]
        nd -= 1

    name = path_s.rsplit("/", 1)[-1]
    if any(s in path_s.rsplit("/", 2)[-1] or s in name for s in _SMALL) or nd <= 1:
        return P(*(leading + (None,) * nd))

    if nd == 3 and body[0] > 4:      # (E, d, f) expert stacks
        e_axis = _div(body[0], mesh, model)
        if e_axis:                   # expert parallel over model
            return P(*(leading + (e_axis, _div(body[1], mesh, fsdp), None)))
        # tensor-parallel within experts
        if name in _OUT_PROJ:
            return P(*(leading + (None, _div(body[1], mesh, model),
                                  _div(body[2], mesh, fsdp))))
        return P(*(leading + (None, _div(body[1], mesh, fsdp),
                              _div(body[2], mesh, model))))

    if nd == 2:
        if "embed" in path_s:
            if embed_vocab_shard:  # vocab-parallel: V over model, D over fsdp
                return P(*(leading + (_div(body[0], mesh, model),
                                      _div(body[1], mesh, fsdp))))
            return P(*(leading + (_div(body[0], mesh, fsdp),
                                  _div(body[1], mesh, model))))
        if "head" in path_s:
            if embed_vocab_shard:  # logits dim V over model
                return P(*(leading + (_div(body[0], mesh, fsdp),
                                      _div(body[1], mesh, model))))
            return P(*(leading + (_div(body[0], mesh, fsdp),
                                  _div(body[1], mesh, model))))
        if name in _OUT_PROJ:
            return P(*(leading + (_div(body[0], mesh, model),
                                  _div(body[1], mesh, fsdp))))
        return P(*(leading + (_div(body[0], mesh, fsdp),
                              _div(body[1], mesh, model))))

    return P(*(leading + (None,) * nd))


def param_pspecs(params_shapes, mesh: Mesh, *, fsdp: Optional[str], model: str,
                 worker_axes: Tuple[str, ...] = (),
                 embed_vocab_shard: bool = False):
    """Pytree of PartitionSpecs matching ``params_shapes`` (eval_shape output).

    ``worker_axes`` non-empty → leaves carry a leading worker-stack dim
    sharded over those axes (decentralized training state).
    """
    flat = jax.tree_util.tree_flatten_with_path(params_shapes)[0]
    # detect stacked layers: leaves under "layers/" with ndim>=3 share a leading L
    specs = {}
    for path, leaf in flat:
        ps = _path_str(path)
        stacked = ps.startswith("layers/") and not _is_unrolled(ps)
        shape = leaf.shape
        if worker_axes:
            shape = shape[1:]
        spec = leaf_spec(ps, shape, mesh, fsdp, model, stacked,
                         embed_vocab_shard=embed_vocab_shard)
        if worker_axes:
            spec = P(worker_axes if len(worker_axes) > 1 else worker_axes[0],
                     *tuple(spec))
        specs[ps] = spec
    # rebuild tree
    leaves = [specs[_path_str(p)] for p, _ in flat]
    treedef = jax.tree_util.tree_structure(params_shapes)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _is_unrolled(path_s: str) -> bool:
    # unrolled (hybrid) layers look like "layers/0/..." — numeric second part
    parts = path_s.split("/")
    return len(parts) > 1 and parts[1].isdigit()


def batch_pspec(batch_shapes, worker_axes: Tuple[str, ...],
                fsdp: Optional[str], seq_axis: Optional[str] = None):
    """Specs for a train batch shaped (n_workers, per_worker_batch, S, ...).

    Worker-stack dim over ``worker_axes``; per-worker batch over ``fsdp``;
    sequence dim optionally over ``seq_axis`` (sequence parallelism — shrinks
    the remat'd residual footprint by the model-axis size).
    """
    first = worker_axes if len(worker_axes) > 1 else worker_axes[0]

    def spec(leaf):
        nd = len(leaf.shape)
        rest = [fsdp, seq_axis] + [None] * max(0, nd - 3)
        return P(first, *rest[: nd - 1])

    return jax.tree.map(spec, batch_shapes)


def serve_pspecs(state_shapes, mesh: Mesh, *, data="data",
                 model: str = "model", batch_first: bool = True):
    """Specs for decode state: batch over data, heads (or head_dim) over model."""
    def spec(leaf):
        shape = leaf.shape
        nd = len(shape)
        out = [None] * nd
        # leading stacked-layer axis heuristics: (L, B, ...) when nd >= 3
        b_idx = 0
        if nd >= 2 and shape[0] <= 256 and nd >= 3:
            b_idx = 1
        if nd > b_idx:
            out[b_idx] = _div(shape[b_idx], mesh, data)
        # shard the largest remaining dim over model if divisible
        rest = [(i, s) for i, s in enumerate(shape) if i > b_idx]
        rest.sort(key=lambda t: -t[1])
        for i, s in rest:
            ax = _div(s, mesh, model)
            if ax:
                out[i] = ax
                break
        return P(*out)

    return jax.tree.map(spec, state_shapes)
