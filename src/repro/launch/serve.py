"""Serving launcher: batched prefill + decode on a device mesh.

Implements a minimal continuous-batching server: requests (token prompts)
queue up, are padded into a fixed decode batch, prefilled once, then decoded
step-by-step; finished sequences free their slots for queued requests.
``--demo`` runs a reduced config on CPU.

  python -m repro.launch.serve --arch qwen3-8b --demo --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Fixed-slot batched decoder around prefill/decode_step."""

    def __init__(self, cfg, params, batch_slots: int, cache_len: int):
        from repro.models.transformer import decode_step, prefill
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.cache_len = cache_len
        self._prefill = jax.jit(
            lambda p, t: prefill(p, cfg, t, cache_len))
        self._decode = jax.jit(
            lambda p, tok, st, pos: decode_step(p, cfg, tok, st, pos))

    def run(self, requests: List[Request], greedy: bool = True):
        """Sequentially admit requests in slot-sized waves (static batching)."""
        for i in range(0, len(requests), self.slots):
            wave = requests[i:i + self.slots]
            self._run_wave(wave)
        return requests

    def _run_wave(self, wave: List[Request]):
        B = len(wave)
        max_len = max(len(r.prompt) for r in wave)
        toks = np.zeros((B, max_len), np.int32)
        for j, r in enumerate(wave):
            toks[j, max_len - len(r.prompt):] = r.prompt  # left-pad
        logits, state = self._prefill(self.params, jnp.asarray(toks))
        pos = max_len
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        max_new = max(r.max_new for r in wave)
        for step in range(max_new):
            for j, r in enumerate(wave):
                if step < r.max_new:
                    r.out.append(int(cur[j]))
            logits, state = self._decode(self.params, cur, state,
                                         jnp.int32(pos))
            cur = jnp.argmax(logits, -1).astype(jnp.int32)
            pos += 1
        for r in wave:
            r.done = True


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--demo", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.models.transformer import init_model

    cfg = get_config(args.arch)
    if args.demo:
        cfg = cfg.reduced()
    params = init_model(jax.random.PRNGKey(args.seed), cfg)
    cache_len = args.cache_len or 256
    server = BatchedServer(cfg, params, args.slots, cache_len)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=rng.integers(4, 17)).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    server.run(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.out) for r in reqs)
    for r in reqs[:3]:
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.out[:8]}...")
    print(f"served {len(reqs)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
