"""Production meshes (TPU v5e target) and hierarchical worker views.

``make_production_mesh`` is the mandated entry point: 16×16 = 256 chips per
pod, 2 pods = 512 chips multi-pod.  Decentralized training additionally uses
a *derived view* of the same devices (DESIGN.md §4): the ``data`` axis splits
into (worker × fsdp) so that giant architectures keep fewer, internally-FSDP-
sharded replicas.  Functions only — importing this module never touches jax
device state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.utils.compat import auto_axis_types, make_mesh, mesh_from_devices


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=auto_axis_types(len(axes)))


@dataclasses.dataclass(frozen=True)
class TrainAxes:
    """Axis names of the (possibly hierarchical) training mesh view."""
    pod: Optional[str]      # "pod" on the multi-pod mesh, else None
    worker: str             # gossip axis
    fsdp: Optional[str]     # intra-worker parameter sharding, None if f == 1
    model: str              # tensor/expert parallel

    @property
    def worker_axes(self) -> Tuple[str, ...]:
        return ((self.pod,) if self.pod else ()) + (self.worker,)

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        out = self.worker_axes
        return out + ((self.fsdp,) if self.fsdp else ())


def hierarchical_view(mesh: Mesh, workers: int, fsdp: int) -> Tuple[Mesh, TrainAxes]:
    """Split the mesh's ``data`` axis into (worker, fsdp) — same devices.

    The physical device array is exactly the production mesh's; only the
    logical axis naming changes, so every dry-run still runs on the mandated
    16×16 / 2×16×16 topology.
    """
    names = mesh.axis_names
    devs = np.asarray(mesh.devices)
    data_size = mesh.shape["data"]
    if workers * fsdp != data_size:
        raise ValueError(f"workers*fsdp must equal data axis ({data_size}), "
                         f"got {workers}×{fsdp}")
    multi_pod = "pod" in names
    model = mesh.shape["model"]
    if multi_pod:
        new = devs.reshape(mesh.shape["pod"], workers, fsdp, model)
        new_names = ("pod", "worker", "fsdp", "model")
    else:
        new = devs.reshape(workers, fsdp, model)
        new_names = ("worker", "fsdp", "model")
    if fsdp == 1:
        new = new.squeeze(axis=-2)
        new_names = tuple(n for n in new_names if n != "fsdp")
    view = mesh_from_devices(new, new_names,
                             axis_types=auto_axis_types(len(new_names)))
    axes = TrainAxes(pod="pod" if multi_pod else None, worker="worker",
                     fsdp="fsdp" if fsdp > 1 else None, model="model")
    return view, axes


# Per-architecture (workers, fsdp) split of the 16-wide data axis, sized so a
# worker replica (params + grads + remat'd activations) fits v5e HBM.
# Rationale in EXPERIMENTS.md §Dry-run.
WORKER_FSDP: Dict[str, Tuple[int, int]] = {
    "deepseek-67b": (4, 4),
    "rwkv6-1.6b": (16, 1),
    "minicpm-2b": (16, 1),
    "musicgen-large": (16, 1),
    "grok-1-314b": (2, 8),
    "mistral-nemo-12b": (16, 1),
    "arctic-480b": (2, 8),
    "llava-next-mistral-7b": (16, 1),
    "recurrentgemma-2b": (16, 1),
    "qwen3-8b": (16, 1),
}

# Gradient-accumulation microbatches for activation-heavy train configs.
MICROBATCH: Dict[str, int] = {
    "deepseek-67b": 2,
    "grok-1-314b": 2,
    # arctic: fp32 grad-accumulation buffers (2x replica bytes/128 devices)
    # cost more than the activations microbatching saves — measured in
    # EXPERIMENTS.md §Perf; single batch + remat is strictly better.
}


def train_view(arch: str, *, multi_pod: bool = False) -> Tuple[Mesh, TrainAxes, int]:
    """(mesh view, axes, total workers) for an arch's training dry-run."""
    w, f = WORKER_FSDP.get(arch, (16, 1))
    base = make_production_mesh(multi_pod=multi_pod)
    view, axes = hierarchical_view(base, w, f)
    n_workers = w * (2 if multi_pod else 1)
    return view, axes, n_workers
