"""Roofline analysis of compiled dry-run artifacts.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body **once**,
so scan-over-layers programs under-report FLOPs/bytes by ~n_layers×
(verified: an unrolled 26-layer model matches its analytic FLOPs, a
95-layer scanned model reads ~78× low).  We therefore run our own cost
model over the optimized HLO text:

  * computations are parsed into symbol tables (op name → shape);
  * a call graph (fusion ``calls=``, ``while`` body/cond, conditionals)
    assigns every computation a trip multiplier — while trip counts are
    recovered from the loop-bound constant in the condition region;
  * FLOPs: 2·|result|·|contraction| for every ``dot`` (matmul FLOPs dominate
    all our programs; elementwise FLOPs are ignored, documented);
  * HBM bytes: 2× the produced bytes of every op at non-fusion level
    (each buffer is written once and read ≈once downstream) plus the entry
    parameters read once — a traffic *proxy* that stays exact-scale through
    while loops, where fusion-operand counting would bill the whole stacked
    weight array per layer instead of the dynamic-slice actually read;
  * collective bytes: operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute.

All values are per-device (the SPMD partition's program) — verified against
a hand-sharded matmul.

Hardware constants: TPU v5e — 197 TFLOP/s bf16/chip, 819 GB/s HBM,
~50 GB/s/link ICI (brief §Roofline).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\b([a-z][a-z0-9\-_]*)\(")
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")


def _dims(dims_s: str) -> int:
    n = 1
    if dims_s:
        for d in dims_s.split(","):
            n *= int(d)
    return n


def _first_shape(text: str) -> Optional[Tuple[str, str]]:
    m = _SHAPE_RE.search(text)
    return (m.group(1), m.group(2)) if m else None


def _all_shapes_bytes(text: str) -> int:
    return sum(_DTYPE_BYTES[dt] * _dims(ds) for dt, ds in _SHAPE_RE.findall(text))


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    line: str
    args: str                             # text after the opcode's "("
    shape: Optional[Tuple[str, str]]      # (dtype, dims) of result (first shape)
    result_bytes: int                     # total incl. tuple results


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op]
    symbols: Dict[str, Tuple[str, str]]


def parse_hlo(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s:
            continue
        if s.endswith("{") and ("->" in s or s.startswith(("ENTRY", "%"))):
            m = _HEADER_RE.match(s)
            if m:
                cur = Computation(name=m.group(2), is_entry=bool(m.group(1)),
                                  ops=[], symbols={})
                comps[cur.name] = cur
                continue
        if s.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        nm = _NAME_RE.match(s)
        if not nm:
            continue
        rest = s[nm.end():]
        om = _OPCODE_RE.search(rest)
        if not om:
            continue
        name = nm.group(1)
        opcode = om.group(1)
        head = rest[: om.start()]          # result type text
        shape = _first_shape(head)
        cur.ops.append(Op(name=name, opcode=opcode, line=s,
                          args=rest[om.end():], shape=shape,
                          result_bytes=_all_shapes_bytes(head)))
        if shape:
            cur.symbols[name] = shape
    return comps


# ---------------------------------------------------------------------------
# Call graph and trip multipliers
# ---------------------------------------------------------------------------

_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TFCOMP_RE = re.compile(r"(?:true|false)_computation=%?([\w\.\-]+)")


def _trip_count(cond: Computation, comps: Dict[str, Computation]) -> int:
    """Largest integer constant reachable in the condition region."""
    best = 1
    seen = set()

    def visit(c: Computation):
        if c.name in seen:
            return
        seen.add(c.name)
        nonlocal best
        for op in c.ops:
            for m in re.finditer(r"constant\((\d+)\)", op.line):
                best = max(best, int(m.group(1)))
            cm = _CALLS_RE.search(op.line)
            if cm and cm.group(1) in comps:
                visit(comps[cm.group(1)])

    visit(cond)
    return best


def multipliers(comps: Dict[str, Computation]) -> Tuple[Dict[str, float], Dict[str, bool]]:
    """(trip multiplier per computation, is-fusion-internal flag)."""
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    fusion_internal: Dict[str, bool] = {name: False for name in comps}
    if entry is None:
        return {name: 1.0 for name in comps}, fusion_internal

    # gather edges: (caller, callee, factor, via_fusion)
    edges: List[Tuple[str, str, float, bool]] = []
    for c in comps.values():
        for op in c.ops:
            wm = _WHILE_RE.search(op.line)
            if op.opcode == "while" and wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps[cond], comps) if cond in comps else 1
                edges.append((c.name, body, float(trips), False))
                edges.append((c.name, cond, float(trips), False))
                continue
            cm = _CALLS_RE.search(op.line)
            if cm and op.opcode == "fusion":
                edges.append((c.name, cm.group(1), 1.0, True))
            elif cm:
                edges.append((c.name, cm.group(1), 1.0, False))
            bm = _BRANCH_RE.search(op.line)
            if bm:
                branches = [b for b in re.findall(r"%?([\w\.\-]+)", bm.group(1))
                            if b in comps]
                # expected-value weighting: exactly one branch executes per
                # visit (the causal-frontier conditional in blockwise
                # attention would otherwise be double-counted)
                for b in branches:
                    edges.append((c.name, b, 1.0 / max(len(branches), 1), False))
            tf = list(_TFCOMP_RE.finditer(op.line))
            for tm in tf:
                edges.append((c.name, tm.group(1), 1.0 / max(len(tf), 1), False))

    mult[entry] = 1.0
    # propagate in topological-ish order (iterate to fixpoint; DAG, small)
    for _ in range(len(comps)):
        changed = False
        new = {name: 0.0 for name in comps}
        new[entry] = 1.0
        for caller, callee, f, via_fusion in edges:
            new[callee] += mult.get(caller, 0.0) * f
        for caller, callee, f, via_fusion in edges:
            if via_fusion:
                fusion_internal[callee] = True
        for name in comps:
            if abs(new[name] - mult[name]) > 1e-9:
                changed = True
        mult = new
        if not changed:
            break
    # fusion-internal propagates transitively
    for _ in range(4):
        for caller, callee, f, via in edges:
            if fusion_internal.get(caller):
                fusion_internal[callee] = True
    return mult, fusion_internal


# ---------------------------------------------------------------------------
# FLOPs / bytes / collectives
# ---------------------------------------------------------------------------

_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_SKIP_BYTES_OPS = {"parameter", "tuple", "get-tuple-element", "bitcast",
                   "constant", "after-all", "partition-id", "replica-id",
                   "iota",
                   # control ops whose "result" aliases carried buffers —
                   # their traffic happens inside their called computations
                   "while", "conditional", "call"}


def _dot_flops(op: Op, symbols: Dict[str, Tuple[str, str]]) -> float:
    if op.shape is None:
        return 0.0
    res = _dims(op.shape[1])
    m = _LHS_CONTRACT_RE.search(op.line)
    if not m:
        return 2.0 * res  # degenerate
    # The lhs operand is the first %name; older jax as_text() prefixes it
    # with an inline type ("dot(f32[4,64]{1,0} %x, ...)") which takes
    # priority over the symbol table.
    nm = re.search(r"%([\w\.\-]+)", op.args)
    lhs = None
    if nm is not None:
        lhs = _first_shape(op.args[:nm.start()]) or symbols.get(nm.group(1))
    if lhs is None:
        return 2.0 * res
    lhs_dims = [int(d) for d in lhs[1].split(",")] if lhs[1] else []
    contract = 1
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(lhs_dims):
            contract *= lhs_dims[idx]
    return 2.0 * res * contract


def _fusion_dus_bytes(comp: Optional[Computation]) -> Optional[int]:
    """If a fusion's root is (a bitcast of) dynamic-update-slice, the bytes
    of the update operand; else None."""
    if comp is None or not comp.ops:
        return None
    root = comp.ops[-1]
    target = root
    if root.opcode in ("bitcast", "convert") and root.args:
        nm = root.args.split(")", 1)[0].strip().lstrip("%")
        for op in comp.ops:
            if op.name == nm:
                target = op
                break
    for op in (target, *comp.ops[::-1]):
        if op.opcode == "dynamic-update-slice":
            names = re.findall(r"%([\w\.\-]+)", op.args.split(")", 1)[0])
            if len(names) > 1:
                upd = comp.symbols.get(names[1])
                if upd:
                    return _DTYPE_BYTES[upd[0]] * _dims(upd[1])
            return None
    return None


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, float]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


@dataclasses.dataclass
class HloCost:
    flops: float
    hbm_bytes: float
    collectives: CollectiveStats


def analyze_hlo_text(hlo: str) -> HloCost:
    comps = parse_hlo(hlo)
    mult, fusion_internal = multipliers(comps)
    flops = 0.0
    hbm = 0.0
    coll_bytes = {k: 0.0 for k in _COLLECTIVES}
    coll_count = {k: 0.0 for k in _COLLECTIVES}
    for c in comps.values():
        m = mult.get(c.name, 0.0)
        if m == 0.0:
            continue
        for op in c.ops:
            if op.opcode == "dot":
                flops += m * _dot_flops(op, c.symbols)
            if fusion_internal.get(c.name):
                continue
            if op.opcode == "parameter" and c.is_entry:
                hbm += op.result_bytes          # inputs read once per step
                continue
            if op.opcode in _SKIP_BYTES_OPS:
                continue
            if op.opcode == "dynamic-update-slice":
                # in-place aliased: traffic is the update operand, not the
                # full result buffer
                names = re.findall(r"%([\w\.\-]+)", op.args.split(")", 1)[0])
                upd = c.symbols.get(names[1]) if len(names) > 1 else None
                b = _DTYPE_BYTES[upd[0]] * _dims(upd[1]) if upd else op.result_bytes
                hbm += m * 2.0 * b
                continue
            if op.opcode == "fusion":
                # fusions rooted in a dynamic-update-slice alias their output
                # buffer: bill the updated slice, not the whole buffer
                cm = _CALLS_RE.search(op.line)
                dus = _fusion_dus_bytes(comps.get(cm.group(1))) if cm else None
                if dus is not None:
                    hbm += m * 2.0 * dus
                    continue
            hbm += m * 2.0 * op.result_bytes    # write + downstream read
            base = op.opcode.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not op.opcode.endswith("-done"):
                operand_bytes = 0
                arg_head = op.args.split(")", 1)[0]
                for nm2 in re.findall(r"%([\w\.\-]+)", arg_head):
                    sh = c.symbols.get(nm2)
                    if sh:
                        operand_bytes += _DTYPE_BYTES[sh[0]] * _dims(sh[1])
                if operand_bytes == 0:
                    operand_bytes = op.result_bytes
                coll_bytes[base] += m * operand_bytes
                coll_count[base] += m
    return HloCost(flops=flops, hbm_bytes=hbm,
                   collectives=CollectiveStats(coll_bytes, coll_count))


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Roofline:
    """Per-device-per-step seconds for the three roofline terms (values are
    the SPMD partition's — numerically equal to global/(chips·peak))."""
    flops: float                 # matmul FLOPs per device per step
    hbm_bytes: float             # HBM traffic proxy per device per step
    coll_bytes: float            # collective operand bytes per device per step
    n_devices: int
    model_flops: float = 0.0     # 6·N·D analytic (global)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def finalize(self) -> "Roofline":
        self.compute_s = self.flops / PEAK_FLOPS
        self.memory_s = self.hbm_bytes / HBM_BW
        self.collective_s = self.coll_bytes / ICI_BW
        return self

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        tot = self.flops * self.n_devices
        return self.model_flops / tot if tot else 0.0


def analyze(compiled, n_devices: int, model_flops: float) -> Tuple[Roofline, CollectiveStats]:
    cost = analyze_hlo_text(compiled.as_text())
    rl = Roofline(flops=cost.flops, hbm_bytes=cost.hbm_bytes,
                  coll_bytes=cost.collectives.total_bytes,
                  n_devices=n_devices, model_flops=model_flops).finalize()
    return rl, cost.collectives


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Back-compat helper used by tests."""
    return analyze_hlo_text(hlo_text).collectives
