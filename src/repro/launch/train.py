"""Production training launcher: decentralized DSGD-AAU on a device mesh.

Runs the pjit/shard_map train_step from launch/steps.py in a loop with the
host-side AAU scheduler streaming gossip weights, the token data pipeline,
and periodic checkpointing.  ``--demo`` shrinks everything (reduced config,
tiny mesh) so the same driver runs end-to-end on CPU; on a TPU pod the same
code paths run the production mesh.

  python -m repro.launch.train --arch qwen3-8b --demo --steps 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.utils.compat import auto_axis_types, make_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--eta", type=float, default=0.05)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--demo", action="store_true",
                    help="reduced config on a small CPU mesh")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--straggler-prob", type=float, default=0.1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.core.straggler import StragglerModel
    from repro.data.pipeline import TokenStream, TokenStreamConfig
    from repro.launch import sharding as S
    from repro.launch import shapes as SH
    from repro.launch import steps as ST
    from repro.launch.mesh import (MICROBATCH, TrainAxes, hierarchical_view,
                                   make_production_mesh, train_view)

    cfg = get_config(args.arch)
    if args.demo:
        cfg = cfg.reduced()
        n_dev = jax.device_count()
        model_par = 2 if n_dev % 2 == 0 and n_dev > 1 else 1
        data_par = max(1, n_dev // model_par)
        base = make_mesh((data_par, model_par), ("data", "model"),
                         axis_types=auto_axis_types(2))
        workers = args.workers or data_par
        fsdp = data_par // workers
        mesh, axes = hierarchical_view(base, workers, max(1, fsdp))
        n_workers = workers
        seq = args.seq or 64
        gb = args.global_batch or max(n_workers * 2, 4)
        microbatch = 1
    else:
        mesh, axes, n_workers = train_view(args.arch, multi_pod=args.multipod)
        seq = args.seq or 4096
        gb = args.global_batch or 256
        microbatch = MICROBATCH.get(args.arch, 1)

    shape = SH.InputShape("train_cli", "train", seq, gb)
    params_init = ST.stacked_init(cfg, n_workers)
    params_sds = jax.eval_shape(params_init, jax.random.PRNGKey(0))
    pspecs = S.param_pspecs(params_sds, mesh, fsdp=axes.fsdp, model=axes.model,
                            worker_axes=axes.worker_axes)
    batch_sds, batch_specs = SH.train_input_specs(cfg, shape, n_workers, axes)
    ns = lambda spec: jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                                   is_leaf=lambda x: isinstance(x, P))
    step = ST.build_train_step(cfg, n_workers, axes, mesh, pspecs,
                               microbatch=microbatch,
                               logit_chunk=min(512, max(seq // 4, 16)))
    gw0 = ST.default_gossip_weights(n_workers // (2 if axes.pod else 1),
                                    axes.pod is not None)
    jitted = jax.jit(
        step,
        in_shardings=(ns(pspecs), ns(batch_specs), NamedSharding(mesh, P()),
                      jax.tree.map(lambda _: NamedSharding(mesh, P()), gw0)),
        out_shardings=(ns(pspecs), NamedSharding(mesh, P())),
        donate_argnums=(0,),
    )

    stream = TokenStream(TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=gb,
        n_workers=n_workers))
    rng = np.random.default_rng(0)

    with mesh:
        W = jax.jit(params_init, out_shardings=ns(pspecs))(jax.random.PRNGKey(0))
        ckpt = None
        if args.ckpt_dir:
            from repro.checkpoint import Checkpointer
            ckpt = Checkpointer(args.ckpt_dir)
        for k in range(args.steps):
            # AAU adaptivity: edges whose endpoint straggles this round carry
            # zero weight (the worker keeps computing; its mass stays put).
            gw = dict(gw0)
            if rng.random() < args.straggler_prob:
                gw = {**gw0, "left": jnp.float32(0.0),
                      "right": jnp.float32(0.0),
                      "self": jnp.float32(1.0)}
            toks = np.stack([
                np.asarray(stream.worker_batch(w)["tokens"])
                for w in range(n_workers)])
            batch = {"tokens": jax.device_put(jnp.asarray(toks),
                                              ns(batch_specs)["tokens"])}
            if cfg.frontend:
                pf = jnp.zeros((n_workers, gb // n_workers,
                                cfg.n_prefix_tokens, cfg.d_model), cfg.cdtype)
                batch["prefix"] = jax.device_put(pf, ns(batch_specs)["prefix"])
            t0 = time.time()
            W, loss = jitted(W, batch, jnp.float32(args.eta), gw)
            loss = float(loss)
            print(f"step {k:4d} loss {loss:.4f}  ({time.time()-t0:.2f}s)")
            if ckpt and args.ckpt_every and (k + 1) % args.ckpt_every == 0:
                ckpt.save(k + 1, jax.device_get(W),
                          extra={"stream": {"cursor": stream.state_dict()["cursor"].tolist()}})
    print("done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
