import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input shape × mesh).

Proves the distribution config is coherent without hardware: 512 placeholder
host devices build the production meshes; every step function is lowered with
ShapeDtypeStruct inputs (no allocation), compiled, and its memory_analysis /
cost_analysis / collective schedule recorded for EXPERIMENTS.md §Dry-run and
§Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k [--multipod]
  python -m repro.launch.dryrun --all [--multipod] --out experiments/dryrun
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def _mesh_contexts(arch: str, multi_pod: bool):
    from repro.launch import mesh as M
    view, axes, n_workers = M.train_view(arch, multi_pod=multi_pod)
    serve_mesh = M.make_production_mesh(multi_pod=multi_pod)
    return view, axes, n_workers, serve_mesh


def _make_attn_hint(mesh, batch_axis="data", head_axis="model"):
    """with_sharding_constraint hook for attention internals (layers._hint).

    batch_axis=None → leave the batch dim unconstrained (train views whose
    per-worker batch is not sharded within the worker)."""

    def _size(ax):
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return n

    def hint(x, dims):
        spec = [None] * len(dims)
        # prefer sharding heads over the model axis; when the head count
        # doesn't divide (e.g. minicpm's 36 heads on a 16-wide axis) fall
        # back to sharding the q/sequence-chunk dim over the same axis --
        # attention and CE rows are independent per q position.
        placed = False
        for i, ch in enumerate(dims):
            if ch == "h" and x.shape[i] % _size(head_axis) == 0:
                spec[i] = head_axis
                placed = True
                break
        if not placed:
            for i, ch in enumerate(dims):
                if ch == "q" and x.shape[i] % _size(head_axis) == 0:
                    spec[i] = head_axis
                    break
        for i, ch in enumerate(dims):
            if (ch == "b" and batch_axis is not None
                    and x.shape[i] % _size(batch_axis) == 0):
                spec[i] = batch_axis
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))

    return hint


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            seq_shard: bool = True, attn_hint: bool = True,
            embed_vocab_shard: bool = False,
            verbose: bool = True) -> dict:
    from repro.configs import get_config
    from repro.launch import hlo_analysis as H
    from repro.launch import sharding as S
    from repro.launch import shapes as SH
    from repro.launch import steps as ST
    from repro.launch.mesh import MICROBATCH
    from repro.models import layers as L
    from repro.models.transformer import active_param_count

    shape = SH.SHAPES[shape_name]
    cfg = SH.shape_config(get_config(arch), shape)
    t0 = time.time()
    view, axes, n_workers, serve_mesh = _mesh_contexts(arch, multi_pod)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "n_devices": 512 if multi_pod else 256}

    if shape.kind == "train":
        mesh = view
        params_sds = jax.eval_shape(ST.stacked_init(cfg, n_workers),
                                    jax.random.PRNGKey(0))
        pspecs = S.param_pspecs(params_sds, mesh, fsdp=axes.fsdp,
                                model=axes.model, worker_axes=axes.worker_axes,
                                embed_vocab_shard=embed_vocab_shard)
        batch_sds, batch_specs = SH.train_input_specs(
            cfg, shape, n_workers, axes, seq_shard=seq_shard)
        mb = MICROBATCH.get(arch, 1)
        # CE-chunk sized so one chunk's fp32 logits stay under ~0.5 GiB per
        # worker (the live-buffer peak is a few chunks deep in backward)
        bw = shape.global_batch // n_workers // mb
        budget = int(0.5e9 / max(bw * cfg.vocab_size * 4, 1))
        logit_chunk = max(32, min(512, 1 << max(budget, 1).bit_length() - 1))
        step = ST.build_train_step(cfg, n_workers, axes, mesh, pspecs,
                                   microbatch=mb, logit_chunk=logit_chunk)
        ns = lambda spec: jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                                       is_leaf=lambda x: isinstance(x, P))
        gw = ST.gossip_weights_spec()
        jitted = jax.jit(
            step,
            in_shardings=(ns(pspecs), ns(batch_specs),
                          NamedSharding(mesh, P()),
                          jax.tree.map(lambda _: NamedSharding(mesh, P()), gw)),
            out_shardings=(ns(pspecs), NamedSharding(mesh, P())),
            donate_argnums=(0,),
        )
        if attn_hint:
            L.set_attention_shard_hint(
                _make_attn_hint(mesh, batch_axis=axes.fsdp, head_axis=axes.model))
        try:
            with mesh:
                lowered = jitted.lower(params_sds, batch_sds,
                                       jax.ShapeDtypeStruct((), jnp.float32), gw)
                compiled = lowered.compile()
        finally:
            L.set_attention_shard_hint(None)
        tokens_per_step = shape.global_batch * shape.seq_len
        # MODEL_FLOPS: 6·N_active·D tokens per *worker step*; all workers step.
        model_flops = 6.0 * active_param_count(cfg) * tokens_per_step
    elif shape.kind == "prefill":
        mesh = serve_mesh
        from repro.models.transformer import init_model
        params_sds = jax.eval_shape(
            lambda k: init_model(k, cfg), jax.random.PRNGKey(0))
        da = ("pod", "data") if multi_pod else "data"
        pspecs = S.param_pspecs(params_sds, mesh, fsdp=da, model="model")
        batch_sds, batch_specs = SH.prefill_input_specs(cfg, shape, mesh)
        step = ST.build_prefill_step(cfg, cache_len=shape.seq_len)
        ns = lambda spec: jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                                       is_leaf=lambda x: isinstance(x, P))
        jitted = jax.jit(step, in_shardings=(ns(pspecs), ns(batch_specs)))
        if attn_hint:
            L.set_attention_shard_hint(_make_attn_hint(mesh, batch_axis=da))
        try:
            with mesh:
                lowered = jitted.lower(params_sds, batch_sds)
                compiled = lowered.compile()
        finally:
            L.set_attention_shard_hint(None)
        model_flops = (2.0 * active_param_count(cfg)
                       * shape.global_batch * shape.seq_len)
    else:  # decode
        mesh = serve_mesh
        from repro.models.transformer import init_model
        params_sds = jax.eval_shape(
            lambda k: init_model(k, cfg), jax.random.PRNGKey(0))
        da = ("pod", "data") if multi_pod else "data"
        pspecs = S.param_pspecs(params_sds, mesh, fsdp=da, model="model")
        inp, specs = SH.decode_input_specs(cfg, shape, mesh)
        step = ST.build_serve_step(cfg)
        ns = lambda spec: jax.tree.map(
            lambda s: NamedSharding(mesh, s), spec,
            is_leaf=lambda x: isinstance(x, P))
        jitted = jax.jit(step, in_shardings=(ns(pspecs), ns(specs["token"]),
                                             ns(specs["state"]),
                                             NamedSharding(mesh, P())))
        with mesh:
            lowered = jitted.lower(params_sds, inp["token"], inp["state"],
                                   inp["pos"])
            compiled = lowered.compile()
        model_flops = 2.0 * active_param_count(cfg) * shape.global_batch

    mem = compiled.memory_analysis()
    rl, coll = H.analyze(compiled, rec["n_devices"], model_flops)
    rec.update(
        compile_s=round(time.time() - t0, 1),
        argument_bytes_per_device=getattr(mem, "argument_size_in_bytes", None),
        output_bytes_per_device=getattr(mem, "output_size_in_bytes", None),
        temp_bytes_per_device=getattr(mem, "temp_size_in_bytes", None),
        peak_bytes_per_device=(
            (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0)),
        flops=rl.flops, hbm_bytes=rl.hbm_bytes, coll_bytes=rl.coll_bytes,
        model_flops=model_flops,
        compute_s=rl.compute_s, memory_s=rl.memory_s,
        collective_s=rl.collective_s, dominant=rl.dominant,
        useful_flops_ratio=rl.useful_flops_ratio,
        coll_bytes_by_kind=coll.bytes_by_kind,
        coll_count_by_kind=coll.count_by_kind,
    )
    if verbose:
        print(json.dumps(rec, indent=None, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro.configs import ASSIGNED
    from repro.launch.shapes import SHAPES

    pairs = ([(args.arch, args.shape)] if not args.all else
             [(a, s) for a in ASSIGNED for s in SHAPES])
    results = []
    for arch, shape in pairs:
        try:
            results.append(run_one(arch, shape, multi_pod=args.multipod,
                                   seq_shard=not args.no_seq_shard))
        except Exception as e:  # record the failure — it is a bug to fix
            traceback.print_exc()
            results.append({"arch": arch, "shape": shape,
                            "mesh": "2x16x16" if args.multipod else "16x16",
                            "error": repr(e)})
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        tag = "multi" if args.multipod else "single"
        path = os.path.join(args.out, f"dryrun_{tag}.json")
        with open(path, "w") as f:
            json.dump(results, f, indent=1, default=str)
        print("wrote", path)
    ok = sum(1 for r in results if "error" not in r)
    print(f"dry-run: {ok}/{len(results)} pairs compiled")
    return 0 if ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
