"""Launch layer: production meshes, sharding policy, dry-run, train/serve CLIs."""
