"""Assigned input shapes and abstract input specs for the dry-run.

``input_specs`` returns (ShapeDtypeStruct pytree, PartitionSpec pytree) for
every (architecture × input shape × mode) — weak-type-correct, shardable,
zero device allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import TrainAxes
from repro.launch.sharding import batch_pspec, serve_pspecs
from repro.models.transformer import init_decode_state


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
}

SWA_WINDOW = 8192  # rolling window for the long_500k variant on quadratic archs


def shape_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Arch variant actually lowered for this shape (SWA for long_500k)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return cfg.with_sliding_window(SWA_WINDOW)
    return cfg


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# Train inputs: batch stacked per worker — {tokens (nw, B_w, S), [prefix]}
# ---------------------------------------------------------------------------

def train_input_specs(cfg: ModelConfig, shape: InputShape, n_workers: int,
                      axes: TrainAxes, *, seq_shard: bool = True):
    if shape.global_batch % n_workers:
        raise ValueError(f"{shape.global_batch} batch !% {n_workers} workers")
    bw = shape.global_batch // n_workers
    batch = {"tokens": sds((n_workers, bw, shape.seq_len), jnp.int32)}
    if cfg.frontend:
        batch["prefix"] = sds((n_workers, bw, cfg.n_prefix_tokens, cfg.d_model),
                              cfg.cdtype)
    specs = batch_pspec(batch, axes.worker_axes, axes.fsdp,
                        seq_axis=axes.model if seq_shard else None)
    return batch, specs


# ---------------------------------------------------------------------------
# Serve inputs (decode): token (B,), state pytree, pos scalar
# ---------------------------------------------------------------------------

def _data_axes(mesh):
    """The data-like axis for serving: ("pod","data") on the multi-pod mesh."""
    return ("pod", "data") if "pod" in mesh.shape else "data"


def decode_input_specs(cfg: ModelConfig, shape: InputShape, mesh):
    B = shape.global_batch
    da = _data_axes(mesh)
    dsize = (mesh.shape["pod"] * mesh.shape["data"] if isinstance(da, tuple)
             else mesh.shape["data"])
    state = jax.eval_shape(
        lambda: init_decode_state(cfg, B, shape.seq_len, filled=True))
    token = sds((B,), jnp.int32)
    pos = sds((), jnp.int32)
    state_specs = serve_pspecs(state, mesh, data=da)
    token_spec = P(da) if B % dsize == 0 else (
        P("data") if B % mesh.shape["data"] == 0 else P())
    return ({"token": token, "state": state, "pos": pos},
            {"token": token_spec, "state": state_specs, "pos": P()})


# ---------------------------------------------------------------------------
# Prefill inputs: tokens (B, S) [+ prefix]
# ---------------------------------------------------------------------------

def prefill_input_specs(cfg: ModelConfig, shape: InputShape, mesh):
    B = shape.global_batch
    da = _data_axes(mesh)
    dsize = (mesh.shape["pod"] * mesh.shape["data"] if isinstance(da, tuple)
             else mesh.shape["data"])
    baxis = da if B % dsize == 0 else (
        "data" if B % mesh.shape["data"] == 0 else None)
    batch = {"tokens": sds((B, shape.seq_len), jnp.int32)}
    specs = {"tokens": P(baxis, "model")}
    if cfg.frontend:
        batch["prefix"] = sds((B, cfg.n_prefix_tokens, cfg.d_model), cfg.cdtype)
        specs["prefix"] = P(baxis, None, None)
    return batch, specs
