"""Pytree checkpointing (npz-based, dependency-free).

Supports the decentralized trainer's stacked worker state (save/restore the
full (N, …) stack or a single worker's slice — what a real deployment would
write per-host), plus data-pipeline cursors and step metadata.  Writes are
atomic (tmp + rename) and keep a bounded history.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:08d}.npz")

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
        flat = _flatten_with_paths(tree)
        # numpy's npz cannot store ml_dtypes (bfloat16 etc.): store the raw
        # bits and record the original dtype for restore.
        dtypes = {}
        for k, v in list(flat.items()):
            if v.dtype.name not in _NATIVE_DTYPES:
                dtypes[k] = v.dtype.name
                flat[k] = v.view(np.uint8).reshape(v.shape + (v.dtype.itemsize,))
        meta = {
            "step": step,
            "dtypes": dtypes,
            "extra": extra or {},
        }
        path = self._path(step)
        fd, tmp = tempfile.mkstemp(dir=self.directory, suffix=".npz")
        os.close(fd)
        np.savez(tmp, __meta__=json.dumps(meta, default=_json_default), **flat)
        os.replace(tmp, path)  # atomic publish
        self._gc()
        return path

    def restore(self, like: Any, step: Optional[int] = None) -> Tuple[Any, Dict]:
        """Restore into the structure of ``like`` (shapes must match)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        data = np.load(self._path(step), allow_pickle=False)
        meta = json.loads(str(data["__meta__"]))
        dtypes = meta.get("dtypes", {})
        leaves = []
        for path, leaf in jax.tree_util.tree_flatten_with_path(like)[0]:
            key = "/".join(_path_str(p) for p in path)
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = _undo_bits(data[key], dtypes.get(key))
            if arr.shape != np.asarray(leaf).shape:
                raise ValueError(f"{key}: shape {arr.shape} != {np.shape(leaf)}")
            leaves.append(jnp.asarray(arr, dtype=leaf.dtype if hasattr(leaf, 'dtype') else None))
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), leaves)
        return tree, meta.get("extra", {})

    def restore_worker_slice(self, like_single: Any, worker: int,
                             step: Optional[int] = None) -> Any:
        """Restore one worker's parameters from a stacked (N, …) checkpoint."""
        step = self.latest_step() if step is None else step
        data = np.load(self._path(step), allow_pickle=False)
        meta = json.loads(str(data["__meta__"]))
        dtypes = meta.get("dtypes", {})
        leaves = []
        for path, leaf in jax.tree_util.tree_flatten_with_path(like_single)[0]:
            key = "/".join(_path_str(p) for p in path)
            leaves.append(jnp.asarray(_undo_bits(data[key], dtypes.get(key))[worker]))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like_single), leaves)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def all_steps(self):
        out = []
        for f in os.listdir(self.directory):
            if f.startswith("ckpt_") and f.endswith(".npz"):
                out.append(int(f[5:-4]))
        return sorted(out)

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            os.remove(self._path(s))


_NATIVE_DTYPES = {"bool", "int8", "uint8", "int16", "uint16", "int32",
                  "uint32", "int64", "uint64", "float16", "float32",
                  "float64", "complex64", "complex128"}


def _undo_bits(arr: np.ndarray, dtype_name: Optional[str]) -> np.ndarray:
    if dtype_name is None:
        return arr
    import ml_dtypes
    dt = np.dtype(getattr(ml_dtypes, dtype_name))
    return arr.reshape(arr.shape[:-1] + (-1,)).view(dt).reshape(arr.shape[:-1])


def _json_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(type(o))
