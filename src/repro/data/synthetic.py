"""Synthetic datasets with controllable non-iid-ness across workers.

The paper trains on non-iid label-sharded CIFAR-10/MNIST (each worker holds a
few classes) and Shakespeare next-character text.  Offline we generate:

  * ``ClassificationData`` — Gaussian-mixture classification with the paper's
    label-sharding partitioner (sort by label, split into N/2 shards per
    class, each worker samples ``classes_per_worker`` classes) and a Dirichlet
    partitioner (the modern non-iid benchmark protocol).
  * ``CharLMData`` — Markov-chain character streams; each worker's chain has a
    distinct transition temperature → heterogeneous local distributions
    (ς > 0 in Assumption 5), standing in for per-speaker Shakespeare shards.

Everything is numpy-side and deterministic; batches convert to jnp on draw.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ClassificationData:
    n_workers: int
    d: int = 64
    n_classes: int = 10
    samples_per_worker: int = 512
    classes_per_worker: int = 5          # paper: 5 of 10 classes per worker
    partition: str = "label_shard"       # or "dirichlet" / "iid"
    dirichlet_alpha: float = 0.3
    noise: float = 0.5
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # class prototypes
        self.protos = rng.normal(size=(self.n_classes, self.d)).astype(np.float32)
        # per-worker class distributions
        if self.partition == "iid":
            probs = np.full((self.n_workers, self.n_classes), 1.0 / self.n_classes)
        elif self.partition == "dirichlet":
            probs = rng.dirichlet([self.dirichlet_alpha] * self.n_classes,
                                  size=self.n_workers)
        elif self.partition == "label_shard":
            probs = np.zeros((self.n_workers, self.n_classes))
            for w in range(self.n_workers):
                classes = rng.choice(self.n_classes,
                                     size=min(self.classes_per_worker, self.n_classes),
                                     replace=False)
                probs[w, classes] = 1.0 / len(classes)
        else:
            raise ValueError(self.partition)
        self.class_probs = probs
        self._worker_data: Dict[int, tuple] = {}
        for w in range(self.n_workers):
            r = np.random.default_rng(self.seed * 7919 + w)
            labels = r.choice(self.n_classes, size=self.samples_per_worker,
                              p=probs[w])
            x = (self.protos[labels]
                 + self.noise * r.normal(size=(self.samples_per_worker, self.d))
                 ).astype(np.float32)
            self._worker_data[w] = (x, labels.astype(np.int32))

    def batch(self, worker: int, step: int, batch_size: int = 64):
        x, y = self._worker_data[worker]
        r = np.random.default_rng((self.seed, worker, step))
        idx = r.integers(0, len(y), size=batch_size)
        return {"x": jnp.asarray(x[idx]), "y": jnp.asarray(y[idx])}

    def eval_batch(self, batch_size: int = 1024):
        """Held-out iid batch from the global mixture."""
        r = np.random.default_rng(self.seed + 123456)
        labels = r.choice(self.n_classes, size=batch_size)
        x = (self.protos[labels]
             + self.noise * r.normal(size=(batch_size, self.d))).astype(np.float32)
        return {"x": jnp.asarray(x), "y": jnp.asarray(labels.astype(np.int32))}

    def heterogeneity(self) -> float:
        """TV distance of worker label distributions from uniform (ς proxy)."""
        u = 1.0 / self.n_classes
        return float(np.mean(np.abs(self.class_probs - u).sum(1) / 2))


@dataclasses.dataclass
class CharLMData:
    n_workers: int
    vocab: int = 80
    seq_len: int = 64
    temperature_spread: float = 0.5     # worker-to-worker distribution shift
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        base = rng.normal(size=(self.vocab, self.vocab))
        self._trans: List[np.ndarray] = []
        for w in range(self.n_workers):
            temp = 1.0 + self.temperature_spread * (w / max(1, self.n_workers - 1) - 0.5)
            logits = base / temp + 0.1 * rng.normal(size=base.shape)
            p = np.exp(logits - logits.max(1, keepdims=True))
            self._trans.append(p / p.sum(1, keepdims=True))

    def _sample_stream(self, trans, rng, length):
        out = np.empty(length, dtype=np.int32)
        s = rng.integers(0, self.vocab)
        for t in range(length):
            out[t] = s
            s = rng.choice(self.vocab, p=trans[s])
        return out

    def batch(self, worker: int, step: int, batch_size: int = 16):
        rng = np.random.default_rng((self.seed, worker, step))
        toks = np.stack([
            self._sample_stream(self._trans[worker], rng, self.seq_len)
            for _ in range(batch_size)])
        return {"tokens": jnp.asarray(toks)}

    def eval_batch(self, batch_size: int = 32):
        rng = np.random.default_rng(self.seed + 999)
        avg = np.mean(np.stack(self._trans), axis=0)
        avg = avg / avg.sum(1, keepdims=True)
        toks = np.stack([
            self._sample_stream(avg, rng, self.seq_len) for _ in range(batch_size)])
        return {"tokens": jnp.asarray(toks)}
