from repro.data.pipeline import TokenStream, TokenStreamConfig
from repro.data.synthetic import CharLMData, ClassificationData
