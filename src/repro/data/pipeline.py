"""Token-stream data pipeline for the framework-scale training drivers.

Produces globally-sharded batches for the mesh runtime: each worker (data-axis
group) draws from its own document stream — the decentralized analogue of the
paper's per-worker local datasets — with deterministic, resumable cursors
(checkpointable alongside the model).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    n_workers: int = 1
    seed: int = 0
    zipf_s: float = 1.2          # token frequency skew
    worker_shift: float = 0.25   # per-worker distribution rotation (non-iid)


class TokenStream:
    """Deterministic synthetic token stream (Zipf unigram + worker shift)."""

    def __init__(self, cfg: TokenStreamConfig):
        self.cfg = cfg
        if cfg.global_batch % cfg.n_workers:
            raise ValueError("global_batch must divide evenly across workers")
        self.per_worker = cfg.global_batch // cfg.n_workers
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        self._base = 1.0 / ranks ** cfg.zipf_s
        self._cursor = np.zeros(cfg.n_workers, dtype=np.int64)

    def _probs(self, worker: int) -> np.ndarray:
        shift = int(self.cfg.worker_shift * worker * self.cfg.vocab_size
                    / max(1, self.cfg.n_workers))
        p = np.roll(self._base, shift)
        return p / p.sum()

    def worker_batch(self, worker: int, step: Optional[int] = None) -> Dict:
        step = int(self._cursor[worker]) if step is None else step
        self._cursor[worker] = step + 1
        rng = np.random.default_rng((self.cfg.seed, worker, step))
        toks = rng.choice(self.cfg.vocab_size, p=self._probs(worker),
                          size=(self.per_worker, self.cfg.seq_len))
        return {"tokens": jnp.asarray(toks.astype(np.int32))}

    def global_batch(self, step: Optional[int] = None) -> Dict:
        parts = [np.asarray(self.worker_batch(w, step)["tokens"])
                 for w in range(self.cfg.n_workers)]
        return {"tokens": jnp.asarray(np.concatenate(parts, axis=0))}

    # -- checkpointing ----------------------------------------------------
    def state_dict(self) -> Dict:
        return {"cursor": self._cursor.copy()}

    def load_state_dict(self, state: Dict) -> None:
        self._cursor = np.asarray(state["cursor"], dtype=np.int64).copy()

    def __iter__(self) -> Iterator[Dict]:
        while True:
            yield self.global_batch()
