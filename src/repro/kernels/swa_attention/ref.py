"""Pure-jnp oracle for sliding-window causal attention."""
import jax
import jax.numpy as jnp
import numpy as np


def swa_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      window: int, n_groups: int = 1) -> jax.Array:
    """q: (BH, T, dh); k, v: (BKV, T, dh), BH = BKV · n_groups."""
    BH, T, dh = q.shape
    kf = jnp.repeat(k, n_groups, axis=0)
    vf = jnp.repeat(v, n_groups, axis=0)
    s = jnp.einsum("htd,hsd->hts", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) / np.sqrt(dh)
    pos = jnp.arange(T)
    mask = (pos[None, :] <= pos[:, None]) & (pos[None, :] > pos[:, None] - window)
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hts,hsd->htd", p, vf.astype(jnp.float32)).astype(q.dtype)
