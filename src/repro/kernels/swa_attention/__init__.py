from repro.kernels.swa_attention import ops, ref
from repro.kernels.swa_attention.kernel import swa_attention_pallas
from repro.kernels.swa_attention.ops import swa_attention
from repro.kernels.swa_attention.ref import swa_attention_ref
