"""Pallas TPU kernel: sliding-window flash attention (prefill).

Used by the long-context path of the dense/audio/vlm archs and by
RecurrentGemma's local-attention blocks.  Online-softmax over k-blocks with
the *block-sparse band* optimization: for window w only the
``1 + ceil((w + bq − 1)/bk)`` diagonal k-blocks per q-block are visited, so
compute is O(T·w) instead of O(T²).

Grid: (B·H, q-blocks, band-offsets), band innermost (sequential) so the
accumulator / running-max / running-denominator scratch carries across the
band.  GQA is handled by the k/v index map (kv head = head // group).

VMEM per step: q(bq·dh) + k,v(2·bk·dh) + acc(bq·dh) + m,l — e.g.
bq=bk=256, dh=128 → ~0.6 MB, all f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                block_q: int, block_k: int, window: int, n_band: int,
                scale: float):
    qi = pl.program_id(1)
    off = pl.program_id(2)

    @pl.when(off == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    hi = (qi * block_q + block_q - 1) // block_k       # diagonal k-block
    kj = jnp.maximum(hi - off, 0)

    @pl.when(hi - off >= 0)
    def _step():
        q = q_ref[0].astype(jnp.float32)               # (bq, dh)
        k = k_ref[0].astype(jnp.float32)               # (bk, dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        pos_q = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        pos_k = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (pos_k <= pos_q) & (pos_k > pos_q - window)
        s = jnp.where(mask, s, -1e30)
        m_prev = m_ref[...]                            # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * corr
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(off == n_band - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def swa_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         window: int, n_groups: int = 1, block_q: int = 256,
                         block_k: int = 256, interpret: bool = False) -> jax.Array:
    """q: (BH, T, dh); k, v: (BKV, T, dh) with BH = BKV · n_groups.

    Heads are flattened into the leading dim batch-major (b·H + h) so
    kv index = bh // n_groups.  Causal + window-w mask; same-length
    self-attention (prefill).
    """
    BH, T, dh = q.shape
    BKV = k.shape[0]
    assert BH == BKV * n_groups, (BH, BKV, n_groups)
    assert T % block_q == 0 and T % block_k == 0, (T, block_q, block_k)
    n_band = 1 + int(np.ceil((window + block_q - 1) / block_k))
    n_band = min(n_band, T // block_k)
    grid = (BH, T // block_q, n_band)

    def q_map(bh, qi, off):
        return (bh, qi, 0)

    def kv_map(bh, qi, off):
        hi = (qi * block_q + block_q - 1) // block_k
        kj = jnp.maximum(hi - off, 0)
        return (bh // n_groups, kj, 0)

    kernel = functools.partial(
        _swa_kernel, block_q=block_q, block_k=block_k, window=window,
        n_band=n_band, scale=1.0 / np.sqrt(dh))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), q_map),
            pl.BlockSpec((1, block_k, dh), kv_map),
            pl.BlockSpec((1, block_k, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), q_map),
        out_shape=jax.ShapeDtypeStruct((BH, T, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, dh), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
