"""Jitted wrapper for swa_attention: (B, T, H, dh) interface + GQA + padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.swa_attention.kernel import swa_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit,
                   static_argnames=("window", "block_q", "block_k", "interpret"))
def swa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *, window: int,
                  block_q: int = 256, block_k: int = 256,
                  interpret: bool | None = None) -> jax.Array:
    """Sliding-window causal self-attention.

    q: (B, T, H, dh); k, v: (B, T, KV, dh) with H % KV == 0.  Returns
    (B, T, H, dh).  T is padded up to the block size (padded queries attend
    causally to real keys only; padded outputs are sliced away).
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, T, H, dh = q.shape
    KV = k.shape[2]
    assert H % KV == 0, (H, KV)
    groups = H // KV
    bq = min(block_q, T)
    bk = min(block_k, T)
    Tp = -(-T // max(bq, bk)) * max(bq, bk)
    if Tp != T:
        pad = ((0, 0), (0, Tp - T), (0, 0), (0, 0))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Tp, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, Tp, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, Tp, dh)
    out = swa_attention_pallas(qf, kf, vf, window=window, n_groups=groups,
                               block_q=bq, block_k=bk, interpret=interpret)
    out = out.reshape(B, H, Tp, dh).transpose(0, 2, 1, 3)
    return out[:, :T]
