"""Pure-jnp oracles for the gossip_mix kernels."""
import jax
import jax.numpy as jnp


def gossip_mix_ref(W: jax.Array, P: jax.Array) -> jax.Array:
    """out[j, d] = Σ_i P[i, j] · W[i, d]  ==  Pᵀ @ W."""
    return jnp.einsum("nd,nj->jd", W.astype(jnp.float32),
                      P.astype(jnp.float32)).astype(W.dtype)


def masked_gossip_ref(W: jax.Array, G: jax.Array, P: jax.Array,
                      scaled_mask: jax.Array) -> jax.Array:
    """out = Pᵀ · (W − diag(scaled_mask) · G) with scaled_mask = η·grad_mask."""
    stepped = W.astype(jnp.float32) - (
        scaled_mask.astype(jnp.float32)[:, None] * G.astype(jnp.float32))
    return jnp.einsum("nd,nj->jd", stepped,
                      P.astype(jnp.float32)).astype(W.dtype)


def gossip_mix_batched_ref(W: jax.Array, P: jax.Array) -> jax.Array:
    """out[e] = P[e]ᵀ @ W[e] for stacked (E, N, D) problems."""
    return jnp.einsum("end,enj->ejd", W.astype(jnp.float32),
                      P.astype(jnp.float32)).astype(W.dtype)
