"""Pure-jnp oracle for the gossip_mix kernel."""
import jax.numpy as jnp
import jax


def gossip_mix_ref(W: jax.Array, P: jax.Array) -> jax.Array:
    """out[j, d] = Σ_i P[i, j] · W[i, d]  ==  Pᵀ @ W."""
    return jnp.einsum("nd,nj->jd", W.astype(jnp.float32),
                      P.astype(jnp.float32)).astype(W.dtype)
