from repro.kernels.gossip_mix import ops, ref
from repro.kernels.gossip_mix.kernel import gossip_mix_pallas
from repro.kernels.gossip_mix.ops import gossip_mix
from repro.kernels.gossip_mix.ref import gossip_mix_ref
