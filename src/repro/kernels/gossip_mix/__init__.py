from repro.kernels.gossip_mix import ops, ref
from repro.kernels.gossip_mix.kernel import (gossip_mix_batched_pallas,
                                             gossip_mix_pallas,
                                             masked_gossip_pallas)
from repro.kernels.gossip_mix.ops import (gossip_mix, gossip_mix_batched,
                                          masked_gossip_mix)
from repro.kernels.gossip_mix.ref import (gossip_mix_batched_ref,
                                          gossip_mix_ref, masked_gossip_ref)
