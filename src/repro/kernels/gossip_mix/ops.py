"""Jitted wrapper for gossip_mix: shape guards, padding, CPU interpret fallback.

Handles arbitrary leaf shapes by flattening to (N, D), padding D up to the
lane-aligned tile and N up to the sublane boundary (padding P with identity
rows so padded workers mix with nobody).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gossip_mix.kernel import gossip_mix_pallas

_SUBLANE = 8


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def gossip_mix(W: jax.Array, P: jax.Array, *, block_d: int = 512,
               interpret: bool | None = None) -> jax.Array:
    """Mix worker-stacked parameters: out = Pᵀ·W for any W of shape (N, ...)."""
    if interpret is None:
        interpret = not _on_tpu()
    N = W.shape[0]
    orig_shape = W.shape
    flat = W.reshape(N, -1)
    D = flat.shape[1]
    Dp = -(-D // block_d) * block_d
    Np = -(-N // _SUBLANE) * _SUBLANE
    if Dp != D:
        flat = jnp.pad(flat, ((0, 0), (0, Dp - D)))
    if Np != N:
        flat = jnp.pad(flat, ((0, Np - N), (0, 0)))
        P = jnp.pad(P, ((0, Np - N), (0, Np - N)))
        P = P.at[jnp.arange(N, Np), jnp.arange(N, Np)].set(1.0)
    out = gossip_mix_pallas(flat, P.astype(flat.dtype), block_d=block_d,
                            interpret=interpret)
    return out[:N, :D].reshape(orig_shape)
