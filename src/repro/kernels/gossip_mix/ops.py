"""Jitted wrappers for the gossip_mix kernels: shape guards, padding, CPU
interpret fallback.

Handles arbitrary leaf shapes by flattening to (N, D), padding D up to the
lane-aligned tile and N up to the sublane boundary (padding P with identity
rows so padded workers mix with nobody).  ``masked_gossip_mix`` additionally
folds the per-event learning-rate/gradient mask into a second resident matrix
Q = diag(η·mask)·P so the scan body's whole event update is one kernel call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gossip_mix.kernel import (gossip_mix_batched_pallas,
                                             gossip_mix_pallas,
                                             masked_gossip_pallas)

_SUBLANE = 8


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _pad_P_identity(P: jax.Array, N: int, Np: int) -> jax.Array:
    """Pad P to (Np, Np) with identity rows: padded workers mix with nobody."""
    P = jnp.pad(P, ((0, Np - N), (0, Np - N)))
    return P.at[jnp.arange(N, Np), jnp.arange(N, Np)].set(1.0)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def gossip_mix(W: jax.Array, P: jax.Array, *, block_d: int = 512,
               interpret: bool | None = None) -> jax.Array:
    """Mix worker-stacked parameters: out = Pᵀ·W for any W of shape (N, ...)."""
    if interpret is None:
        interpret = not _on_tpu()
    N = W.shape[0]
    orig_shape = W.shape
    flat = W.reshape(N, -1)
    D = flat.shape[1]
    Dp = _pad_up(D, block_d)
    Np = _pad_up(N, _SUBLANE)
    if Dp != D:
        flat = jnp.pad(flat, ((0, 0), (0, Dp - D)))
    if Np != N:
        flat = jnp.pad(flat, ((0, Np - N), (0, 0)))
        P = _pad_P_identity(P, N, Np)
    with jax.named_scope("gossip_mix"):
        out = gossip_mix_pallas(flat, P.astype(flat.dtype), block_d=block_d,
                                interpret=interpret)
    return out[:N, :D].reshape(orig_shape)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def masked_gossip_mix(W: jax.Array, G: jax.Array, P: jax.Array,
                      scaled_mask: jax.Array, *, block_d: int = 512,
                      interpret: bool | None = None) -> jax.Array:
    """Fused event update: out = Pᵀ·(W − diag(scaled_mask)·G), any (N, ...) W.

    ``scaled_mask`` is η·grad_mask (length N); padded workers get zero mask
    and identity mixing, so padding never leaks into real rows.
    """
    if interpret is None:
        interpret = not _on_tpu()
    N = W.shape[0]
    orig_shape = W.shape
    flat_w = W.reshape(N, -1)
    flat_g = G.reshape(N, -1).astype(flat_w.dtype)
    D = flat_w.shape[1]
    Dp = _pad_up(D, block_d)
    Np = _pad_up(N, _SUBLANE)
    if Dp != D:
        flat_w = jnp.pad(flat_w, ((0, 0), (0, Dp - D)))
        flat_g = jnp.pad(flat_g, ((0, 0), (0, Dp - D)))
    if Np != N:
        flat_w = jnp.pad(flat_w, ((0, Np - N), (0, 0)))
        flat_g = jnp.pad(flat_g, ((0, Np - N), (0, 0)))
        P = _pad_P_identity(P, N, Np)
        scaled_mask = jnp.pad(scaled_mask, (0, Np - N))
    P = P.astype(flat_w.dtype)
    Q = scaled_mask.astype(flat_w.dtype)[:, None] * P
    with jax.named_scope("masked_gossip_mix"):
        out = masked_gossip_pallas(flat_w, flat_g, P, Q, block_d=block_d,
                                   interpret=interpret)
    return out[:N, :D].reshape(orig_shape)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def gossip_mix_batched(W: jax.Array, P: jax.Array, *, block_d: int = 512,
                       interpret: bool | None = None) -> jax.Array:
    """Stacked mixing problems: out[e] = P[e]ᵀ·W[e] for W of shape (E, N, ...)."""
    if interpret is None:
        interpret = not _on_tpu()
    E, N = W.shape[:2]
    orig_shape = W.shape
    flat = W.reshape(E, N, -1)
    D = flat.shape[2]
    Dp = _pad_up(D, block_d)
    Np = _pad_up(N, _SUBLANE)
    if Dp != D:
        flat = jnp.pad(flat, ((0, 0), (0, 0), (0, Dp - D)))
    if Np != N:
        flat = jnp.pad(flat, ((0, 0), (0, Np - N), (0, 0)))
        P = jnp.pad(P, ((0, 0), (0, Np - N), (0, Np - N)))
        P = P.at[:, jnp.arange(N, Np), jnp.arange(N, Np)].set(1.0)
    with jax.named_scope("gossip_mix_batched"):
        out = gossip_mix_batched_pallas(flat, P.astype(flat.dtype),
                                        block_d=block_d, interpret=interpret)
    return out[:, :N, :D].reshape(orig_shape)
