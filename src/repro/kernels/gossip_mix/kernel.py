"""Pallas TPU kernels: consensus gossip mixing  out = Pᵀ · W and variants.

The hot step of eq. (5): every worker's new parameters are a P-weighted
combination of all workers' parameters.  W is (N, D) with N = #workers (small,
≤ 128) and D = flattened parameter dimension (huge).  The kernel tiles D into
VMEM-resident blocks; the (N, N) consensus matrix stays resident across the
whole grid.  Each grid step issues one (N×N)·(N×Dt) MXU matmul — N is padded
to the 8-sublane boundary and Dt is a multiple of 128 lanes (ops.py pads).

VMEM budget per step: (2·N·Dt + N·N) · 4B — e.g. N=128, Dt=512 → 0.5 MB.

Three entry points share that tiling scheme:

- ``gossip_mix_pallas``:        out = Pᵀ·W                  (plain mixing)
- ``masked_gossip_pallas``:     out = Pᵀ·W − Qᵀ·G           (fused event step)
- ``gossip_mix_batched_pallas``: out[e] = P[e]ᵀ·W[e]        (stacked problems)

The masked form is the whole gradient-then-mix event update in one pass:
with Q = diag(η·grad_mask)·P it equals Pᵀ·(W − η·mask⊙G) without ever
materializing the masked-gradient intermediate — this is what the
``masked_gossip_scan`` block trainer (core/aau.py) runs per scan step.  The
batched form adds a leading grid axis over E independent (P, W) problems;
both preserve the resident-P / D-tiled MXU layout above.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gossip_kernel(p_ref, w_ref, o_ref):
    # p_ref: (N, N) consensus matrix; w_ref: (N, Dt) tile; o_ref: (N, Dt)
    p = p_ref[...]
    w = w_ref[...]
    o_ref[...] = jax.lax.dot_general(
        p, w,
        dimension_numbers=(((0,), (0,)), ((), ())),   # Pᵀ @ W
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def gossip_mix_pallas(W: jax.Array, P: jax.Array, *, block_d: int = 512,
                      interpret: bool = False) -> jax.Array:
    """W: (N, D) worker-stacked parameters; P: (N, N). D % block_d == 0."""
    N, D = W.shape
    assert P.shape == (N, N), (P.shape, N)
    assert D % block_d == 0, (D, block_d)
    grid = (D // block_d,)
    return pl.pallas_call(
        _gossip_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((N, N), lambda d: (0, 0)),        # P resident
            pl.BlockSpec((N, block_d), lambda d: (0, d)),  # W tile
        ],
        out_specs=pl.BlockSpec((N, block_d), lambda d: (0, d)),
        out_shape=jax.ShapeDtypeStruct((N, D), W.dtype),
        interpret=interpret,
    )(P, W)


def _masked_gossip_kernel(p_ref, q_ref, w_ref, g_ref, o_ref):
    # p_ref/q_ref: (N, N) resident; w_ref/g_ref: (N, Dt) tiles.
    # out = Pᵀ·W − Qᵀ·G, two MXU matmuls per tile.
    contract = (((0,), (0,)), ((), ()))
    mix = jax.lax.dot_general(p_ref[...], w_ref[...], dimension_numbers=contract,
                              preferred_element_type=jnp.float32)
    step = jax.lax.dot_general(q_ref[...], g_ref[...], dimension_numbers=contract,
                               preferred_element_type=jnp.float32)
    o_ref[...] = (mix - step).astype(o_ref.dtype)


def masked_gossip_pallas(W: jax.Array, G: jax.Array, P: jax.Array,
                         Q: jax.Array, *, block_d: int = 512,
                         interpret: bool = False) -> jax.Array:
    """Fused event step: Pᵀ·W − Qᵀ·G with Q = diag(η·mask)·P (see ops.py)."""
    N, D = W.shape
    assert G.shape == (N, D), (G.shape, W.shape)
    assert P.shape == (N, N) and Q.shape == (N, N), (P.shape, Q.shape)
    assert D % block_d == 0, (D, block_d)
    grid = (D // block_d,)
    return pl.pallas_call(
        _masked_gossip_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((N, N), lambda d: (0, 0)),        # P resident
            pl.BlockSpec((N, N), lambda d: (0, 0)),        # Q resident
            pl.BlockSpec((N, block_d), lambda d: (0, d)),  # W tile
            pl.BlockSpec((N, block_d), lambda d: (0, d)),  # G tile
        ],
        out_specs=pl.BlockSpec((N, block_d), lambda d: (0, d)),
        out_shape=jax.ShapeDtypeStruct((N, D), W.dtype),
        interpret=interpret,
    )(P, Q, W, G)


def _gossip_batched_kernel(p_ref, w_ref, o_ref):
    # p_ref: (1, N, N); w_ref: (1, N, Dt) — one event's problem per grid row.
    o_ref[0] = jax.lax.dot_general(
        p_ref[0], w_ref[0],
        dimension_numbers=(((0,), (0,)), ((), ())),   # P[e]ᵀ @ W[e]
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def gossip_mix_batched_pallas(W: jax.Array, P: jax.Array, *, block_d: int = 512,
                              interpret: bool = False) -> jax.Array:
    """W: (E, N, D) stacked problems; P: (E, N, N).  out[e] = P[e]ᵀ·W[e]."""
    E, N, D = W.shape
    assert P.shape == (E, N, N), (P.shape, W.shape)
    assert D % block_d == 0, (D, block_d)
    grid = (E, D // block_d)
    return pl.pallas_call(
        _gossip_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, N, N), lambda e, d: (e, 0, 0)),
            pl.BlockSpec((1, N, block_d), lambda e, d: (e, 0, d)),
        ],
        out_specs=pl.BlockSpec((1, N, block_d), lambda e, d: (e, 0, d)),
        out_shape=jax.ShapeDtypeStruct((E, N, D), W.dtype),
        interpret=interpret,
    )(P, W)
