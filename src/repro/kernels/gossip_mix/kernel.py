"""Pallas TPU kernel: consensus gossip mixing  out = Pᵀ · W.

The hot step of eq. (5): every worker's new parameters are a P-weighted
combination of all workers' parameters.  W is (N, D) with N = #workers (small,
≤ 128) and D = flattened parameter dimension (huge).  The kernel tiles D into
VMEM-resident blocks; the (N, N) consensus matrix stays resident across the
whole grid.  Each grid step issues one (N×N)·(N×Dt) MXU matmul — N is padded
to the 8-sublane boundary and Dt is a multiple of 128 lanes (ops.py pads).

VMEM budget per step: (2·N·Dt + N·N) · 4B — e.g. N=128, Dt=512 → 0.5 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gossip_kernel(p_ref, w_ref, o_ref):
    # p_ref: (N, N) consensus matrix; w_ref: (N, Dt) tile; o_ref: (N, Dt)
    p = p_ref[...]
    w = w_ref[...]
    o_ref[...] = jax.lax.dot_general(
        p, w,
        dimension_numbers=(((0,), (0,)), ((), ())),   # Pᵀ @ W
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def gossip_mix_pallas(W: jax.Array, P: jax.Array, *, block_d: int = 512,
                      interpret: bool = False) -> jax.Array:
    """W: (N, D) worker-stacked parameters; P: (N, N). D % block_d == 0."""
    N, D = W.shape
    assert P.shape == (N, N), (P.shape, N)
    assert D % block_d == 0, (D, block_d)
    grid = (D // block_d,)
    return pl.pallas_call(
        _gossip_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((N, N), lambda d: (0, 0)),        # P resident
            pl.BlockSpec((N, block_d), lambda d: (0, d)),  # W tile
        ],
        out_specs=pl.BlockSpec((N, block_d), lambda d: (0, d)),
        out_shape=jax.ShapeDtypeStruct((N, D), W.dtype),
        interpret=interpret,
    )(P, W)
