"""Pallas TPU kernel: active-set gossip mixing via gather → mix → (scatter).

The sparse counterpart of ``gossip_mix``: an asynchronous event touches only
the ``A`` workers named by its active-edge list (AD-PSGD/AGP touch 2 of N;
DSGD-AAU a finished subset), and every consensus matrix the schedulers emit
is identity outside that set.  Mixing therefore only needs the A×A submatrix
``P_sub`` and the A gathered worker rows — O(A²·D) work instead of the dense
kernel's O(N²·D), the factor that makes paper-scale N=256 streams cheap.

``sparse_gossip_pallas`` computes the *compact* mixed rows

    out[b] = Σ_a P_sub[a, b] · W[workers[a]]  −  Σ_a Q_sub[a, b] · G[a]

with the gather fused into the kernel: ``workers`` is a scalar-prefetch
operand (``pltpu.PrefetchScalarGridSpec``), so the BlockSpec index map DMAs
exactly the A active rows of W out of HBM — inactive rows are never read.
As with the dense ``masked_gossip`` kernel, Q = diag(η·grad_mask)·P_sub
folds the gradient step into the same pass: out = P_subᵀ·(W_a − η·mask⊙G).

Grid layout: ``(D // block_d, A)`` with the active-row axis innermost.  The
(A, block_d) output tile has a constant index over the inner axis, so it
stays VMEM-resident while each step accumulates one gathered row's
rank-1 contribution (P_sub[a, :] ⊗ W[workers[a]] tile).  P_sub/Q_sub stay
resident across the whole grid.

The *scatter* half of the gather-compute-scatter contract deliberately stays
outside the kernel (ops.py ``sparse_gossip_apply``): writing updated rows
back into a W-aliased output would race the gather reads of later grid steps
(every output row is also an input row of the mix), so ops scatters the
compact result with a deterministic ``.at[workers].set(..., mode="drop")``.

Padding contract (ops.py enforces it): padded lanes carry ``workers`` index 0
(any valid row — its contribution is annihilated) and all-zero P_sub/Q_sub
rows *and* columns, so they neither contribute to nor receive mass; their
compact output rows are exactly zero and the scatter drops them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sparse_gossip_kernel(workers_ref, p_ref, q_ref, w_ref, g_ref, o_ref):
    # workers_ref: (A,) scalar-prefetch (consumed by the index maps);
    # p_ref/q_ref: (A, A) resident; w_ref: (1, Dt) gathered row W[workers[a]];
    # g_ref: (1, Dt) compact gradient row a; o_ref: (A, Dt) resident tile.
    del workers_ref
    a = pl.program_id(1)

    @pl.when(a == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    contrib = (p_ref[a, :][:, None] * w_ref[...]
               - q_ref[a, :][:, None] * g_ref[...])
    o_ref[...] += contrib.astype(o_ref.dtype)


def sparse_gossip_pallas(W: jax.Array, G: jax.Array, P_sub: jax.Array,
                         Q_sub: jax.Array, workers: jax.Array, *,
                         block_d: int = 512,
                         interpret: bool = False) -> jax.Array:
    """Compact active-set mix: out = P_subᵀ·W[workers] − Q_subᵀ·G.

    W: (N, D) full worker-stacked state (only ``workers`` rows are read);
    G: (A, D) active-set gradients; P_sub/Q_sub: (A, A); workers: (A,) int32
    row indices in [0, N).  Returns the (A, D) mixed active rows.
    """
    N, D = W.shape
    A = workers.shape[0]
    assert G.shape == (A, D), (G.shape, (A, D))
    assert P_sub.shape == (A, A) and Q_sub.shape == (A, A), (
        P_sub.shape, Q_sub.shape)
    assert D % block_d == 0, (D, block_d)
    grid = (D // block_d, A)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((A, A), lambda d, a, workers: (0, 0)),  # P resident
            pl.BlockSpec((A, A), lambda d, a, workers: (0, 0)),  # Q resident
            # the gather: row a of the active set comes from W[workers[a]]
            pl.BlockSpec((1, block_d), lambda d, a, workers: (workers[a], d)),
            pl.BlockSpec((1, block_d), lambda d, a, workers: (a, d)),
        ],
        out_specs=pl.BlockSpec((A, block_d), lambda d, a, workers: (0, d)),
    )
    return pl.pallas_call(
        _sparse_gossip_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((A, D), W.dtype),
        interpret=interpret,
    )(workers, P_sub, Q_sub, W, G)


def _scatter_rows_kernel(workers_ref, rows_ref, x_ref, o_ref):
    # workers_ref: (A,) scalar-prefetch; x_ref / o_ref: the same (1, Dt)
    # window of the aliased carry at row max(workers[a], 0); rows_ref: the
    # compact row of lane a for valid lanes, of *worker 0's lane* for
    # padded lanes (see the index map).  A valid lane replaces its window
    # with its compact row.  A padded lane (workers[a] < 0, clamped to
    # row 0) must write row 0's *final* content back: that is the owning
    # lane's compact row when some valid lane carries worker 0 — wherever
    # that lane sits (merged block-diagonal rows interleave pads, so it
    # need not be lane 0) — else the gathered window.  Deciding from the
    # workers array rather than re-reading the carry keeps the kernel
    # correct whether the x gather observes the aliased buffer's updates
    # (TPU read-through) or a stale pre-kernel copy (interpret mode).
    a = pl.program_id(1)
    keep_rows = (workers_ref[a] >= 0) | jnp.any(workers_ref[...] == 0)
    o_ref[...] = jnp.where(keep_rows, rows_ref[...],
                           x_ref[...]).astype(o_ref.dtype)


def scatter_rows_pallas(X: jax.Array, rows: jax.Array, workers: jax.Array, *,
                        block_d: int = 512,
                        interpret: bool = False) -> jax.Array:
    """Scatter compact active-set rows into the carry, in place.

    The scatter half of the gather-compute-scatter contract, moved into the
    kernel: ``X`` (N, D) is **aliased to the output** (donated by the
    caller), so only the A windows named by ``workers`` are ever written —
    the other N−A rows are never touched, never copied, never DMA'd.  That
    replaces the XLA ``.at[workers].set``, whose lowering materializes a
    fresh (N, D) buffer per event — O(N·D) carry traffic for an O(A·D)
    logical update, the term that grows linearly with n and capped the
    sparse path's scaling (see BENCH_event_stream.json N≥128).

    Race-freedom: valid active-set indices are unique per event (disjoint
    across the blocks of a merged row), so the only repeated output window
    is the padded lanes' row-0 writes — and the kernel makes each of those
    re-write row 0's final content (see ``_scatter_rows_kernel``), so
    repetition is idempotent regardless of where pads sit in the lane axis
    (``merge_event_groups`` interleaves them between blocks).

    rows: (A, D) compact rows; workers: (A,) int32 with ``-1`` padding in
    any position.  Returns the updated (N, D) carry (the same buffer when
    donation applies).
    """
    N, D = X.shape
    A = workers.shape[0]
    assert rows.shape == (A, D), (rows.shape, (A, D))
    assert D % block_d == 0, (D, block_d)
    grid = (D // block_d, A)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            # padded lanes read worker 0's owning lane (the row-0 writeback
            # candidate; argmax is 0 when no lane carries worker 0, and the
            # kernel then keeps the gathered window instead)
            pl.BlockSpec((1, block_d),
                         lambda d, a, workers: (jnp.where(
                             workers[a] >= 0, a,
                             jnp.argmax(workers[...] == 0)
                             .astype(jnp.int32)),
                             d)),
            pl.BlockSpec((1, block_d),
                         lambda d, a, workers: (jnp.maximum(workers[a], 0), d)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_d), lambda d, a, workers: (jnp.maximum(workers[a], 0), d)),
    )
    return pl.pallas_call(
        _scatter_rows_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, D), X.dtype),
        # operand indices count the scalar-prefetch arg: (workers, rows, X)
        input_output_aliases={2: 0},
        interpret=interpret,
    )(workers, rows, X)
