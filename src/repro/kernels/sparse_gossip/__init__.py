from repro.kernels.sparse_gossip import ops, ref
from repro.kernels.sparse_gossip.kernel import (scatter_rows_pallas,
                                                sparse_gossip_pallas)
from repro.kernels.sparse_gossip.ops import (sparse_gossip_apply,
                                             sparse_gossip_rows,
                                             sparse_scatter_rows)
from repro.kernels.sparse_gossip.ref import (sparse_gossip_apply_ref,
                                             sparse_gossip_ref,
                                             sparse_scatter_rows_ref)
