"""Pure-jnp oracles for the sparse_gossip kernel."""
import jax
import jax.numpy as jnp


def sparse_gossip_ref(W: jax.Array, G: jax.Array, P_sub: jax.Array,
                      Q_sub: jax.Array, workers: jax.Array) -> jax.Array:
    """Compact active-set mix: out = P_subᵀ·W[workers] − Q_subᵀ·G.

    ``workers`` may carry ``-1`` padding: padded lanes are clamped to row 0
    and must come with all-zero P_sub/Q_sub rows and columns (the ops-layer
    contract), so they contribute and receive nothing.
    """
    idx = jnp.clip(workers, 0, W.shape[0] - 1)
    Wa = W[idx].astype(jnp.float32)
    out = (jnp.einsum("ad,ab->bd", Wa, P_sub.astype(jnp.float32))
           - jnp.einsum("ad,ab->bd", G.astype(jnp.float32),
                        Q_sub.astype(jnp.float32)))
    return out.astype(W.dtype)


def sparse_gossip_apply_ref(W: jax.Array, G: jax.Array, P_sub: jax.Array,
                            scaled_mask: jax.Array,
                            workers: jax.Array) -> jax.Array:
    """Full-state oracle: gather → mix → scatter, identity off the active set.

    Equals the dense ``masked_gossip_ref`` applied to the N×N matrix that is
    identity everywhere except the active-set block ``P_sub``.
    """
    Q_sub = scaled_mask.astype(jnp.float32)[:, None] * P_sub.astype(jnp.float32)
    rows = sparse_gossip_ref(W, G, P_sub, Q_sub, workers)
    sidx = jnp.where(workers >= 0, workers, W.shape[0])
    return W.at[sidx].set(rows.astype(W.dtype), mode="drop")


def sparse_scatter_rows_ref(X: jax.Array, rows: jax.Array,
                            workers: jax.Array) -> jax.Array:
    """Oracle for the in-place scatter: valid lanes replace their row,
    ``-1``-padded lanes drop, every other row of X is untouched."""
    sidx = jnp.where(workers >= 0, workers, X.shape[0])
    return X.at[sidx].set(rows.astype(X.dtype), mode="drop")
