"""Jitted wrappers for the sparse_gossip kernel: padding, masking, scatter.

The gather-compute-**scatter** contract lives here: ``sparse_gossip_rows``
returns the compact (A, ...) mixed active rows (gather + mix fused in the
kernel), and ``sparse_gossip_apply`` scatters them back into the full
(N, ...) state with ``.at[workers].set(..., mode="drop")`` — deterministic
and safe because valid active-set indices are unique and padded lanes map
out of bounds.

Padding semantics (shared with core/scheduler.py ``SparseEventBatch``):
``workers`` is ``-1``-padded to the scheduler's fixed ``active_bound``.
Before the kernel sees anything, padded lanes are clamped to row 0 and their
P_sub rows/columns and mask entries are zeroed, so a padded lane neither
contributes mass nor receives any — its compact output row is exactly zero
and the scatter drops it.  The lane axis A is additionally padded up to the
8-sublane boundary and D up to the lane-aligned tile, exactly like
gossip_mix/ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sparse_gossip.kernel import (scatter_rows_pallas,
                                                sparse_gossip_pallas)

_SUBLANE = 8


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def sparse_gossip_rows(W: jax.Array, G: jax.Array, P_sub: jax.Array,
                       scaled_mask: jax.Array, workers: jax.Array, *,
                       block_d: int = 512,
                       interpret: bool | None = None) -> jax.Array:
    """Compact active-set event update rows for one (N, ...) leaf.

    out[b] = Σ_a P_sub[a, b]·(W[workers[a]] − scaled_mask[a]·G[a]) for the
    valid lanes; zero rows for ``-1``-padded lanes.  W: (N, ...); G: (A, ...)
    active-set gradients; P_sub: (A, A); scaled_mask: (A,) = η·grad_mask.
    """
    if interpret is None:
        interpret = not _on_tpu()
    N = W.shape[0]
    A = workers.shape[0]
    valid = workers >= 0
    gidx = jnp.where(valid, workers, 0).astype(jnp.int32)
    vf = valid.astype(P_sub.dtype)
    P = P_sub * vf[:, None] * vf[None, :]
    Q = (scaled_mask * vf).astype(P.dtype)[:, None] * P

    flat_w = W.reshape(N, -1)
    flat_g = G.reshape(A, -1).astype(flat_w.dtype)
    D = flat_w.shape[1]
    Dp = _pad_up(D, block_d)
    Ap = _pad_up(A, _SUBLANE)
    if Dp != D:
        flat_w = jnp.pad(flat_w, ((0, 0), (0, Dp - D)))
        flat_g = jnp.pad(flat_g, ((0, 0), (0, Dp - D)))
    if Ap != A:
        flat_g = jnp.pad(flat_g, ((0, Ap - A), (0, 0)))
        P = jnp.pad(P, ((0, Ap - A), (0, Ap - A)))
        Q = jnp.pad(Q, ((0, Ap - A), (0, Ap - A)))
        gidx = jnp.pad(gidx, (0, Ap - A))  # clamped lanes with zero P/Q rows
    with jax.named_scope("sparse_gossip"):
        out = sparse_gossip_pallas(flat_w, flat_g, P.astype(flat_w.dtype),
                                   Q.astype(flat_w.dtype), gidx,
                                   block_d=block_d, interpret=interpret)
    return out[:A, :D].reshape((A,) + W.shape[1:])


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def sparse_gossip_apply(W: jax.Array, G: jax.Array, P_sub: jax.Array,
                        scaled_mask: jax.Array, workers: jax.Array, *,
                        block_d: int = 512,
                        interpret: bool | None = None) -> jax.Array:
    """Full event update for one leaf: gather → mix → scatter.

    Returns W′ where active rows hold P_subᵀ·(W_a − η·mask⊙G) and every
    other row is untouched — the sparse equivalent of the dense fused
    ``masked_gossip_mix`` with the (implicit) N×N matrix that is identity
    off the active set.
    """
    rows = sparse_gossip_rows(W, G, P_sub, scaled_mask, workers,
                              block_d=block_d, interpret=interpret)
    sidx = jnp.where(workers >= 0, workers, W.shape[0])
    return W.at[sidx].set(rows.astype(W.dtype), mode="drop")


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"),
                   donate_argnums=(0,))
def sparse_scatter_rows(X: jax.Array, rows: jax.Array, workers: jax.Array, *,
                        block_d: int = 512,
                        interpret: bool | None = None) -> jax.Array:
    """Scatter compact (A, ...) rows into the (N, ...) carry leaf, in place.

    The kernel-side replacement for ``X.at[workers].set(rows, mode="drop")``:
    ``X`` is donated and aliased straight through ``scatter_rows_pallas``, so
    valid lanes overwrite exactly their A rows and the other N−A rows are
    never copied — the XLA scatter's O(N·D) fresh-buffer lowering becomes
    O(A·D) window writes.  ``-1`` lanes (stream padding *and* the sublane
    padding added here) write their gathered window back unchanged.

    Called standalone (outside a wrapping jit) the donation is real: passing
    ``X`` again afterwards raises JAX's donated-buffer error, which
    tests/test_bucketed_stream.py pins.  When traced inside the event-scan
    jit the inner donation is a no-op and XLA's own aliasing takes over.
    """
    if interpret is None:
        interpret = not _on_tpu()
    N = X.shape[0]
    A = workers.shape[0]
    flat_x = X.reshape(N, -1)
    flat_r = rows.reshape(A, -1).astype(flat_x.dtype)
    idx = workers.astype(jnp.int32)
    D = flat_x.shape[1]
    Dp = _pad_up(D, block_d)
    Ap = _pad_up(A, _SUBLANE)
    if Dp != D:
        flat_x = jnp.pad(flat_x, ((0, 0), (0, Dp - D)))
        flat_r = jnp.pad(flat_r, ((0, 0), (0, Dp - D)))
    if Ap != A:
        flat_r = jnp.pad(flat_r, ((0, Ap - A), (0, 0)))
        idx = jnp.pad(idx, (0, Ap - A), constant_values=-1)
    with jax.named_scope("sparse_scatter_rows"):
        out = scatter_rows_pallas(flat_x, flat_r, idx, block_d=block_d,
                                  interpret=interpret)
    return out[:, :D].reshape(X.shape)
