"""Pallas TPU kernels for the paper's compute hot-spots (DESIGN.md §5).

Each kernel ships as <name>/kernel.py (pl.pallas_call + BlockSpec),
<name>/ops.py (jitted wrapper) and <name>/ref.py (pure-jnp oracle);
tests sweep shapes/dtypes against the oracle in interpret mode.
"""
from repro.kernels import gossip_mix, linear_scan, sparse_gossip, swa_attention
