"""Jitted wrapper for linear_scan: padding + interpret fallback on CPU."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.linear_scan.kernel import linear_scan_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_t", "block_d", "interpret"))
def linear_scan(a: jax.Array, x: jax.Array, *, block_t: int = 128,
                block_d: int = 512, interpret: bool | None = None) -> jax.Array:
    """Diagonal linear recurrence over axis 1 for (B, T, D) inputs.

    Pads T up to block_t (a=1, x=0 padding is recurrence-neutral at the tail)
    and D up to block_d.
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, T, D = a.shape
    bt = min(block_t, T) if T % block_t else block_t
    if T % bt:
        pad_t = -(-T // bt) * bt - T
        a = jnp.pad(a, ((0, 0), (0, pad_t), (0, 0)), constant_values=1.0)
        x = jnp.pad(x, ((0, 0), (0, pad_t), (0, 0)))
    bd = min(block_d, D) if D % block_d else block_d
    if D % bd:
        pad_d = -(-D // bd) * bd - D
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad_d)), constant_values=1.0)
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad_d)))
    out = linear_scan_pallas(a, x, block_t=bt, block_d=bd, interpret=interpret)
    return out[:, :T, :D]
