from repro.kernels.linear_scan import ops, ref
from repro.kernels.linear_scan.kernel import linear_scan_pallas
from repro.kernels.linear_scan.ops import linear_scan
from repro.kernels.linear_scan.ref import linear_scan_ref
