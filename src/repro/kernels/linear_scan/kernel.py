"""Pallas TPU kernel: blocked diagonal linear recurrence  h_t = a_t ⊙ h_{t-1} + x_t.

Serves RWKV6's data-dependent-decay state update and RecurrentGemma's RG-LRU
(DESIGN.md §5).  Inputs (B, T, D); the grid is (B, D-tiles, T-tiles) with the
T dimension innermost — TPU grids iterate the last axis sequentially, so the
running state for each (batch, channel-tile) lives in a VMEM scratch
accumulator carried across T-tiles.  Within a tile the recurrence is a short
``fori_loop`` over rows (each step one (Dt,)-lane VPU fma).

VMEM per step: 3 · Tt · Dt · 4B + Dt · 4B  (e.g. 128×512 → 0.8 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(a_ref, x_ref, o_ref, carry_ref):
    t_idx = pl.program_id(2)

    @pl.when(t_idx == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[0].astype(jnp.float32)       # (Tt, Dt)
    x = x_ref[0].astype(jnp.float32)
    bt = a.shape[0]

    def body(i, h):
        h = a[i] * h + x[i]
        o_ref[0, i, :] = h.astype(o_ref.dtype)
        return h

    h0 = carry_ref[0]
    h = jax.lax.fori_loop(0, bt, body, h0)
    carry_ref[0] = h


def linear_scan_pallas(a: jax.Array, x: jax.Array, *, block_t: int = 128,
                       block_d: int = 512, interpret: bool = False) -> jax.Array:
    """a, x: (B, T, D) with T % block_t == 0 and D % block_d == 0."""
    B, T, D = a.shape
    assert x.shape == (B, T, D)
    assert T % block_t == 0 and D % block_d == 0, (T, D, block_t, block_d)
    grid = (B, D // block_d, T // block_t)   # T innermost → sequential carry
    spec = pl.BlockSpec((1, block_t, block_d), lambda b, d, t: (b, t, d))
    return pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, T, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
    )(a, x)
