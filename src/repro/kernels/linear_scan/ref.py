"""Pure-jnp oracle for the linear_scan kernel."""
import jax
import jax.numpy as jnp


def linear_scan_ref(a: jax.Array, x: jax.Array) -> jax.Array:
    """h_t = a_t ⊙ h_{t-1} + x_t over axis 1, h_0 = 0.  a, x: (B, T, D)."""
    def step(h, ax):
        at, xt = ax
        h = at * h + xt
        return h, h

    a32 = a.astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    aT = jnp.swapaxes(a32, 0, 1)
    xT = jnp.swapaxes(x32, 0, 1)
    _, hs = jax.lax.scan(step, jnp.zeros_like(x32[:, 0]), (aT, xT))
    return jnp.swapaxes(hs, 0, 1).astype(x.dtype)
