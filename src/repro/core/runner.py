"""Decentralized training driver: any scheduler × any model × any data.

Consumes a scheduler's event stream and advances the stacked worker state with
the jitted update from core/aau.py.  Records loss / accuracy versus both the
iteration counter and the *virtual wall-clock*, plus cumulative communication,
reproducing the paper's Figures 3–5 measurement protocol.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aau import build_event_step, debiased_average
from repro.core.scheduler import Scheduler
from repro.utils.tree import tree_size, tree_stack


@dataclasses.dataclass
class HistoryPoint:
    k: int
    time: float
    loss: float
    metric: float
    comm_param_copies: int
    n_active_mean: float


@dataclasses.dataclass
class RunResult:
    algorithm: str
    history: List[HistoryPoint]
    final_loss: float
    final_metric: float
    total_events: int
    total_time: float
    total_comm_copies: int
    param_count: int

    def comm_bytes(self, bytes_per_scalar: int = 4) -> int:
        return self.total_comm_copies * self.param_count * bytes_per_scalar

    def time_to_loss(self, target: float) -> Optional[float]:
        for p in self.history:
            if p.loss <= target:
                return p.time
        return None

    def iters_to_loss(self, target: float) -> Optional[int]:
        for p in self.history:
            if p.loss <= target:
                return p.k
        return None


class DecentralizedTrainer:
    """Runs one algorithm on one model/dataset under one straggler model."""

    def __init__(
        self,
        scheduler: Scheduler,
        loss_fn: Callable,                  # loss_fn(params, batch) -> scalar
        init_params_fn: Callable,           # init_params_fn(rng) -> pytree
        worker_batch_fn: Callable,          # worker_batch_fn(worker, step) -> batch pytree
        eval_batch,                         # held-out batch for the global model
        eval_fn: Optional[Callable] = None, # eval_fn(params, batch) -> (loss, metric)
        eta0: float = 0.1,
        eta_decay: float = 1.0,             # paper uses η(k) = η₀ · δᵏ with δ=0.95 per *round*
        eta_decay_every: int = 1,
        seed: int = 0,
        use_kernel: bool = False,
        same_init: bool = True,
    ):
        self.scheduler = scheduler
        self.n = scheduler.n
        self.loss_fn = loss_fn
        self.eval_fn = eval_fn or (lambda p, b: (loss_fn(p, b), 0.0))
        self.worker_batch_fn = worker_batch_fn
        self.eval_batch = eval_batch
        self.eta0, self.eta_decay, self.eta_decay_every = eta0, eta_decay, eta_decay_every
        rng = jax.random.PRNGKey(seed)
        if same_init:
            p0 = init_params_fn(rng)
            params = [p0] * self.n
        else:
            params = [init_params_fn(k) for k in jax.random.split(rng, self.n)]
        self.W = tree_stack(params)
        self.S = self.W
        self.y = jnp.ones((self.n,), dtype=jnp.float32)
        self.param_count = tree_size(params[0])
        self._step = build_event_step(loss_fn, use_kernel=use_kernel)
        self._eval = jax.jit(self.eval_fn)
        self._draw_count = np.zeros(self.n, dtype=np.int64)
        self._batches = tree_stack(
            [self._draw(i) for i in range(self.n)])

    def _draw(self, worker: int):
        b = self.worker_batch_fn(worker, int(self._draw_count[worker]))
        self._draw_count[worker] += 1
        return b

    def _refresh_batches(self, restart_mask: np.ndarray) -> None:
        idx = np.nonzero(restart_mask)[0]
        if len(idx) == 0:
            return
        new = {int(i): self._draw(int(i)) for i in idx}

        def upd(leaf_batches, getter):
            arr = np.array(leaf_batches)  # host copy (jax buffers are read-only)
            for i, b in new.items():
                arr[i] = np.asarray(getter(b))
            return jnp.asarray(arr)

        leaves, treedef = jax.tree.flatten(self._batches)
        new_leaves = []
        for li, leaf in enumerate(leaves):
            new_leaves.append(upd(leaf, lambda b, li=li: jax.tree.leaves(b)[li]))
        self._batches = jax.tree.unflatten(treedef, new_leaves)

    def run(
        self,
        max_events: Optional[int] = None,
        max_time: Optional[float] = None,
        eval_every: int = 10,
    ) -> RunResult:
        assert max_events or max_time, "bound the run by events or virtual time"
        history: List[HistoryPoint] = []
        comm = 0
        active_sizes: List[int] = []
        t = 0.0
        k = -1
        rounds = 0
        for ev in self.scheduler.events():
            if max_events is not None and ev.k >= max_events:
                break
            if max_time is not None and ev.time > max_time:
                break
            k, t = ev.k, ev.time
            comm += ev.param_copies_sent
            active_sizes.append(ev.n_active)
            eta = jnp.float32(
                self.eta0 * (self.eta_decay ** (rounds // self.eta_decay_every)))
            self.W, self.S, self.y = self._step(
                self.W, self.S, self.y, self._batches,
                jnp.asarray(ev.P, dtype=jnp.float32),
                jnp.asarray(ev.grad_workers), jnp.asarray(ev.restart_workers),
                eta,
            )
            self._refresh_batches(ev.restart_workers)
            rounds += 1
            if rounds % eval_every == 0:
                loss, metric = self._eval_now()
                history.append(HistoryPoint(
                    k=k, time=t, loss=loss, metric=metric,
                    comm_param_copies=comm,
                    n_active_mean=float(np.mean(active_sizes[-eval_every:])),
                ))
        loss, metric = self._eval_now()
        history.append(HistoryPoint(
            k=k, time=t, loss=loss, metric=metric, comm_param_copies=comm,
            n_active_mean=float(np.mean(active_sizes)) if active_sizes else 0.0))
        return RunResult(
            algorithm=self.scheduler.name, history=history,
            final_loss=loss, final_metric=metric,
            total_events=rounds, total_time=t, total_comm_copies=comm,
            param_count=self.param_count,
        )

    def _eval_now(self):
        avg = debiased_average(self.W, self.y)
        loss, metric = self._eval(avg, self.eval_batch)
        return float(loss), float(metric)


def run_algorithms(
    algorithms: Dict[str, Scheduler],
    make_trainer: Callable[[Scheduler], DecentralizedTrainer],
    **run_kw,
) -> Dict[str, RunResult]:
    """Run several algorithms under identical model/data settings."""
    out = {}
    for name, sched in algorithms.items():
        trainer = make_trainer(sched)
        out[name] = trainer.run(**run_kw)
    return out
