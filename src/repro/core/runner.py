"""Decentralized training driver: any scheduler × any model × any data.

Consumes a scheduler's event stream and advances the stacked worker state
with the updates from core/aau.py.  Records loss / accuracy versus both the
iteration counter and the *virtual wall-clock*, plus cumulative
communication, reproducing the paper's Figures 3–5 measurement protocol.

Execution model — block-compiled, mode chosen automatically by default
(``mode="auto"`` resolves to the dense ``scan`` or the active-set
``sparse_scan`` via :func:`choose_mode`'s recorded crossover heuristic):

- The event stream is packed ``block_size`` events at a time into
  :class:`~repro.core.scheduler.EventBatch` stacked arrays and replayed on
  device through one compiled ``lax.scan`` call per block
  (``masked_gossip_scan``) — one XLA dispatch and zero host round-trips per
  E events, instead of the legacy one-dispatch-per-event interpreter.
- ``mode="sparse_scan"`` replays the same stream in active-set form
  (:class:`~repro.core.scheduler.SparseEventBatch` + ``sparse_gossip_scan``):
  each event gathers only the workers it touches, evaluates gradients for
  those lanes alone, mixes with the A×A consensus submatrix, and scatters
  back — O(A·D) per event instead of O(n²·D), the representation that makes
  paper-scale N≥256 streams affordable.  The lane width A follows the
  scheduler's ``active_buckets()`` ladder: single-bucket schedulers
  (AD-PSGD/AGP at A=2, Prague at the group size) compile one block program,
  while schedulers whose event sizes are a *distribution* (DSGD-AAU's
  finished cliques) are packed per bucket and dispatched segment-by-segment
  in stream order (``BucketedSparseEventBatch`` — see
  ``_dispatch_bucketed``), so the typical small event stops paying the
  worst-case event's padding.  Schedulers whose events are global barriers
  (sync DSGD, ``Scheduler.global_events``) automatically fall back to the
  dense scan.  The sparse block donates its carry buffers — the n-row state
  is updated in place across blocks rather than copied per dispatch.
- Per-worker batches come from a pre-drawn on-device sample pool indexed by
  a restart counter the scan carries.  By default the pool is sized from the
  first run's bound — ``max_events`` directly, or a ``max_time`` bound via a
  restarts-per-worker estimate (``2·max_time / min base time``), both capped
  at 1024 — which guarantees exact per-event sampling semantics; pass
  ``batch_pool`` to fix the size explicitly.  The pointer wraps modulo the
  pool, so runs with more restarts per worker than the pool revisit samples
  cyclically — a warning is issued once if that happens.
- Evaluation fires every ``eval_every`` events; block boundaries are snapped
  to the eval grid and truncated blocks are padded with no-op events, so a
  single compiled program serves the whole run and the recorded history
  matches the per-event path point-for-point.  Eval scalars accumulate in a
  device buffer (one ``.at[i].set`` dispatch per eval, no host sync) and are
  fetched once when the run ends.

The legacy interpreter is kept behind ``mode="per_event"`` for equivalence
testing (tests/test_event_stream.py) and as the reference semantics.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aau import (build_event_scan, build_event_step,
                            build_sparse_event_scan, debiased_average)
from repro.core.scheduler import (BucketedSparseEventBatch, EventBatch,
                                  Scheduler, SparseEventBatch,
                                  merge_event_groups)
from repro.obs import RunLogger, init_metrics, metrics_summary
from repro.obs.critical_path import straggler_tax
from repro.obs.metrics import dense_metrics_update, fused_metrics_fold
from repro.obs.trace import TraceRecorder, drain_fused_payload
from repro.utils.tree import tree_size, tree_stack


def choose_mode(n: int, buckets: Tuple[int, ...],
                global_events: bool = False) -> str:
    """``mode="auto"``'s dispatch decision: dense ``scan`` vs ``sparse_scan``.

    The sparse path wins when gathering the ladder's typical A lanes beats
    touching all n rows; at small n the dense scan's single fixed-shape
    block both avoids the gather/scatter overhead and compiles once.  The
    recorded BENCH_event_stream rows put the crossover consistently around
    ``n ≈ 4·A`` for the narrowest rung (AD-PSGD at N=16 ran the sparse path
    at 0.52× the dense scan; DSGD-AAU at N=64, whose first rung is 16, at
    0.91×; both cross above 1 at the next measured scale), with a floor of
    n=16 below which nothing beats the dense scan.  Barrier schedulers
    (``global_events``) always take the dense scan — every event touches
    all n workers, so sparse gathering is pure overhead.
    """
    if global_events:
        return "scan"
    if n <= max(16, 4 * buckets[0]):
        return "scan"
    return "sparse_scan"


@dataclasses.dataclass
class HistoryPoint:
    k: int
    time: float
    loss: float
    metric: float
    comm_param_copies: int
    n_active_mean: float


@dataclasses.dataclass
class RunResult:
    algorithm: str
    history: List[HistoryPoint]
    final_loss: float
    final_metric: float
    total_events: int
    total_time: float
    total_comm_copies: int
    param_count: int
    # Scalar width of the trainer's dtype policy (bf16 runs send 2-byte
    # scalars, not the old hardcoded 4) and, when the trainer ran with
    # telemetry=True, the drained device-counter summary
    # (repro.obs.metrics.metrics_summary).
    bytes_per_scalar: int = 4
    telemetry: Optional[Dict] = None
    # With trace=True, the wait-blame / critical-path summary
    # (repro.obs.critical_path.straggler_tax) of the run's recorded
    # event-identity stream; the full Trace stays on the trainer as
    # ``trainer.last_trace`` (export it with repro.obs.chrome_trace).
    trace: Optional[Dict] = None

    def comm_bytes(self, bytes_per_scalar: Optional[int] = None) -> int:
        bps = self.bytes_per_scalar if bytes_per_scalar is None else bytes_per_scalar
        return self.total_comm_copies * self.param_count * bps

    def time_to_loss(self, target: float) -> Optional[float]:
        for p in self.history:
            if p.loss <= target:
                return p.time
        return None

    def iters_to_loss(self, target: float) -> Optional[int]:
        for p in self.history:
            if p.loss <= target:
                return p.k
        return None


class DecentralizedTrainer:
    """Runs one algorithm on one model/dataset under one straggler model."""

    def __init__(
        self,
        scheduler: Scheduler,
        loss_fn: Callable,                  # loss_fn(params, batch) -> scalar
        init_params_fn: Callable,           # init_params_fn(rng) -> pytree
        worker_batch_fn: Callable,          # worker_batch_fn(worker, step) -> batch pytree
        eval_batch,                         # held-out batch for the global model
        eval_fn: Optional[Callable] = None, # eval_fn(params, batch) -> (loss, metric)
        eta0: float = 0.1,
        eta_decay: float = 1.0,             # paper uses η(k) = η₀ · δᵏ with δ=0.95 per *round*
        eta_decay_every: int = 1,
        seed: int = 0,
        use_kernel: bool = False,
        same_init: bool = True,
        mode: str = "auto",                 # "auto" (choose_mode picks scan
                                            # vs sparse_scan from n and the
                                            # scheduler's lane ladder) |
                                            # "scan" | "sparse_scan" |
                                            # "per_event" | "fused"
        block_size: int = 32,               # events per compiled scan call
        batch_pool: Optional[int] = None,   # pre-drawn samples per worker
                                            # (scan mode; None = auto from the
                                            # first run's max_events, cap 1024)
        dtype: str = "float32",             # worker-state dtype policy:
                                            # "float32" | "bfloat16" — applied
                                            # to stacked params, snapshots and
                                            # sample pools (float leaves only)
        events_per_step: Optional[int] = None,
                                            # sparse path: merge up to K
                                            # conflict-free events per scan
                                            # step (None = auto per bucket,
                                            # ~64 lanes/step; 1 disables)
        native_generation: bool = True,     # sparse path: schedulers with an
                                            # array-native generator fill the
                                            # packed chunks directly (bit-
                                            # identical; False forces the
                                            # per-event object adapter)
        telemetry: bool = False,            # device-resident per-worker
                                            # counters (repro.obs): drained
                                            # once per run into
                                            # RunResult.telemetry
        trace: bool = False,                # record the event-identity
                                            # stream (repro.obs.trace):
                                            # wait-blame summary in
                                            # RunResult.trace, full Trace
                                            # in trainer.last_trace —
                                            # host-side recording, one
                                            # device fetch max (fused)
        run_log: Optional[Union[str, object]] = None,
                                            # JSONL structured run log: a
                                            # path, a file-like object, or
                                            # None (disabled)
        sanitize: Optional[bool] = None,    # wrap runs in repro.check.runtime
                                            # .sanitized() — leak checking +
                                            # d2h transfer guard (None = the
                                            # REPRO_SANITIZE env flag)
    ):
        if mode not in ("scan", "sparse_scan", "per_event", "auto", "fused"):
            raise ValueError(
                "mode must be 'scan', 'sparse_scan', 'per_event', 'auto' "
                f"or 'fused', got {mode!r}")
        self.dtype = jnp.dtype(dtype)
        if not jnp.issubdtype(self.dtype, jnp.floating):
            raise ValueError(f"dtype policy must be a float dtype, got {dtype!r}")
        if mode == "auto":
            mode = choose_mode(scheduler.n, scheduler.active_buckets(),
                               scheduler.global_events)
        if mode == "fused" and not (hasattr(scheduler, "fused_spec")
                                    and scheduler.fused_supported()):
            raise ValueError(
                "mode='fused' needs a single-edge scheduler (ad_psgd/agp) "
                "whose time model has iid completion-time factors "
                f"(TimeModel.iid_horizon); got {scheduler.name!r}")
        if mode == "sparse_scan" and scheduler.global_events:
            # Barrier streams (sync DSGD) touch all n workers every event:
            # the gather-compute-scatter path would gather everything anyway,
            # so fall back to the dense scan automatically.
            mode = "scan"
        self.scheduler = scheduler
        self.n = scheduler.n
        self.loss_fn = loss_fn
        self.eval_fn = eval_fn or (lambda p, b: (loss_fn(p, b), 0.0))
        self.worker_batch_fn = worker_batch_fn
        self.eval_batch = eval_batch
        self.eta0, self.eta_decay, self.eta_decay_every = eta0, eta_decay, eta_decay_every
        self.use_kernel = use_kernel
        self.mode = mode
        self.block_size = max(1, block_size)
        self.batch_pool = batch_pool if batch_pool is None else max(1, batch_pool)
        self.events_per_step = events_per_step
        self.native_generation = native_generation
        self.telemetry = bool(telemetry)
        self.trace = bool(trace)
        if sanitize is None:
            from repro.check.runtime import sanitize_enabled
            sanitize = sanitize_enabled()
        self.sanitize = bool(sanitize)
        self._log = RunLogger(run_log)
        rng = jax.random.PRNGKey(seed)
        if same_init:
            p0 = init_params_fn(rng)
            params = [p0] * self.n
        else:
            params = [init_params_fn(k) for k in jax.random.split(rng, self.n)]
        # The dtype policy casts the stacked worker state (and, below, the
        # on-device sample pools): the gossip kernels and the scan updates
        # already accept bf16 leaves, so bf16 halves simulator memory and
        # doubles effective MXU throughput at paper scale.  Push-sum weights
        # y stay float32 — they are n scalars and de-biasing divides by them.
        self.W = self._cast(tree_stack(params))
        self.S = self.W
        self.y = jnp.ones((self.n,), dtype=jnp.float32)
        self.param_count = tree_size(params[0])
        self._eval = jax.jit(self.eval_fn)
        # Per-mode state built lazily on first use (avoids tracing both paths).
        self._step = None           # per-event jitted update
        self._batches = None        # per-event current batch stack
        self._draw_count = np.zeros(self.n, dtype=np.int64)
        self._scan = None           # block-compiled jitted update (dense)
        self._sparse = None         # block-compiled jitted update (active-set)
        self._fused = None          # generate-and-consume block (fused mode)
        self._fused_clock = None    # (times, lock_free) device event-process carry
        self._pools = None          # (n, batch_pool, ...) on-device sample pools
        self._ptr = None            # (n,) int32 restart counters
        self._eval_accum = None     # jitted eval → device-buffer accumulator
        self._metrics = None        # MetricsCarry device accumulators
        self._metrics_step = None   # per-event jitted dense metrics update
        self._bucket_occ = None     # host per-rung occupancy aggregation
        self._fused_payload = None  # per-block (t_ev, i, p, t_raw) device
                                    #   streams, folded once at drain
        self._fused_fold = None     # jitted fused_metrics_fold
        self._trace = None          # TraceRecorder (host-side buffers)
        self.last_trace = None      # finalized Trace of the latest run

    def _cast(self, tree):
        """Apply the worker-state dtype policy to a pytree's float leaves."""
        dt = self.dtype
        return jax.tree.map(
            lambda x: x.astype(dt)
            if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating) else x,
            tree)

    # one compiled call per reset: plain init_metrics is 11 separate device
    # puts, a measurable per-run fixed cost on the overhead-asserted paths
    _init_metrics = staticmethod(jax.jit(init_metrics, static_argnums=0))

    def _ensure_metrics(self):
        if self.telemetry and self._metrics is None:
            self._metrics = self._init_metrics(self.n)
            if self._bucket_occ is None:
                self._bucket_occ = {}

    # -- legacy per-event state -------------------------------------------
    def _ensure_per_event(self):
        if self._step is None:
            self._log.log("compile", key="per_event")
            self._step = build_event_step(self.loss_fn, use_kernel=self.use_kernel)
            self._batches = self._cast(
                tree_stack([self._draw(i) for i in range(self.n)]))
            if self.telemetry:
                self._metrics_step = jax.jit(dense_metrics_update)
        self._ensure_metrics()

    def _draw(self, worker: int):
        b = self.worker_batch_fn(worker, int(self._draw_count[worker]))
        self._draw_count[worker] += 1
        return b

    def _refresh_batches(self, idx: np.ndarray) -> None:
        """Redraw the batches of the workers in ``idx`` (restarted lanes)."""
        if len(idx) == 0:
            return
        new = {int(i): self._draw(int(i)) for i in idx}

        def upd(leaf_batches, getter):
            arr = np.array(leaf_batches)  # host copy (jax buffers are read-only)
            for i, b in new.items():
                arr[i] = np.asarray(getter(b))
            return jnp.asarray(arr)

        leaves, treedef = jax.tree.flatten(self._batches)
        new_leaves = []
        for li, leaf in enumerate(leaves):
            new_leaves.append(upd(leaf, lambda b, li=li: jax.tree.leaves(b)[li]))
        self._batches = jax.tree.unflatten(treedef, new_leaves)

    # -- scan-mode state ---------------------------------------------------
    def _estimate_restarts(self, max_time: float) -> int:
        """Upper-bound restarts/worker for a ``max_time``-bounded run.

        A worker restarts at most once per completed local computation, and
        the fastest worker's completions take at least its base time shrunk
        by jitter's low tail — 2× headroom covers both that tail and any
        scheduler that restarts on someone else's clock.  Without this a
        long ``max_time`` run fell back to a 64-draw pool and silently
        revisited samples (the wrap warning below remains the backstop).
        """
        base = np.min(self.scheduler.sampler.base)
        return int(np.ceil(2.0 * max_time / max(float(base), 1e-9)))

    def _ensure_pools(self, max_events: Optional[int] = None,
                      max_time: Optional[float] = None):
        # Restarts per worker are bounded by total events, so a pool of
        # max_events draws never wraps; a max_time bound is converted into
        # a restart estimate; explicit batch_pool overrides both.
        if self.batch_pool is not None:
            pool_len = self.batch_pool
        elif max_events:
            pool_len = min(max_events, 1024)
        elif max_time is not None:
            pool_len = max(64, min(self._estimate_restarts(max_time), 1024))
        else:
            pool_len = 64
        if self._pools is not None and self._pool_len >= pool_len:
            return
        # pool[i, s] = the s-th batch worker i would draw — identical to
        # the legacy path's draw sequence, moved on-device ahead of time.
        # Growing an auto-sized pool (e.g. warmup() built 64, a later
        # run(max_events=...) needs more) is safe mid-stream: the draw at
        # (w, s) is a pure function of its arguments, so a larger pool keeps
        # the prefix already consumed and the carried ``ptr`` stays valid
        # (the block jit re-traces once for the new pool shape).
        self._pool_len = pool_len
        self._pools = self._cast(tree_stack([
            tree_stack([self.worker_batch_fn(w, s)
                        for s in range(pool_len)])
            for w in range(self.n)]))
        if self._ptr is None:
            self._ptr = jnp.zeros((self.n,), dtype=jnp.int32)

    def _ensure_scan(self, max_events: Optional[int] = None,
                     max_time: Optional[float] = None):
        if self._scan is None:
            self._log.log("compile", key="scan", telemetry=self.telemetry)
            self._scan = build_event_scan(self.loss_fn,
                                          use_kernel=self.use_kernel,
                                          telemetry=self.telemetry)
        self._ensure_metrics()
        self._ensure_pools(max_events, max_time)

    def _ensure_sparse(self, max_events: Optional[int] = None,
                       max_time: Optional[float] = None):
        if self._sparse is None:
            self._log.log("compile", key="sparse_scan", telemetry=self.telemetry)
            self._sparse = build_sparse_event_scan(
                self.loss_fn, use_kernel=self.use_kernel,
                telemetry=self.telemetry)
            # The sparse block donates its (W, S, y, ptr) carry arguments.
            # With same_init the snapshot stack S still *is* W (one shared
            # buffer) until the first update — donating that buffer through
            # two arguments is an XLA error, so break the alias once here.
            if any(w is s for w, s in zip(jax.tree.leaves(self.W),
                                          jax.tree.leaves(self.S))):
                self.S = jax.tree.map(jnp.array, self.S)
        self._ensure_metrics()
        self._ensure_pools(max_events, max_time)

    def _etas_for(self, batch_E: int, valid_E: int, rounds: int) -> np.ndarray:
        etas = self.eta0 * self.eta_decay ** (
            (rounds + np.arange(batch_E)) // self.eta_decay_every)
        if valid_E < batch_E:
            etas[valid_E:] = 0.0  # padded no-op events (masks all-False)
        return etas

    def _dispatch_block(self, batch: EventBatch, rounds: int,
                        target: Optional[int] = None) -> None:
        """One compiled call: pad to the block shape, advance (W, S, y, ptr)."""
        E = batch.E
        if target is None:
            target = self.block_size
        if E < target:
            batch = batch.pad_to(target)
        etas = self._etas_for(batch.E, E, rounds)
        args = (
            self.W, self.S, self.y, self._ptr,
            jnp.asarray(batch.P, dtype=jnp.float32),
            jnp.asarray(batch.grad_workers),
            jnp.asarray(batch.restart_workers),
            jnp.asarray(etas, dtype=jnp.float32),
        )
        # logged for every dispatch (no-op without a run log): the wall-
        # clock track of repro.obs.trace is built from these records
        self._log.log("block_dispatch", mode="scan", events=E,
                      padded=batch.E, rounds=rounds)
        if not self.telemetry:
            with jax.profiler.TraceAnnotation("dispatch:scan"):
                self.W, self.S, self.y, self._ptr = self._scan(
                    *args[:4], self._pools, *args[4:])
            return
        Ep = batch.E
        fin = batch.finish if batch.finish is not None \
            else np.broadcast_to(batch.times[:, None], (Ep, self.n))
        with jax.profiler.TraceAnnotation("dispatch:scan"):
            # casts happen host-side: a cross-dtype jnp.asarray would pay a
            # per-block convert_element_type dispatch
            (self.W, self.S, self.y, self._ptr, self._metrics) = self._scan(
                *args[:4], self._metrics, self._pools, *args[4:],
                jnp.asarray(np.asarray(batch.times, dtype=np.float32)),
                jnp.asarray(np.asarray(fin, dtype=np.float32)),
                jnp.asarray(np.arange(rounds, rounds + Ep, dtype=np.int32)),
                jnp.asarray(np.asarray(batch.param_copies_sent,
                                       dtype=np.int32)),
            )

    def _dispatch_sparse_block(self, batch: SparseEventBatch, rounds: int,
                               target: Optional[int] = None,
                               lane_off: Optional[np.ndarray] = None,
                               lane_ts: Optional[np.ndarray] = None) -> None:
        """One compiled call over active-set arrays: O(A·D) per event.

        ``lane_off`` marks ``batch`` as the output of ``merge_event_groups``:
        a (E, A) int array of absolute source-event offsets per lane, from
        which per-*lane* step sizes are built (each merged lane keeps the η
        its source event would have used — the decay schedule is indexed by
        event, not by scan step, so merging stays bit-exact).  ``lane_ts``
        (telemetry, merged path only) carries the matching per-lane source
        event clocks, gathered the same way.
        """
        E = batch.E
        if target is None:
            target = self.block_size
        if E < target:
            batch = batch.pad_to(target)
        if lane_off is None:
            etas = self._etas_for(batch.E, E, rounds)
        else:
            etas = np.zeros((batch.E, batch.A))
            etas[:E] = self.eta0 * self.eta_decay ** (
                (rounds + lane_off) // self.eta_decay_every)
        args = (
            self.W, self.S, self.y, self._ptr,
            jnp.asarray(batch.workers),
            jnp.asarray(batch.P_sub, dtype=jnp.float32),
            jnp.asarray(batch.grad_workers),
            jnp.asarray(batch.restart_workers),
            jnp.asarray(etas, dtype=jnp.float32),
        )
        self._log.log("block_dispatch", mode="sparse_scan", events=E,
                      padded=batch.E, lanes=batch.A, rounds=rounds,
                      merged=lane_off is not None)
        if not self.telemetry:
            with jax.profiler.TraceAnnotation("dispatch:sparse_scan"):
                self.W, self.S, self.y, self._ptr = self._sparse(
                    *args[:4], self._pools, *args[4:])
            return
        Ep, A = batch.E, batch.A
        # Per-lane event indices and clocks: every lane of an unmerged row
        # shares the row's event; a merged row's lanes keep their source
        # event's index/clock so staleness and mix ages stay bit-exact
        # against the unmerged replay.  Padded rows are skipped wholesale
        # by the scan body's cond (workers[0] < 0), so their values are
        # never read.
        if lane_off is None:
            ks = np.broadcast_to(
                np.arange(rounds, rounds + Ep, dtype=np.int32)[:, None],
                (Ep, A))
            ts = np.broadcast_to(batch.times[:, None], (Ep, A))
        else:
            ks = np.zeros((Ep, A), dtype=np.int32)
            ks[:E] = rounds + lane_off
            ts = np.zeros((Ep, A))
            ts[:E] = lane_ts
        fin = batch.finish if batch.finish is not None else ts
        with jax.profiler.TraceAnnotation("dispatch:sparse_scan"):
            # casts happen host-side: a cross-dtype jnp.asarray would pay a
            # per-block convert_element_type dispatch
            (self.W, self.S, self.y, self._ptr,
             self._metrics) = self._sparse(
                *args[:4], self._metrics, self._pools, *args[4:],
                jnp.asarray(np.asarray(ts, dtype=np.float32)),
                jnp.asarray(np.asarray(fin, dtype=np.float32)),
                jnp.asarray(ks),
                jnp.asarray(np.asarray(batch.param_copies_sent,
                                       dtype=np.int32)),
            )

    def _events_per_step(self, A: int) -> int:
        """Events merged per scan step at lane width ``A`` (the blocking K).

        The per-scan-step dispatch cost (~100 µs on this CPU backend,
        measured in BENCH_event_stream) is independent of the step's lane
        count, so folding a run of conflict-free events into one K·A-lane
        step amortizes it group-size-fold.  K·A is a *lane budget* —
        ``merge_event_groups`` packs members compactly, so low-fill streams
        fit more than K events per step.  The auto policy targets ~64 lanes
        per step — enough to amortize, small enough that one conflicting
        event doesn't truncate groups often: A=2 pair events merge
        16-deep, DSGD-AAU's typical A=16 rung packs ~10 of its ~5-worker
        cliques per step, and A≥64 rungs stay unmerged (at budgets near n,
        conflicts are certain and the padded lanes cost more than the
        amortized thunk).
        """
        if self.events_per_step is not None:
            return max(1, int(self.events_per_step))
        return int(np.clip(64 // max(A, 1), 1, 16))

    def _dispatch_sparse_chunk(self, batch: SparseEventBatch, rounds: int,
                               cap: int) -> None:
        """Advance the carry through one same-bucket packed chunk.

        With K > 1 the chunk is first folded by ``merge_event_groups`` —
        runs of ≤K consecutive events with pairwise-disjoint worker sets
        become single block-diagonal scan steps — then chopped into
        fixed-length ``cap // K`` dispatches (the merged path compiles its
        own (E, K·A) block shape, distinct from the unmerged one).
        """
        K = self._events_per_step(batch.A)
        if K <= 1:
            start = 0
            while start < batch.E:
                stop = min(batch.E, start + cap)
                self._dispatch_sparse_block(
                    batch.slice(start, stop), rounds + start, cap)
                start = stop
            return
        merged, lane_off = merge_event_groups(batch, K)
        g_cap = max(1, cap // K)
        # telemetry: lane-level source-event clocks, gathered once per chunk
        lane_ts = batch.times[lane_off] if self.telemetry else None
        start = 0
        while start < merged.E:
            stop = min(merged.E, start + g_cap)
            # lane_off carries *absolute* source offsets within ``batch``,
            # so ``rounds`` stays the chunk base across slices.
            self._dispatch_sparse_block(
                merged.slice(start, stop), rounds, g_cap,
                lane_off=lane_off[start:stop],
                lane_ts=None if lane_ts is None else lane_ts[start:stop])
            start = stop

    # Base chunk length for the narrowest bucket of a multi-bucket ladder.
    # Chunks must be short: a DSGD-AAU stream switches buckets every ~4
    # events at N=256, so a chunk longer than the typical same-bucket
    # segment just pads with no-op events.  They must also be *one fixed
    # shape per bucket*: each distinct (A, E) pair compiles its own block
    # program, and with segment-length-sized shapes the tracing cost (tens
    # of XLA compiles) swamped the event stream it was meant to speed up.
    _CHUNK_QUANTUM = 32

    @staticmethod
    def _bucket_cap(buckets: Tuple[int, ...], b: int, target: int) -> int:
        """Fixed chunk length for bucket ``b`` of the ladder.

        Scaled inversely to the *square* of the lane-width ratio —
        ``quantum · (buckets[0] / buckets[b])²`` — which tracks both costs
        that grow with lane width: the O(A²·D) mix per event and, more
        importantly on a fragmented stream, the no-op padding.  Measured
        DSGD-AAU streams at N=256 spend ~93% of events in the first rung in
        ~15-event runs, but the wide rungs fire in 1–2-event bursts — a
        linear cap (quantum·b0/A) padded those bursts 4–8× with wide-lane
        no-ops and cost more than the dense fallback it replaced; the
        quadratic cap pins wide-bucket chunks at 1–2 events (≈ their true
        burst length) and lifted bucketed throughput from ~3× to ~5–6× the
        static-bound path.
        """
        quantum = min(target, DecentralizedTrainer._CHUNK_QUANTUM)
        return max(1, (quantum * buckets[0] * buckets[0])
                   // (buckets[b] * buckets[b]))

    def _dispatch_bucketed(self, bucketed: BucketedSparseEventBatch,
                           rounds: int, target: int) -> None:
        """Advance the carry through a bucketed block, in stream order.

        State updates are sequential, so buckets are *not* replayed whole:
        the stream's maximal same-bucket runs (``segment_batches`` — each
        contiguous both in the stream and in its bucket's packed arrays)
        are dispatched in order, every segment chopped into fixed-length
        chunks at its bucket's lane width (short chunks padded with no-op
        events — ``SparseEventBatch.pad_to`` — to keep one compiled shape
        per bucket).  Events therefore execute in exactly the per-event
        order — the bucketed path's results are bit-exact against the dense
        scan — while a typical DSGD-AAU event pays for ~16 lanes instead
        of n.
        """
        for b, off, seg in bucketed.segment_batches():
            cap = self._bucket_cap(bucketed.buckets, b, target)
            self._log.log("bucket_segment", A=int(bucketed.buckets[b]),
                          events=seg.E, rounds=rounds + off)
            self._dispatch_sparse_chunk(seg, rounds + off, cap)

    def _accum_occupancy(self, rows: List[Dict[str, float]]) -> None:
        """Fold one chunk's per-rung packing stats into the run aggregate."""
        if self._bucket_occ is None:
            self._bucket_occ = {}
        for r in rows:
            if not r["events"]:
                continue
            acc = self._bucket_occ.setdefault(int(r["A"]),
                                              {"events": 0, "lanes": 0.0})
            acc["events"] += int(r["events"])
            acc["lanes"] += float(r["lane_fill"]) * r["events"] * r["A"]

    def _telemetry_summary(self, t_end: float) -> Optional[Dict]:
        """Drain the device counters once (logged before ``run_end``)."""
        if not self.telemetry or self._metrics is None:
            return None
        if self._fused_payload:
            # fold the whole fused run's streamed event identities in one
            # compiled call (event indices restart at 0 with the per-run
            # counter reset, so k0 = 0)
            t_ev, i_seq, p_seq, t_raw = (
                jnp.concatenate(xs) if len(xs) > 1 else xs[0]
                for xs in zip(*self._fused_payload))
            self._metrics = self._fused_fold(
                self._metrics, i_seq, p_seq, t_raw, t_ev,
                int(self.scheduler.fused_spec()["copies_pair"]),
                jnp.int32(0))
            self._fused_payload = []
        summary = metrics_summary(
            self._metrics, t_end,
            n_minus_1_bound=self.scheduler.name == "dsgd_aau")
        summary["comm_bytes_per_copy"] = self.param_count * self.dtype.itemsize
        if self._bucket_occ:
            summary["bucket_occupancy"] = [
                {"A": A, "events": acc["events"],
                 "lane_fill": acc["lanes"] / (acc["events"] * A)}
                for A, acc in sorted(self._bucket_occ.items())]
        bound = summary.get("staleness_bound")
        if bound is not None:
            self._log.log("staleness_bound", **bound)
            if not bound["ok"]:
                self._log.warn_once(
                    "staleness_bound",
                    f"DSGD-AAU staleness monitor: observed max staleness "
                    f"{bound['observed_max']} exceeds the 2N-4 bound "
                    f"({bound['bound']}) induced by the B <= N-1 per-epoch "
                    "commit bound — the scheduler violated the paper's "
                    "bounded-staleness guarantee.")
        return summary

    def _trace_summary(self) -> Optional[Dict]:
        """Finalize the recorded identity stream; one device fetch max.

        Host modes recorded everything host-side already; a fused run's
        buffered device blocks are fetched here with a single explicit
        ``jax.device_get`` (``drain_fused_payload``).  Runs *before* the
        telemetry drain in every finish path — ``_telemetry_summary``
        consumes and clears ``_fused_payload``.
        """
        if not self.trace or self._trace is None:
            return None
        if self._fused_payload:
            host = drain_fused_payload(self._fused_payload)
            self._trace.record_fused(
                *host,
                copies_pair=int(self.scheduler.fused_spec()["copies_pair"]))
        tr = self._trace.finalize(algorithm=self.scheduler.name,
                                  mode=self.mode)
        self.last_trace = tr
        return straggler_tax(tr)

    def warmup(self) -> None:
        """Compile this trainer's update and eval with no-op dispatches.

        State is left exactly unchanged (identity P, all-False masks — η is
        traced data, so its warmup values don't matter), letting benchmarks
        separate compile time from steady-state throughput.  In the scan
        modes the compiled block shape is ``block_size``; a subsequent run
        whose ``eval_every`` is smaller re-traces once at the smaller
        shape, and an auto-sized batch pool built here at the 64-draw
        default grows (one more re-trace) if the run's ``max_events``
        needs more — pass ``batch_pool`` explicitly to pin both.
        """
        n = self.n
        if self.mode == "fused":
            self._ensure_fused()
            # The block donates its carry: clone the state, advance the
            # clones through one full-length block of zero-factor /
            # zero-pick draws (η is traced data) and discard them.  No
            # scheduler RNG is consumed, so the run's realization is
            # untouched.
            E = self.block_size
            zeros = jnp.zeros((E,), dtype=jnp.float32)
            clones = (jax.tree.map(jnp.array, self.W),
                      jax.tree.map(jnp.array, self.S),
                      jnp.array(self.y), jnp.array(self._ptr))
            clock = (jnp.ones((n,), dtype=jnp.float32), jnp.float32(0.0))
            carry, ys = self._fused(
                *clones, self._pools, *clock,
                jnp.int32(0), zeros, zeros, zeros,
            )
            # warmup's streamed payload is discarded (telemetry/trace widen
            # the scan outputs; the block signature is otherwise identical)
            t_seq = ys[0] if (self.telemetry or self.trace) else ys
            carry[2].block_until_ready()
            self._warm_eval()
            # Also warm the per-eval recording ops (row build + history
            # scatter + buffer growth): they are tiny eager dispatches, but
            # their first-call compiles sum to ~0.25 s — 30× a whole
            # steady-state block at N=256.  Scratch buffer only; state and
            # scheduler RNG are untouched.
            buf = self._fused_record(
                jnp.zeros((2, 4), dtype=jnp.float32), 0, t_seq[-1],
                jnp.int32(0))
            jnp.concatenate([buf, jnp.zeros_like(buf)]).block_until_ready()
            return
        if self.mode == "sparse_scan":
            self._ensure_sparse()
            buckets = self.scheduler.active_buckets()
            ebound = self.scheduler.edge_bound()
            if len(buckets) > 1:
                # one compiled block program per bucket, at the chunk cap
                # (and merge width) its full segments will dispatch with
                for b, A in enumerate(buckets):
                    cap = self._bucket_cap(buckets, b, self.block_size)
                    noop = SparseEventBatch.from_events(
                        [_identity_event(n)], active_bound=A,
                        edge_bound=min(ebound, max(1, A * (A - 1) // 2)))
                    self._dispatch_sparse_chunk(noop, 0, cap)
            else:
                noop = SparseEventBatch.from_events(
                    [_identity_event(n)],
                    active_bound=self.scheduler.active_bound(),
                    edge_bound=ebound)
                self._dispatch_sparse_chunk(noop, 0, self.block_size)
            self.y.block_until_ready()
            self._warm_eval()
            return
        noop = EventBatch.from_events(
            [_identity_event(n)], edge_bound=1).pad_to(
                self.block_size if self.mode == "scan" else 1)
        if self.mode == "scan":
            self._ensure_scan()
            self._dispatch_block(noop, rounds=0)
            self.y.block_until_ready()
            self._warm_eval()
            return
        self._ensure_per_event()
        ev = noop.to_events()[0]
        self.W, self.S, self.y = self._step(
            self.W, self.S, self.y, self._batches,
            jnp.asarray(ev.P, dtype=jnp.float32),
            jnp.asarray(ev.grad_workers), jnp.asarray(ev.restart_workers),
            jnp.float32(0.0),
        )
        self.y.block_until_ready()
        self._eval_now()

    def _warm_eval(self) -> None:
        """Compile the scan modes' history eval (state left untouched)."""
        self._ensure_eval_accum()
        self._eval_accum(self.W, self.y, self.eval_batch).block_until_ready()

    # -- driving loop ------------------------------------------------------
    def run(
        self,
        max_events: Optional[int] = None,
        max_time: Optional[float] = None,
        eval_every: int = 10,
    ) -> RunResult:
        assert max_events or max_time, "bound the run by events or virtual time"
        if self.telemetry:
            # fresh counters per run: event indices (the staleness clock)
            # restart at 0 every run, so carried-over restart marks from a
            # previous run would alias as negative staleness
            self._metrics = self._init_metrics(self.n)
            self._bucket_occ = {}
        if self.telemetry or self.trace:
            self._fused_payload = []
        if self.trace:
            self._trace = TraceRecorder(self.n)
        self._log.log("run_start", algorithm=self.scheduler.name, n=self.n,
                      mode=self.mode, max_events=max_events,
                      max_time=max_time, eval_every=eval_every,
                      dtype=str(self.dtype), telemetry=self.telemetry,
                      trace=self.trace)
        if self.mode == "fused" or getattr(self.scheduler, "horizon", None):
            self._log.warn_once(
                "rng_order",
                "event stream is a different-but-deterministic RNG-order "
                "realization (horizon batching / fused generation): "
                "distributionally identical to the exact per-event stream, "
                "not bit-identical to it.", warn=False)
        with self._maybe_sanitized():
            if self.mode == "fused":
                return self._run_fused(max_events, max_time, eval_every)
            if self.mode == "sparse_scan":
                return self._run_sparse_stream(max_events, max_time,
                                               eval_every)
            if self.mode == "scan":
                return self._run_scan(max_events, max_time, eval_every)
            return self._run_per_event(max_events, max_time, eval_every)

    def _maybe_sanitized(self):
        """The runtime sanitizer context when enabled, else a no-op.

        Wraps the whole driving loop: every trace runs under
        ``jax.checking_leaks`` and every implicit device→host transfer
        (the ~100 µs/event sync class) raises instead of silently blocking
        — the runner's explicit per-drain ``jax.device_get`` stays legal.
        """
        if not self.sanitize:
            return contextlib.nullcontext()
        from repro.check.runtime import sanitized
        self._log.log("sanitize", check_leaks=True, transfer_guard="disallow")
        return sanitized()

    def _run_per_event(self, max_events, max_time, eval_every) -> RunResult:
        self._ensure_per_event()
        history: List[HistoryPoint] = []
        comm = 0
        active_sizes: List[int] = []
        t = 0.0
        k = -1
        rounds = 0
        for ev in self.scheduler.events():
            if max_events is not None and ev.k >= max_events:
                break
            if max_time is not None and ev.time > max_time:
                break
            k, t = ev.k, ev.time
            comm += ev.param_copies_sent
            active_sizes.append(ev.n_active)
            if self.trace:
                self._trace.record_event(ev)
            eta = jnp.float32(
                self.eta0 * (self.eta_decay ** (rounds // self.eta_decay_every)))
            P_dev = jnp.asarray(ev.P, dtype=jnp.float32)
            gm_dev = jnp.asarray(ev.grad_workers)
            rm_dev = jnp.asarray(ev.restart_workers)
            self.W, self.S, self.y = self._step(
                self.W, self.S, self.y, self._batches,
                P_dev, gm_dev, rm_dev, eta,
            )
            if self.telemetry:
                # same per-event quantities the scan paths pack: per-lane
                # raw completion clocks scattered over the event-time base
                fin = np.full(self.n, ev.time)
                if ev.finish_lanes is not None and len(ev.workers):
                    fin[ev.workers] = ev.finish_lanes
                self._metrics = self._metrics_step(
                    self._metrics, P_dev, gm_dev, rm_dev,
                    jnp.float32(ev.time),
                    jnp.asarray(fin, dtype=jnp.float32),
                    jnp.int32(rounds),
                    jnp.int32(ev.param_copies_sent))
            self._refresh_batches(ev.workers[ev.restart_lanes])
            rounds += 1
            if rounds % eval_every == 0:
                loss, metric = self._eval_now()
                history.append(HistoryPoint(
                    k=k, time=t, loss=loss, metric=metric,
                    comm_param_copies=comm,
                    n_active_mean=float(np.mean(active_sizes[-eval_every:])),
                ))
        return self._finish(history, k, t, comm, rounds, active_sizes)

    def _run_scan(self, max_events, max_time, eval_every) -> RunResult:
        self._ensure_scan(max_events, max_time)
        self._ensure_eval_accum()
        bound = self.scheduler.edge_bound()
        # With eval_every < block_size every chunk is exactly eval_every
        # events, so padding to this target (not block_size) wastes nothing
        # while still compiling a single block shape for the whole run.
        target = min(self.block_size, eval_every)
        # Eval scalars accumulate in a device buffer (one .at[i].set dispatch
        # per eval, zero host syncs); meta carries the host-side fields and
        # everything is fetched once in _finish_scan.
        cap = max(2, (max_events // eval_every + 2) if max_events else 16)
        eval_buf = jnp.zeros((cap, 2), dtype=jnp.float32)
        meta: List[Tuple[int, float, int, float]] = []  # (k, t, comm, a_mean)
        comm = 0
        active_sizes: List[int] = []
        t = 0.0
        k = -1
        rounds = 0
        buf = []
        stream = self.scheduler.events()
        exhausted = False
        while not exhausted:
            try:
                ev = next(stream)
            except StopIteration:  # finite custom stream: flush what we have
                ev = None
            if (ev is None
                    or (max_events is not None and ev.k >= max_events)
                    or (max_time is not None and ev.time > max_time)):
                exhausted = True
            else:
                buf.append(ev)
                k, t = ev.k, ev.time
                comm += ev.param_copies_sent
                active_sizes.append(ev.n_active)
            # Snap block boundaries to the eval grid so the history matches
            # the per-event path point-for-point.
            until_eval = eval_every - rounds % eval_every
            flush = len(buf) >= min(target, until_eval) or (
                exhausted and buf)
            if not flush:
                continue
            if self.trace:
                # recorded pre-pack, pre-pad: the same object events the
                # per-event reference replays, so the traces bit-match
                self._trace.record_events(buf)
            self._dispatch_block(
                EventBatch.from_events(buf, edge_bound=bound), rounds,
                target)
            rounds += len(buf)
            buf = []
            if rounds % eval_every == 0:
                eval_buf = self._record_eval(eval_buf, len(meta))
                meta.append((k, t, comm,
                             float(np.mean(active_sizes[-eval_every:]))))
        self._warn_pool_wrap(rounds)
        return self._finish_scan(eval_buf, meta, k, t, comm, rounds,
                                 active_sizes)

    def _warn_pool_wrap(self, rounds: int) -> None:
        # host-side max: keeps this off the compile cache (a jnp.max here
        # would be the run's only reduce op — one more first-run compile)
        max_ptr = int(np.max(jax.device_get(self._ptr))) if rounds else 0
        if max_ptr > self._pool_len:
            self._log.warn_once(
                "pool_wrap",
                f"batch pool of {self._pool_len} draws/worker wrapped "
                f"(max restarts {max_ptr}): samples were "
                "revisited cyclically; raise batch_pool (or bound the run "
                "by max_events) for exact per-event sampling semantics.")

    def _run_sparse_stream(self, max_events, max_time, eval_every) -> RunResult:
        """The sparse path's driving loop, over *packed chunks*.

        Replaces the object-event buffered loop for ``mode="sparse_scan"``:
        the stream arrives ``next_chunk``-at-a-time already in
        ``SparseEventBatch`` / ``BucketedSparseEventBatch`` array form
        (array-natively generated where the scheduler supports it), and the
        per-chunk metadata — virtual clocks, copy counts, active sizes —
        is read from the packed arrays in vectorized form.  Event order,
        eval-grid snapping and recorded history are identical to the
        object path's (pinned by tests/test_sparse_event_stream.py).
        """
        self._ensure_sparse(max_events, max_time)
        self._ensure_eval_accum()
        target = min(self.block_size, eval_every)
        cap = max(2, (max_events // eval_every + 2) if max_events else 16)
        eval_buf = jnp.zeros((cap, 2), dtype=jnp.float32)
        meta: List[Tuple[int, float, int, float]] = []  # (k, t, comm, a_mean)
        comm = 0
        active_sizes: List[int] = []
        t = 0.0
        k = -1
        rounds = 0
        stream = self.scheduler.packed_stream(native=self.native_generation)
        exhausted = False
        while not exhausted:
            until_eval = eval_every - rounds % eval_every
            want = min(target, until_eval)
            if max_events is not None:
                want = min(want, max_events - rounds)
            if want <= 0:
                break
            chunk = stream.next_chunk(want)
            if chunk is None:
                break
            if chunk.E < want:  # finite custom stream ended mid-chunk
                exhausted = True
            tms = chunk.stream_times()
            if max_time is not None and tms[-1] > max_time:
                exhausted = True
                j = int(np.argmax(tms > max_time))
                if j == 0:
                    break
                chunk = chunk.head(j)
                tms = tms[:j]
            comm += int(chunk.stream_copies().sum())
            active_sizes.extend(chunk.stream_n_active().tolist())
            t = float(tms[-1])
            k = rounds + chunk.E - 1
            if self.trace:
                # pre-merge, pre-pad packed arrays (bucketed chunks are
                # walked segment-by-segment in stream order)
                self._trace.record_chunk(chunk)
            if isinstance(chunk, BucketedSparseEventBatch):
                if self.telemetry:
                    self._accum_occupancy(chunk.occupancy())
                self._dispatch_bucketed(chunk, rounds, target)
            else:
                if self.telemetry:
                    self._accum_occupancy([{
                        "A": int(chunk.A), "events": int(chunk.E),
                        "lane_fill": float(chunk.n_workers.sum())
                        / max(chunk.E * chunk.A, 1)}])
                self._dispatch_sparse_chunk(chunk, rounds, target)
            rounds += chunk.E
            if rounds % eval_every == 0:
                eval_buf = self._record_eval(eval_buf, len(meta))
                meta.append((k, t, comm,
                             float(np.mean(active_sizes[-eval_every:]))))
        self._warn_pool_wrap(rounds)
        return self._finish_scan(eval_buf, meta, k, t, comm, rounds,
                                 active_sizes)

    # -- fused mode --------------------------------------------------------
    def _ensure_fused(self, max_events: Optional[int] = None):
        if self._fused is None:
            from repro.core.fused import build_fused_pair_scan
            self._log.log("compile", key="fused", telemetry=self.telemetry,
                          trace=self.trace)
            # trace reuses telemetry's widened scan outputs — the block
            # streams the identity tuple either way, so trace=True adds
            # zero device work beyond what telemetry already pays
            self._fused = build_fused_pair_scan(
                self.loss_fn, self.scheduler.fused_spec(),
                use_kernel=self.use_kernel,
                telemetry=self.telemetry or self.trace)
            # Same aliasing hazard as _ensure_sparse: the fused block
            # donates both W and S.
            if any(w is s for w, s in zip(jax.tree.leaves(self.W),
                                          jax.tree.leaves(self.S))):
                self.S = jax.tree.map(jnp.array, self.S)
            if self.telemetry:
                self._fused_fold = jax.jit(fused_metrics_fold,
                                           static_argnums=(5,))
        self._ensure_metrics()
        self._ensure_pools(max_events)

    def _run_fused(self, max_events, max_time, eval_every) -> RunResult:
        """Drive the generate-and-consume block (``mode="fused"``).

        Per block the host's only work is two vectorized RNG draws; the
        event process itself (who fires, when, with whom) lives in the
        compiled scan's carry.  The virtual clock is device-resident too,
        so runs are bounded by ``max_events`` only.
        """
        if not max_events:
            raise ValueError(
                "mode='fused' runs are bounded by max_events; max_time is "
                "unsupported (the virtual clock lives on device — bounding "
                "by it would force a host sync per block)")
        if max_time is not None:
            raise ValueError("mode='fused' does not support max_time")
        sched = self.scheduler
        self._ensure_fused(max_events)
        self._ensure_eval_accum()
        copies_pair = int(sched.fused_spec()["copies_pair"])
        if self._fused_clock is None:
            self._fused_clock = (
                jnp.asarray(sched.fused_initial_times(), dtype=jnp.float32),
                jnp.float32(0.0))
        times, lock_free = self._fused_clock
        comm_dev = jnp.int32(0)
        blk = max(1, min(self.block_size, eval_every, max_events))
        # Eval rows carry [loss, metric, t_last, comm] — the virtual clock
        # and the copy counter stay on device; everything is fetched once
        # at the end.  The buffer starts at the same fixed shape warmup()
        # precompiled the record scatter for, and doubles on demand
        # (log₂(evals) growth compiles on the first run, none after).
        eval_buf = jnp.zeros((2, 4), dtype=jnp.float32)
        meta: List[Tuple[int, int]] = []  # (k, rounds_at_eval)
        rounds = 0
        while rounds < max_events:
            until_eval = eval_every - rounds % eval_every
            E = min(blk, until_eval, max_events - rounds)
            factors, picks = sched.fused_draws(E)
            # f32 cast on host: jnp.asarray of an f64 array would insert a
            # convert_element_type op (a first-run compile); a same-dtype
            # asarray is a pure device put
            etas = np.asarray(self._etas_for(E, E, rounds), dtype=np.float32)
            xs = (jnp.asarray(factors, dtype=jnp.float32),
                  jnp.asarray(picks, dtype=jnp.float32),
                  jnp.asarray(etas, dtype=jnp.float32))
            self._log.log("block_dispatch", mode="fused", events=E,
                          rounds=rounds)
            with jax.profiler.TraceAnnotation("dispatch:fused"):
                (self.W, self.S, self.y, self._ptr, times, lock_free,
                 comm_dev), ys = self._fused(
                    self.W, self.S, self.y, self._ptr, self._pools,
                    times, lock_free, comm_dev, *xs)
            if self.telemetry or self.trace:
                # buffer the block's (t_ev, i, p, t_raw) event stream on
                # device — consumed once at drain (fused_metrics_fold /
                # drain_fused_payload), so telemetry and trace add no
                # in-loop work beyond the scan outputs
                self._fused_payload.append(ys)
                t_seq = ys[0]
            else:
                t_seq = ys
            rounds += E
            if rounds % eval_every == 0 or rounds >= max_events:
                eval_buf = self._fused_record(
                    eval_buf, len(meta), t_seq[-1], comm_dev)
                meta.append((rounds - 1, rounds))
        self._fused_clock = (times, lock_free)
        self._warn_pool_wrap(rounds)
        # one fetch; sliced on host (a device-side [:k] would compile a
        # slice executable on the first run)
        vals = np.asarray(jax.device_get(eval_buf))[:len(meta)]
        # comm is exact through f32 up to 2^24 copies; pair-event counts
        # (comm deltas / copies-per-pair) back out the mean active-set
        # size — 2 lanes per pair event, 1 per isolated-worker event.
        history = []
        prev_comm = 0
        prev_rounds = 0
        for i, (mk, mr) in enumerate(meta):
            loss, metric, tt, commf = (float(v) for v in vals[i])
            comm_i = int(round(commf))
            E_i = mr - prev_rounds
            pairs = ((comm_i - prev_comm) // copies_pair
                     if copies_pair else E_i)
            history.append(HistoryPoint(
                k=mk, time=tt, loss=loss, metric=metric,
                comm_param_copies=comm_i,
                n_active_mean=(E_i + min(pairs, E_i)) / max(E_i, 1)))
            prev_comm, prev_rounds = comm_i, mr
        t_end = history[-1].time
        trc = self._trace_summary()   # before telemetry: it clears payload
        tel = self._telemetry_summary(t_end)
        self._log.log("run_end", rounds=rounds, t=t_end,
                      comm=history[-1].comm_param_copies)
        return RunResult(
            algorithm=sched.name, history=history,
            final_loss=history[-1].loss, final_metric=history[-1].metric,
            total_events=rounds, total_time=t_end,
            total_comm_copies=history[-1].comm_param_copies,
            param_count=self.param_count,
            bytes_per_scalar=self.dtype.itemsize,
            telemetry=tel, trace=trc,
        )

    def _fused_record(self, eval_buf: jax.Array, i: int, t_last: jax.Array,
                      comm_dev: jax.Array) -> jax.Array:
        """Append one fused-mode history row ([loss, metric, t, comm]) —
        all eager device ops, no host sync; warmup() precompiles them."""
        row = jnp.concatenate([
            self._eval_accum(self.W, self.y, self.eval_batch),
            jnp.stack([t_last, comm_dev.astype(jnp.float32)])])
        if i == eval_buf.shape[0]:
            eval_buf = jnp.concatenate([eval_buf, jnp.zeros_like(eval_buf)])
        return eval_buf.at[jnp.asarray(i)].set(row)

    # -- on-device eval history -------------------------------------------
    def _ensure_eval_accum(self):
        if self._eval_accum is not None:
            return
        eval_fn = self.eval_fn

        @jax.jit
        def eval_row(W, y, batch):
            loss, metric = eval_fn(debiased_average(W, y), batch)
            return jnp.stack([jnp.asarray(loss, dtype=jnp.float32),
                              jnp.asarray(metric, dtype=jnp.float32)])

        self._eval_accum = eval_row

    def _record_eval(self, eval_buf: jax.Array, i: int) -> jax.Array:
        # The jitted part (eval at the de-biased average) has run-independent
        # shapes — warmup() precompiles it; the scatter into the history
        # buffer is a tiny eager device op (dynamic index: one executable
        # regardless of i or buffer growth).  No host sync anywhere.
        row = self._eval_accum(self.W, self.y, self.eval_batch)
        if i == eval_buf.shape[0]:  # max_time-bounded run outgrew the buffer
            eval_buf = jnp.concatenate([eval_buf, jnp.zeros_like(eval_buf)])
        return eval_buf.at[jnp.asarray(i)].set(row)

    def _finish_scan(self, eval_buf, meta, k, t, comm, rounds,
                     active_sizes) -> RunResult:
        eval_buf = self._record_eval(eval_buf, len(meta))
        meta.append((k, t, comm,
                     float(np.mean(active_sizes)) if active_sizes else 0.0))
        vals = np.asarray(jax.device_get(eval_buf[:len(meta)]))  # one fetch
        history = [
            HistoryPoint(k=mk, time=mt, loss=float(vals[i, 0]),
                         metric=float(vals[i, 1]), comm_param_copies=mc,
                         n_active_mean=ma)
            for i, (mk, mt, mc, ma) in enumerate(meta)]
        trc = self._trace_summary()
        tel = self._telemetry_summary(t)
        self._log.log("run_end", rounds=rounds, t=t, comm=comm)
        return RunResult(
            algorithm=self.scheduler.name, history=history,
            final_loss=history[-1].loss, final_metric=history[-1].metric,
            total_events=rounds, total_time=t, total_comm_copies=comm,
            param_count=self.param_count,
            bytes_per_scalar=self.dtype.itemsize,
            telemetry=tel, trace=trc,
        )

    def _finish(self, history, k, t, comm, rounds, active_sizes) -> RunResult:
        loss, metric = self._eval_now()
        history.append(HistoryPoint(
            k=k, time=t, loss=loss, metric=metric, comm_param_copies=comm,
            n_active_mean=float(np.mean(active_sizes)) if active_sizes else 0.0))
        trc = self._trace_summary()
        tel = self._telemetry_summary(t)
        self._log.log("run_end", rounds=rounds, t=t, comm=comm)
        return RunResult(
            algorithm=self.scheduler.name, history=history,
            final_loss=loss, final_metric=metric,
            total_events=rounds, total_time=t, total_comm_copies=comm,
            param_count=self.param_count,
            bytes_per_scalar=self.dtype.itemsize,
            telemetry=tel, trace=trc,
        )

    def _eval_now(self):
        avg = debiased_average(self.W, self.y)
        # explicit fetch: float() on the device scalars would be an implicit
        # d2h sync (the runtime sanitizer's transfer guard rejects those)
        loss, metric = jax.device_get(self._eval(avg, self.eval_batch))
        return float(loss), float(metric)


def _identity_event(n: int):
    from repro.core.scheduler import ScheduleEvent
    return ScheduleEvent(
        k=0, time=0.0, n=n,
        workers=np.zeros(0, dtype=np.int32),
        P_sub=np.zeros((0, 0), dtype=np.float32),
        grad_lanes=np.zeros(0, dtype=bool),
        restart_lanes=np.zeros(0, dtype=bool),
        edges=np.zeros((0, 2), dtype=np.int32), param_copies_sent=0)


def run_algorithms(
    algorithms: Dict[str, Scheduler],
    make_trainer: Callable[[Scheduler], DecentralizedTrainer],
    **run_kw,
) -> Dict[str, RunResult]:
    """Run several algorithms under identical model/data settings."""
    out = {}
    for name, sched in algorithms.items():
        trainer = make_trainer(sched)
        out[name] = trainer.run(**run_kw)
    return out
