"""Decentralized training driver: any scheduler × any model × any data.

Consumes a scheduler's event stream and advances the stacked worker state
with the updates from core/aau.py.  Records loss / accuracy versus both the
iteration counter and the *virtual wall-clock*, plus cumulative
communication, reproducing the paper's Figures 3–5 measurement protocol.

Execution model — block-compiled by default (``mode="scan"``):

- The event stream is packed ``block_size`` events at a time into
  :class:`~repro.core.scheduler.EventBatch` stacked arrays and replayed on
  device through one compiled ``lax.scan`` call per block
  (``masked_gossip_scan``) — one XLA dispatch and zero host round-trips per
  E events, instead of the legacy one-dispatch-per-event interpreter.
- Per-worker batches come from a pre-drawn on-device sample pool indexed by
  a restart counter the scan carries.  By default the pool is sized from the
  first run's ``max_events`` bound (capped at 1024), which guarantees exact
  per-event sampling semantics; pass ``batch_pool`` to fix the size
  explicitly.  The pointer wraps modulo the pool, so runs with more restarts
  per worker than the pool revisit samples cyclically — a warning is issued
  once if that happens.
- Evaluation stays on device and fires every ``eval_every`` events; block
  boundaries are snapped to the eval grid and truncated blocks are padded
  with identity no-op events, so a single compiled program serves the whole
  run and the recorded history matches the per-event path point-for-point.

The legacy interpreter is kept behind ``mode="per_event"`` for equivalence
testing (tests/test_event_stream.py) and as the reference semantics.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aau import (build_event_scan, build_event_step,
                            debiased_average)
from repro.core.scheduler import EventBatch, Scheduler
from repro.utils.tree import tree_size, tree_stack


@dataclasses.dataclass
class HistoryPoint:
    k: int
    time: float
    loss: float
    metric: float
    comm_param_copies: int
    n_active_mean: float


@dataclasses.dataclass
class RunResult:
    algorithm: str
    history: List[HistoryPoint]
    final_loss: float
    final_metric: float
    total_events: int
    total_time: float
    total_comm_copies: int
    param_count: int

    def comm_bytes(self, bytes_per_scalar: int = 4) -> int:
        return self.total_comm_copies * self.param_count * bytes_per_scalar

    def time_to_loss(self, target: float) -> Optional[float]:
        for p in self.history:
            if p.loss <= target:
                return p.time
        return None

    def iters_to_loss(self, target: float) -> Optional[int]:
        for p in self.history:
            if p.loss <= target:
                return p.k
        return None


class DecentralizedTrainer:
    """Runs one algorithm on one model/dataset under one straggler model."""

    def __init__(
        self,
        scheduler: Scheduler,
        loss_fn: Callable,                  # loss_fn(params, batch) -> scalar
        init_params_fn: Callable,           # init_params_fn(rng) -> pytree
        worker_batch_fn: Callable,          # worker_batch_fn(worker, step) -> batch pytree
        eval_batch,                         # held-out batch for the global model
        eval_fn: Optional[Callable] = None, # eval_fn(params, batch) -> (loss, metric)
        eta0: float = 0.1,
        eta_decay: float = 1.0,             # paper uses η(k) = η₀ · δᵏ with δ=0.95 per *round*
        eta_decay_every: int = 1,
        seed: int = 0,
        use_kernel: bool = False,
        same_init: bool = True,
        mode: str = "scan",                 # "scan" (block-compiled) | "per_event" (legacy)
        block_size: int = 32,               # events per compiled scan call
        batch_pool: Optional[int] = None,   # pre-drawn samples per worker
                                            # (scan mode; None = auto from the
                                            # first run's max_events, cap 1024)
    ):
        if mode not in ("scan", "per_event"):
            raise ValueError(f"mode must be 'scan' or 'per_event', got {mode!r}")
        self.scheduler = scheduler
        self.n = scheduler.n
        self.loss_fn = loss_fn
        self.eval_fn = eval_fn or (lambda p, b: (loss_fn(p, b), 0.0))
        self.worker_batch_fn = worker_batch_fn
        self.eval_batch = eval_batch
        self.eta0, self.eta_decay, self.eta_decay_every = eta0, eta_decay, eta_decay_every
        self.use_kernel = use_kernel
        self.mode = mode
        self.block_size = max(1, block_size)
        self.batch_pool = batch_pool if batch_pool is None else max(1, batch_pool)
        rng = jax.random.PRNGKey(seed)
        if same_init:
            p0 = init_params_fn(rng)
            params = [p0] * self.n
        else:
            params = [init_params_fn(k) for k in jax.random.split(rng, self.n)]
        self.W = tree_stack(params)
        self.S = self.W
        self.y = jnp.ones((self.n,), dtype=jnp.float32)
        self.param_count = tree_size(params[0])
        self._eval = jax.jit(self.eval_fn)
        # Per-mode state built lazily on first use (avoids tracing both paths).
        self._step = None           # per-event jitted update
        self._batches = None        # per-event current batch stack
        self._draw_count = np.zeros(self.n, dtype=np.int64)
        self._scan = None           # block-compiled jitted update
        self._pools = None          # (n, batch_pool, ...) on-device sample pools
        self._ptr = None            # (n,) int32 restart counters

    # -- legacy per-event state -------------------------------------------
    def _ensure_per_event(self):
        if self._step is None:
            self._step = build_event_step(self.loss_fn, use_kernel=self.use_kernel)
            self._batches = tree_stack([self._draw(i) for i in range(self.n)])

    def _draw(self, worker: int):
        b = self.worker_batch_fn(worker, int(self._draw_count[worker]))
        self._draw_count[worker] += 1
        return b

    def _refresh_batches(self, restart_mask: np.ndarray) -> None:
        idx = np.nonzero(restart_mask)[0]
        if len(idx) == 0:
            return
        new = {int(i): self._draw(int(i)) for i in idx}

        def upd(leaf_batches, getter):
            arr = np.array(leaf_batches)  # host copy (jax buffers are read-only)
            for i, b in new.items():
                arr[i] = np.asarray(getter(b))
            return jnp.asarray(arr)

        leaves, treedef = jax.tree.flatten(self._batches)
        new_leaves = []
        for li, leaf in enumerate(leaves):
            new_leaves.append(upd(leaf, lambda b, li=li: jax.tree.leaves(b)[li]))
        self._batches = jax.tree.unflatten(treedef, new_leaves)

    # -- scan-mode state ---------------------------------------------------
    def _ensure_scan(self, max_events: Optional[int] = None):
        if self._scan is None:
            self._scan = build_event_scan(self.loss_fn, use_kernel=self.use_kernel)
            # Restarts per worker are bounded by total events, so a pool of
            # max_events draws never wraps; explicit batch_pool overrides.
            if self.batch_pool is not None:
                pool_len = self.batch_pool
            else:
                pool_len = min(max_events, 1024) if max_events else 64
            self._pool_len = pool_len
            # pool[i, s] = the s-th batch worker i would draw — identical to
            # the legacy path's draw sequence, moved on-device ahead of time.
            self._pools = tree_stack([
                tree_stack([self.worker_batch_fn(w, s)
                            for s in range(pool_len)])
                for w in range(self.n)])
            self._ptr = jnp.zeros((self.n,), dtype=jnp.int32)

    def _dispatch_block(self, batch: EventBatch, rounds: int,
                        target: Optional[int] = None) -> None:
        """One compiled call: pad to the block shape, advance (W, S, y, ptr)."""
        E = batch.E
        if target is None:
            target = self.block_size
        if E < target:
            batch = batch.pad_to(target)
        etas = self.eta0 * self.eta_decay ** (
            (rounds + np.arange(batch.E)) // self.eta_decay_every)
        if E < batch.E:
            etas[E:] = 0.0  # padded no-op events (masks are already all-False)
        self.W, self.S, self.y, self._ptr = self._scan(
            self.W, self.S, self.y, self._ptr, self._pools,
            jnp.asarray(batch.P, dtype=jnp.float32),
            jnp.asarray(batch.grad_workers),
            jnp.asarray(batch.restart_workers),
            jnp.asarray(etas, dtype=jnp.float32),
        )

    def warmup(self) -> None:
        """Compile this trainer's update and eval with no-op dispatches.

        State is left exactly unchanged (identity P, all-False masks — η is
        traced data, so its warmup values don't matter), letting benchmarks
        separate compile time from steady-state throughput.  In scan mode
        the compiled block shape is ``block_size``; a subsequent run whose
        ``eval_every`` is smaller re-traces once at the smaller shape.
        """
        n = self.n
        noop = EventBatch.from_events(
            [_identity_event(n)], edge_bound=1).pad_to(
                self.block_size if self.mode == "scan" else 1)
        if self.mode == "scan":
            self._ensure_scan()
            self._dispatch_block(noop, rounds=0)
            self.y.block_until_ready()
        else:
            self._ensure_per_event()
            ev = noop.to_events()[0]
            self.W, self.S, self.y = self._step(
                self.W, self.S, self.y, self._batches,
                jnp.asarray(ev.P, dtype=jnp.float32),
                jnp.asarray(ev.grad_workers), jnp.asarray(ev.restart_workers),
                jnp.float32(0.0),
            )
            self.y.block_until_ready()
        self._eval_now()

    # -- driving loop ------------------------------------------------------
    def run(
        self,
        max_events: Optional[int] = None,
        max_time: Optional[float] = None,
        eval_every: int = 10,
    ) -> RunResult:
        assert max_events or max_time, "bound the run by events or virtual time"
        if self.mode == "scan":
            return self._run_scan(max_events, max_time, eval_every)
        return self._run_per_event(max_events, max_time, eval_every)

    def _run_per_event(self, max_events, max_time, eval_every) -> RunResult:
        self._ensure_per_event()
        history: List[HistoryPoint] = []
        comm = 0
        active_sizes: List[int] = []
        t = 0.0
        k = -1
        rounds = 0
        for ev in self.scheduler.events():
            if max_events is not None and ev.k >= max_events:
                break
            if max_time is not None and ev.time > max_time:
                break
            k, t = ev.k, ev.time
            comm += ev.param_copies_sent
            active_sizes.append(ev.n_active)
            eta = jnp.float32(
                self.eta0 * (self.eta_decay ** (rounds // self.eta_decay_every)))
            self.W, self.S, self.y = self._step(
                self.W, self.S, self.y, self._batches,
                jnp.asarray(ev.P, dtype=jnp.float32),
                jnp.asarray(ev.grad_workers), jnp.asarray(ev.restart_workers),
                eta,
            )
            self._refresh_batches(ev.restart_workers)
            rounds += 1
            if rounds % eval_every == 0:
                loss, metric = self._eval_now()
                history.append(HistoryPoint(
                    k=k, time=t, loss=loss, metric=metric,
                    comm_param_copies=comm,
                    n_active_mean=float(np.mean(active_sizes[-eval_every:])),
                ))
        return self._finish(history, k, t, comm, rounds, active_sizes)

    def _run_scan(self, max_events, max_time, eval_every) -> RunResult:
        self._ensure_scan(max_events)
        bound = self.scheduler.edge_bound()
        # With eval_every < block_size every chunk is exactly eval_every
        # events, so padding to this target (not block_size) wastes nothing
        # while still compiling a single block shape for the whole run.
        target = min(self.block_size, eval_every)
        history: List[HistoryPoint] = []
        comm = 0
        active_sizes: List[int] = []
        t = 0.0
        k = -1
        rounds = 0
        buf = []
        stream = self.scheduler.events()
        exhausted = False
        while not exhausted:
            try:
                ev = next(stream)
            except StopIteration:  # finite custom stream: flush what we have
                ev = None
            if (ev is None
                    or (max_events is not None and ev.k >= max_events)
                    or (max_time is not None and ev.time > max_time)):
                exhausted = True
            else:
                buf.append(ev)
                k, t = ev.k, ev.time
                comm += ev.param_copies_sent
                active_sizes.append(ev.n_active)
            # Snap block boundaries to the eval grid so the history matches
            # the per-event path point-for-point.
            until_eval = eval_every - rounds % eval_every
            flush = len(buf) >= min(target, until_eval) or (
                exhausted and buf)
            if not flush:
                continue
            self._dispatch_block(
                EventBatch.from_events(buf, edge_bound=bound), rounds, target)
            rounds += len(buf)
            buf = []
            if rounds % eval_every == 0:
                loss, metric = self._eval_now()
                history.append(HistoryPoint(
                    k=k, time=t, loss=loss, metric=metric,
                    comm_param_copies=comm,
                    n_active_mean=float(np.mean(active_sizes[-eval_every:])),
                ))
        if rounds and int(jnp.max(self._ptr)) > self._pool_len:
            warnings.warn(
                f"batch pool of {self._pool_len} draws/worker wrapped "
                f"(max restarts {int(jnp.max(self._ptr))}): samples were "
                "revisited cyclically; raise batch_pool (or bound the run "
                "by max_events) for exact per-event sampling semantics.")
        return self._finish(history, k, t, comm, rounds, active_sizes)

    def _finish(self, history, k, t, comm, rounds, active_sizes) -> RunResult:
        loss, metric = self._eval_now()
        history.append(HistoryPoint(
            k=k, time=t, loss=loss, metric=metric, comm_param_copies=comm,
            n_active_mean=float(np.mean(active_sizes)) if active_sizes else 0.0))
        return RunResult(
            algorithm=self.scheduler.name, history=history,
            final_loss=loss, final_metric=metric,
            total_events=rounds, total_time=t, total_comm_copies=comm,
            param_count=self.param_count,
        )

    def _eval_now(self):
        avg = debiased_average(self.W, self.y)
        loss, metric = self._eval(avg, self.eval_batch)
        return float(loss), float(metric)


def _identity_event(n: int):
    from repro.core.scheduler import ScheduleEvent
    return ScheduleEvent(
        k=0, time=0.0,
        grad_workers=np.zeros(n, dtype=bool),
        restart_workers=np.zeros(n, dtype=bool),
        P=np.eye(n, dtype=np.float32), active_edges=(), param_copies_sent=0)


def run_algorithms(
    algorithms: Dict[str, Scheduler],
    make_trainer: Callable[[Scheduler], DecentralizedTrainer],
    **run_kw,
) -> Dict[str, RunResult]:
    """Run several algorithms under identical model/data settings."""
    out = {}
    for name, sched in algorithms.items():
        trainer = make_trainer(sched)
        out[name] = trainer.run(**run_kw)
    return out
