"""Worker compute-time models (the paper's straggler protocol, §6 + appendix D).

The paper randomly selects workers as stragglers each iteration with
probability ``p`` ("straggler probability", default 10%); a straggler's local
computation is slowed by a factor ``s`` (ablated 5×–40×, default 10×; 6× in
§6).  We add optional persistent heterogeneity (lognormal base speeds) to
model heterogeneous hardware, and a deterministic seed so every experiment is
reproducible.

This pair is the canonical instance of the scenario layer's protocols
(repro/scenarios/base.py): ``StragglerModel`` satisfies ``TimeModelSpec``
(``n`` + ``base_time`` + ``make_sampler``) and ``TimeSampler`` satisfies
``TimeModel`` (``sample``/``sample_batch``/``sample_horizon``/``sample_all``
+ ``base``).  Schedulers only consume those surfaces, so any registered
scenario — heavy-tailed, bimodal, diurnal, churn — drops in wherever a
``StragglerModel`` was accepted; the ``paper_default`` scenario returns an
actual ``TimeSampler`` and is therefore bit-exact with these streams.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class StragglerModel:
    n: int
    straggler_prob: float = 0.10          # paper default 10%
    slowdown: float = 10.0                # paper default 10× (6× in §6 example)
    base_time: float = 1.0                # mean local-gradient time (virtual seconds)
    heterogeneity: float = 0.0            # lognormal sigma of persistent per-worker speed
    jitter: float = 0.05                  # iid lognormal noise per computation
    seed: int = 0

    def make_sampler(self) -> "TimeSampler":
        return TimeSampler(self)


class TimeSampler:
    """Stateful sampler: ``sample(worker) -> duration`` of one local gradient."""

    #: Duration *factors* (jitter × straggler slowdown) are iid across
    #: workers and draws — per-worker structure lives entirely in ``base``
    #: — so a pre-drawn flat factor stream may be assigned to workers in
    #: any order (the fused on-device generator's gate, core/fused.py).
    iid_horizon = True

    #: rng-order sampler surface (repro.check): duration draws happen in
    #: these methods only; ``__init__`` pins the heterogeneity draw.
    rng_methods = ("sample", "sample_batch", "sample_horizon")

    def __init__(self, model: StragglerModel):
        self.model = model
        self._rng = np.random.default_rng(model.seed)
        if model.heterogeneity > 0:
            self.base = model.base_time * self._rng.lognormal(
                mean=0.0, sigma=model.heterogeneity, size=model.n)
        else:
            self.base = np.full(model.n, model.base_time)

    def sample(self, worker: int) -> float:
        m = self.model
        t = self.base[worker]
        if m.jitter > 0:
            t *= self._rng.lognormal(mean=0.0, sigma=m.jitter)
        if self._rng.random() < m.straggler_prob:
            t *= m.slowdown
        return float(t)

    def sample_batch(self, workers) -> np.ndarray:
        """Vectorized draw: one RNG call per distribution for many workers.

        Schedulers restart whole worker sets per event (all of them at t=0);
        drawing their next completion times one `sample()` at a time is the
        event-*generation* hot loop at paper scale.  A single lognormal and a
        single uniform vector draw replace 2·m scalar RNG calls.  For m == 1
        this consumes the generator stream exactly like `sample()` (same
        draw order), so single-restart schedulers keep their streams.
        """
        m = self.model
        workers = np.asarray(workers, dtype=np.intp)
        t = self.base[workers].astype(np.float64, copy=True)
        if m.jitter > 0:
            t *= self._rng.lognormal(mean=0.0, sigma=m.jitter,
                                     size=workers.shape)
        t = np.where(self._rng.random(workers.shape) < m.straggler_prob,
                     t * m.slowdown, t)
        return t

    def sample_horizon(self, k: int) -> np.ndarray:
        """K future duration *factors* drawn at once (event-horizon batching).

        Returns (k,) multiplicative factors — jitter × straggler slowdown —
        to be applied to per-worker base times as completions are assigned:
        ``duration_j = base[worker_j] * factors[j]``.  The distribution of
        each factor is identical to one ``sample()`` draw, but the generator
        stream is consumed as one lognormal(k) then one uniform(k) vector
        call instead of k interleaved scalar pairs, so the resulting event
        stream is a *different* (equally valid, fully deterministic)
        realization than the per-event one — see the ``horizon`` option on
        the single-edge schedulers in core/baselines.py for the trade-off.
        """
        m = self.model
        if m.jitter > 0:
            f = self._rng.lognormal(mean=0.0, sigma=m.jitter, size=k)
        else:
            f = np.ones(k)
        return np.where(self._rng.random(k) < m.straggler_prob,
                        f * m.slowdown, f)

    def sample_all(self) -> np.ndarray:
        return self.sample_batch(np.arange(self.model.n))
