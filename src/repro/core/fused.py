"""Fused device-resident event streaming for single-edge schedulers.

``DecentralizedTrainer(mode="fused")`` — the third stage of the
device-resident event pipeline.  The sparse scan path already consumes
events in compiled blocks, but the events themselves are still *produced*
by a Python heap loop and shipped through packed host arrays; for AD-PSGD
and AGP the event process is simple enough to move on device entirely.
Per event it is a pure recurrence over per-worker next-completion times
(the asynchronous-gossip clock model of Lian et al. 2018 / Assran &
Rabbat 2020):

    i   = argmin(times)                     # next finisher
    t   = lock-shift(times[i])              # AD-PSGD's atomic-average lock
    r   = neighbors[i][⌊pick·deg(i)⌋]       # uniform neighbor pick
    ... 2-lane sparse update on (W, S, y, ptr) ...
    times[i] = t + base[i] · factor         # next completion draw

so one ``lax.scan`` both *generates* the event (argmin "heap" carried in
the scan) and *consumes* it (``sparse_event_update`` — the identical
traced computation the sparse path's scan step runs).  The host's only
job per block is two vectorized RNG draws (completion-time factors and
neighbor picks, ``_SingleEdgeScheduler.fused_draws``); there is no
per-event host work, no packed-array transfer, and no ~100 µs/event
scan-step cost paid on host-visible shapes.

Like the event-horizon batcher (``horizon=K``), the fused stream is
**deterministic but a different RNG-order realization** than the exact
per-event path: factors are drawn as a flat block stream and assigned to
workers in device-decided event order, the clock runs in float32, and the
neighbor pick maps a uniform through ``⌊pick·deg⌋`` instead of
``integers(0, deg)``.  Equivalence is therefore tested distributionally
(event rates, per-worker activation counts) plus exact determinism per
(seed, block size) — see tests/test_fused_stream.py — and the mode is
gated on iid completion-time factors (``TimeModel.iid_horizon``): a
sampler whose factor law depends on the worker or the draw history
(diurnal scenario) cannot be pre-drawn flat.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aau import sparse_event_update

# An isolated worker's event: lane 0 keeps its row (purely local gradient
# step), lane 1 is padding.
_P_SELF2 = np.array([[1.0, 0.0], [0.0, 0.0]], dtype=np.float32)
_LANE_SELF2 = np.array([True, False])


def build_fused_pair_scan(loss_fn: Callable, spec: Dict[str, object],
                          use_kernel: bool = False, telemetry: bool = False):
    """Compile the fused generate-and-consume block for a pair scheduler.

    ``spec`` is ``_SingleEdgeScheduler.fused_spec()`` — the static device
    constants of the event process (padded neighbor table, degrees, base
    compute times, lock interval, the scheduler's frozen 2×2 payloads).

    Returns ``block(W, S, y, ptr, pools, times, lock_free, comm, factors,
    picks, etas) -> ((W, S, y, ptr, times, lock_free, comm), t_seq)``:
    one compiled call advances the worker state *and* the event process
    through ``len(factors)`` events; ``times`` is the (n,) f32 next-
    completion clock (the on-device replacement for the host heap),
    ``lock_free`` the scalar lock-release clock, ``comm`` the running
    int32 parameter-copy counter, and ``t_seq`` the per-event virtual
    clocks (the caller reads ``t_seq[-1]`` for history points).  The
    carry buffers are donated — thread the returned carry into the next
    block, never reuse the arguments.

    With ``telemetry`` the signature is unchanged; only the scan's
    per-event outputs widen from ``t_ev`` to ``(t_ev, i, p, t)`` — each
    event's lock-shifted clock, finisher, partner (−1 when isolated) and
    raw completion.  The runner buffers those outputs per block (device
    arrays, never synced) and consumes the whole run's stream once at
    drain time: folded into its
    :class:`~repro.obs.metrics.MetricsCarry` via
    :func:`~repro.obs.metrics.fused_metrics_fold`, and/or fetched with a
    single ``jax.device_get`` for the virtual-time trace
    (:func:`~repro.obs.trace.drain_fused_payload` — the runner passes
    ``telemetry=True`` here when *either* of its telemetry/trace flags is
    set, since both ride the same widened outputs).  The fused path thus
    stays free of per-event host work *and* of in-block observability
    arithmetic.  The state trajectory is unchanged.
    """
    grad_fn = jax.grad(loss_fn)
    deg = jnp.asarray(spec["deg"], dtype=jnp.int32)
    nbr_table = jnp.asarray(spec["nbr_table"], dtype=jnp.int32)
    base = jnp.asarray(spec["base"], dtype=jnp.float32)
    lock_dt = float(spec["lock_dt"])
    P1 = jnp.asarray(spec["P_first"], dtype=jnp.float32)
    P2 = jnp.asarray(spec["P_second"], dtype=jnp.float32)
    lane1 = jnp.asarray(spec["lane_first"])
    lane2 = jnp.asarray(spec["lane_second"])
    P_self = jnp.asarray(_P_SELF2)
    lane_self = jnp.asarray(_LANE_SELF2)
    copies_pair = int(spec["copies_pair"])

    def _event(W, S, y, ptr, pools, times, lock_free, factor, pick, eta):
        """One generated event: returns the updated state plus the event's
        identity ``(i, p, t, t_ev)`` — finisher, partner (−1 when
        isolated), raw and lock-shifted clocks — from which the callers
        derive comm/telemetry payloads (workers are the sorted pair, the
        finisher's lane is the grad/restart lane, a pair sends
        ``copies_pair`` copies)."""
        i = jnp.argmin(times).astype(jnp.int32)
        t = times[i]
        d = deg[i]
        has_nbr = d > 0
        if lock_dt:
            # serialized atomic averaging (isolated workers skip it)
            t_pair = jnp.maximum(t, lock_free) + jnp.float32(lock_dt)
            t_ev = jnp.where(has_nbr, t_pair, t)
            lock_free = jnp.where(has_nbr, t_ev, lock_free)
        else:
            t_ev = t
        # ⌊pick·deg⌋ clamped: pick ∈ [0, 1) but f32 rounding at huge
        # degree could land exactly on deg
        slot = jnp.minimum((pick * d.astype(jnp.float32))
                           .astype(jnp.int32),
                           jnp.maximum(d - 1, 0))
        r = nbr_table[i, slot]
        first = i < r
        pair = jnp.where(first, jnp.stack([i, r]), jnp.stack([r, i]))
        workers = jnp.where(has_nbr, pair,
                            jnp.stack([i, jnp.full((), -1, jnp.int32)]))
        P_sub = jnp.where(has_nbr, jnp.where(first, P1, P2), P_self)
        lanes = jnp.where(has_nbr,
                          jnp.where(first, lane1, lane2), lane_self)
        W, S, y, ptr = sparse_event_update(
            W, S, y, ptr, pools, grad_fn, workers, P_sub, lanes, lanes,
            eta, use_kernel=use_kernel)
        times = times.at[i].set(t_ev + base[i] * factor)
        p = jnp.where(has_nbr, r, jnp.int32(-1))
        return W, S, y, ptr, times, lock_free, i, p, t, t_ev

    @functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 5, 6, 7))
    def block(W, S, y, ptr, pools, times, lock_free, comm,
              factors, picks, etas):
        def body(carry, xs):
            W, S, y, ptr, times, lock_free, comm = carry
            factor, pick, eta = xs
            (W, S, y, ptr, times, lock_free, i, p, t,
             t_ev) = _event(W, S, y, ptr, pools, times, lock_free,
                            factor, pick, eta)
            comm = comm + jnp.where(p >= 0, copies_pair,
                                    0).astype(comm.dtype)
            # With telemetry the scan additionally streams each event's
            # identity (finisher, partner, raw clock) — the runner buffers
            # these per block, device-resident, and folds them ONCE per run
            # via repro.obs.metrics.fused_metrics_fold; metrics work inside
            # the block (even a per-block fold) is a measurable slice of
            # the fused block's toy-scale runtime.
            ys = (t_ev, i, p, t) if telemetry else t_ev
            return (W, S, y, ptr, times, lock_free, comm), ys

        return jax.lax.scan(body, (W, S, y, ptr, times, lock_free, comm),
                            (factors, picks, etas))

    return block
