"""Communication topologies for decentralized learning.

A topology is a symmetric adjacency structure over ``n`` workers.  The paper
assumes a strongly-connected undirected graph G = (N, E) (Assumption 2 requires
the union over a window to be strongly connected; a static connected graph
trivially satisfies it).

All graphs are represented by a frozen ``Graph`` holding a boolean numpy
adjacency matrix (no self loops stored; neighbor sets implicitly include self,
matching the paper's N_j = {i | (i,j) in E} ∪ {j}).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import FrozenSet, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    n: int
    adj: np.ndarray  # (n, n) bool, symmetric, zero diagonal

    def __post_init__(self):
        a = np.asarray(self.adj, dtype=bool)
        if a.shape != (self.n, self.n):
            raise ValueError(f"adjacency must be ({self.n},{self.n}), got {a.shape}")
        if not np.array_equal(a, a.T):
            raise ValueError("adjacency must be symmetric (undirected graph)")
        if np.any(np.diag(a)):
            raise ValueError("adjacency must have zero diagonal")
        object.__setattr__(self, "adj", a)

    # -- queries ---------------------------------------------------------
    def neighbors(self, j: int) -> np.ndarray:
        """Neighbor indices of worker j, excluding j itself."""
        return np.nonzero(self.adj[j])[0]

    @functools.cached_property
    def neighbor_lists(self) -> Tuple[np.ndarray, ...]:
        """Per-worker neighbor index arrays, scanned from ``adj`` once.

        The event-generation hot loops (schedulers, Pathsearch) index this
        per event; recomputing ``neighbors(j)`` there would rescan an
        adjacency row each time.
        """
        return tuple(np.nonzero(self.adj[j])[0] for j in range(self.n))

    def degree(self, j: int) -> int:
        return int(self.adj[j].sum())

    @property
    def edges(self) -> Tuple[Tuple[int, int], ...]:
        iu = np.triu_indices(self.n, k=1)
        mask = self.adj[iu]
        return tuple((int(i), int(j)) for i, j in zip(iu[0][mask], iu[1][mask]))

    def is_connected(self) -> bool:
        return is_strongly_connected(self.adj)

    def edge_set(self) -> FrozenSet[Tuple[int, int]]:
        return frozenset(self.edges)


def is_strongly_connected(adj: np.ndarray) -> bool:
    """BFS reachability check on a symmetric adjacency matrix."""
    n = adj.shape[0]
    if n == 0:
        return True
    seen = np.zeros(n, dtype=bool)
    stack = [0]
    seen[0] = True
    while stack:
        u = stack.pop()
        for v in np.nonzero(adj[u])[0]:
            if not seen[v]:
                seen[v] = True
                stack.append(int(v))
    return bool(seen.all())


# -- constructors ---------------------------------------------------------

def ring(n: int) -> Graph:
    adj = np.zeros((n, n), dtype=bool)
    for i in range(n):
        adj[i, (i + 1) % n] = adj[(i + 1) % n, i] = True
    if n == 2:
        adj = np.array([[False, True], [True, False]])
    return Graph(n, adj)


def fully_connected(n: int) -> Graph:
    adj = ~np.eye(n, dtype=bool)
    return Graph(n, adj)


def torus(rows: int, cols: int) -> Graph:
    """2-D torus: each worker connects to 4 grid neighbors (wrap-around)."""
    n = rows * cols
    adj = np.zeros((n, n), dtype=bool)

    def idx(r, c):
        return (r % rows) * cols + (c % cols)

    for r in range(rows):
        for c in range(cols):
            u = idx(r, c)
            for v in (idx(r + 1, c), idx(r, c + 1)):
                if u != v:
                    adj[u, v] = adj[v, u] = True
    return Graph(n, adj)


def erdos_renyi(n: int, p: float, seed: int = 0) -> Graph:
    """Random connected graph: ER(n, p) resampled/augmented until connected.

    This mirrors the paper's "randomly generate a connected graph".
    """
    rng = np.random.default_rng(seed)
    upper = rng.random((n, n)) < p
    adj = np.triu(upper, k=1)
    adj = adj | adj.T
    # Guarantee connectivity by adding a random Hamiltonian cycle's edges
    # where needed (keeps the graph random but connected, as in the paper).
    if not is_strongly_connected(adj):
        perm = rng.permutation(n)
        for a, b in zip(perm, np.roll(perm, 1)):
            adj[a, b] = adj[b, a] = True
        np.fill_diagonal(adj, False)
    return Graph(n, adj)


def multipod(n_per_pod: int, n_pods: int, inter_pod_edges: int = 2,
             intra: str = "torus", seed: int = 0) -> Graph:
    """Hierarchical pod topology: dense intra-pod (ICI), sparse inter-pod (DCI).

    Each pod is an intra-pod graph; ``inter_pod_edges`` distinct worker pairs
    bridge each pair of adjacent pods (ring of pods).  This is the graph used
    for the multi-pod dry-run: inter-pod gossip traffic is limited to the few
    bridge edges, unlike all-reduce which crosses DCI on every step.
    """
    n = n_per_pod * n_pods
    adj = np.zeros((n, n), dtype=bool)
    rng = np.random.default_rng(seed)
    for p in range(n_pods):
        off = p * n_per_pod
        if intra == "torus":
            rows = int(np.floor(np.sqrt(n_per_pod)))
            while n_per_pod % rows:
                rows -= 1
            sub = torus(rows, n_per_pod // rows).adj
        elif intra == "full":
            sub = fully_connected(n_per_pod).adj
        else:
            sub = ring(n_per_pod).adj
        adj[off:off + n_per_pod, off:off + n_per_pod] = sub
    for p in range(n_pods):
        q = (p + 1) % n_pods
        if q == p:
            continue
        picks_p = rng.choice(n_per_pod, size=inter_pod_edges, replace=False)
        picks_q = rng.choice(n_per_pod, size=inter_pod_edges, replace=False)
        for a, b in zip(picks_p, picks_q):
            u, v = p * n_per_pod + int(a), q * n_per_pod + int(b)
            adj[u, v] = adj[v, u] = True
    np.fill_diagonal(adj, False)
    return Graph(n, adj)


REGISTRY = {
    "ring": ring,
    "full": fully_connected,
    "torus": torus,
    "erdos_renyi": erdos_renyi,
    "multipod": multipod,
}
