"""Core library: the paper's contribution (DSGD-AAU) and its baselines."""
from repro.core import aau, baselines, consensus, pathsearch, scheduler, straggler, topology
from repro.core.aau import (
    build_event_step,
    debiased_average,
    gossip_mix_dense,
    masked_gossip_step,
    ring_gossip,
    tree_ring_gossip,
)
from repro.core.baselines import (
    ADPSGDScheduler,
    AGPScheduler,
    PragueScheduler,
    make_scheduler,
)
from repro.core.pathsearch import PathSearchState
from repro.core.runner import DecentralizedTrainer, RunResult, run_algorithms
from repro.core.scheduler import AAUScheduler, ScheduleEvent, Scheduler, SyncScheduler
from repro.core.straggler import StragglerModel
from repro.core.topology import Graph
