"""Consensus matrices with Metropolis weights (paper Assumption 1).

Given the per-iteration active structure — for each worker j the subset of
neighbors N_j(k) it waits for — we build the time-varying consensus matrix

    P_ij(k) = 1 / (1 + max(p_i(k), p_j(k)))   if j in N_i(k)  (active edge)
    P_ii(k) = 1 - sum_{j != i} P_ij(k)
    P_ij(k) = 0                               otherwise

where p_i(k) = |active neighbors of i at k|.  These weights make P(k) doubly
stochastic for *any* symmetric active-edge set, which is what Theorem 1 needs
(products of doubly-stochastic matrices + bounded-connectivity ⇒ geometric
consensus, Lemmas 1–2).

Inactive workers have row/col = e_i (identity): they keep their parameters,
matching "w_j(k+1) = w_j(k) if j not in N(k)" (Alg. 1 line 7).
"""
from __future__ import annotations

from typing import Iterable, Sequence, Tuple

import numpy as np

Edge = Tuple[int, int]


def metropolis_matrix(n: int, active_edges: Iterable[Edge]) -> np.ndarray:
    """Build the Metropolis consensus matrix for a set of symmetric active edges.

    ``active_edges`` are undirected pairs (i, j), i != j, each meaning workers
    i and j average with each other this iteration.
    """
    adj = np.zeros((n, n), dtype=bool)
    for i, j in active_edges:
        if i == j:
            raise ValueError("self edges are implicit; pass only i != j pairs")
        adj[i, j] = adj[j, i] = True
    deg = adj.sum(axis=1)  # p_i(k)
    P = np.zeros((n, n), dtype=np.float64)
    ii, jj = np.nonzero(adj)
    P[ii, jj] = 1.0 / (1.0 + np.maximum(deg[ii], deg[jj]))
    np.fill_diagonal(P, 1.0 - P.sum(axis=1))
    return P


def metropolis_submatrix(n: int, workers: np.ndarray,
                         sub_adj: np.ndarray) -> np.ndarray:
    """Active-set restriction of :func:`metropolis_matrix`, built at O(m·n).

    ``workers`` is the sorted (m,) global index set and ``sub_adj`` the (m, m)
    boolean active-edge adjacency *among those workers* (symmetric, zero
    diagonal).  Returns exactly ``metropolis_matrix(n, edges)[np.ix_(workers,
    workers)]`` — bit-identical, not merely close — without materializing the
    (n, n) matrix: off-diagonal weights depend only on active degrees, and the
    diagonal ``1 − Σ_j P_ij`` is summed over a scattered length-``n`` scratch
    row so the floating-point reduction tree matches the dense build's
    ``P.sum(axis=1)`` (numpy's pairwise summation is position-dependent;
    summing the compact row instead would drift in the last ulp).
    """
    m = len(workers)
    deg = sub_adj.sum(axis=1)
    P = np.zeros((m, m), dtype=np.float64)
    ii, jj = np.nonzero(sub_adj)
    P[ii, jj] = 1.0 / (1.0 + np.maximum(deg[ii], deg[jj]))
    scratch = np.zeros((m, n))
    scratch[np.arange(m)[:, None], np.asarray(workers)[None, :]] = P
    np.fill_diagonal(P, 1.0 - scratch.sum(axis=1))
    return P


def is_doubly_stochastic(P: np.ndarray, tol: float = 1e-9) -> bool:
    return (
        bool(np.all(P >= -tol))
        and bool(np.allclose(P.sum(axis=0), 1.0, atol=tol))
        and bool(np.allclose(P.sum(axis=1), 1.0, atol=tol))
    )


def consensus_product(mats: Sequence[np.ndarray]) -> np.ndarray:
    """Φ_{k:s} = P(s) P(s+1) ... P(k) (paper's left-to-right product)."""
    out = np.eye(mats[0].shape[0])
    for P in mats:
        out = out @ P
    return out


def spectral_gap(P: np.ndarray) -> float:
    """1 - |λ₂| of a doubly-stochastic matrix — mixing-speed diagnostic."""
    ev = np.sort(np.abs(np.linalg.eigvals(P)))[::-1]
    return float(1.0 - ev[1]) if len(ev) > 1 else 1.0


def beta_min_positive(mats: Sequence[np.ndarray]) -> float:
    """β: the smallest strictly-positive entry over all consensus matrices."""
    vals = []
    for P in mats:
        pos = P[P > 0]
        if pos.size:
            vals.append(pos.min())
    return float(min(vals)) if vals else 1.0


def contraction_to_uniform(Phi: np.ndarray) -> float:
    """max_ij |Φ_ij − 1/N|, the quantity bounded geometrically by Lemma 2."""
    n = Phi.shape[0]
    return float(np.max(np.abs(Phi - 1.0 / n)))
