"""Event-driven virtual-clock schedulers for decentralized training.

TPU adaptation (DESIGN.md §3): JAX programs are SPMD/bulk-synchronous, so the
paper's thread-level asynchrony is realized as a *deterministic event stream*.
A scheduler simulates every worker's local-computation timeline under a
straggler model and emits, per asynchronous iteration ``k``, a
:class:`ScheduleEvent` carrying exactly the quantities of the paper's compact
update (eq. 5):

    W(k) = [W(k-1) − η · G(k-1) ⊙ mask(k)] · P(k)

The *ordering* of events — not their wall-clock overlap — determines every
worker's view of its neighbors' parameters, so parameter trajectories are
faithful to a real asynchronous cluster under the same straggler draws.

Staleness semantics: a worker's gradient is evaluated at the parameter
*snapshot it held when it started computing* (``restart_workers`` marks where
snapshots refresh).  For DSGD-AAU and synchronous DSGD the snapshot always
equals the current parameters; for AD-PSGD/AGP a neighbor may average into a
worker's parameters mid-computation, and the stale-gradient effect the paper
criticizes emerges naturally.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.consensus import metropolis_matrix
from repro.core.pathsearch import PathSearchState
from repro.core.straggler import StragglerModel, TimeSampler
from repro.core.topology import Graph

Edge = Tuple[int, int]


@dataclasses.dataclass(frozen=True)
class ScheduleEvent:
    """One asynchronous iteration of the compact update."""
    k: int                       # iteration counter (the paper's virtual counter)
    time: float                  # virtual clock at which the iteration completes
    grad_workers: np.ndarray     # bool (n,): workers whose local gradient applies
    restart_workers: np.ndarray  # bool (n,): workers that re-snapshot and restart
    P: np.ndarray                # (n, n) consensus matrix (doubly or column stochastic)
    active_edges: Tuple[Edge, ...]
    param_copies_sent: int       # parameter-vector copies moved this iteration

    @property
    def n_active(self) -> int:
        return int(self.grad_workers.sum())


class Scheduler:
    """Base: iterate ScheduleEvents forever (caller bounds by count/time)."""

    name = "base"

    def __init__(self, graph: Graph, straggler: StragglerModel):
        if straggler.n != graph.n:
            raise ValueError("straggler model and graph disagree on n")
        self.graph = graph
        self.n = graph.n
        self.sampler: TimeSampler = straggler.make_sampler()

    def events(self) -> Iterator[ScheduleEvent]:
        raise NotImplementedError

    # -- shared helpers ---------------------------------------------------
    def _mask(self, workers) -> np.ndarray:
        m = np.zeros(self.n, dtype=bool)
        m[list(workers)] = True
        return m


class AAUScheduler(Scheduler):
    """DSGD-AAU (paper Algorithms 1–3).

    All workers compute local gradients at their own pace.  An iteration ends
    when the set of currently-finished workers contains at least one
    Pathsearch-committable edge; every finished worker then gossip-averages
    with its finished graph-neighbors using Metropolis weights, applies its
    gradient, and restarts.  Stragglers simply keep computing across
    iterations — nobody stalls on them, yet Pathsearch guarantees their
    information joins the spanning structure at least once per epoch.
    """

    name = "dsgd_aau"

    def events(self) -> Iterator[ScheduleEvent]:
        n = self.n
        ps = PathSearchState(self.graph)
        heap: List[Tuple[float, int]] = []
        for i in range(n):
            heapq.heappush(heap, (self.sampler.sample(i), i))
        finished: set = set()
        k = 0
        while True:
            t, i = heapq.heappop(heap)
            finished.add(i)
            novel = ps.novel_edges(finished)
            if n == 1:
                novel = [(0, 0)]  # degenerate single-worker case: every finish fires
            if not novel:
                continue
            if n > 1:
                ps.commit(novel)
            # All finished workers exchange with their finished graph-neighbors.
            fin = sorted(finished)
            active_edges = tuple(
                (a, b) for ai, a in enumerate(fin) for b in fin[ai + 1:]
                if self.graph.adj[a, b]
            )
            P = metropolis_matrix(n, active_edges)
            mask = self._mask(finished)
            yield ScheduleEvent(
                k=k, time=t, grad_workers=mask, restart_workers=mask, P=P,
                active_edges=active_edges,
                param_copies_sent=2 * len(active_edges),
            )
            k += 1
            for j in fin:
                heapq.heappush(heap, (t + self.sampler.sample(j), j))
            finished.clear()
            if n > 1 and ps.epoch_complete():
                ps.reset_epoch()

    # expose for diagnostics
    def make_pathsearch(self) -> PathSearchState:
        return PathSearchState(self.graph)


class SyncScheduler(Scheduler):
    """Synchronous DSGD (eq. 2): every iteration waits for *all* workers."""

    name = "dsgd_sync"

    def events(self) -> Iterator[ScheduleEvent]:
        n = self.n
        edges = self.graph.edges
        P = metropolis_matrix(n, edges)
        mask = np.ones(n, dtype=bool)
        t = 0.0
        k = 0
        while True:
            t += float(self.sampler.sample_all().max())  # barrier: slowest worker
            yield ScheduleEvent(
                k=k, time=t, grad_workers=mask.copy(), restart_workers=mask.copy(),
                P=P, active_edges=edges, param_copies_sent=2 * len(edges),
            )
            k += 1
