"""Event-driven virtual-clock schedulers for decentralized training.

TPU adaptation (DESIGN.md §3): JAX programs are SPMD/bulk-synchronous, so the
paper's thread-level asynchrony is realized as a *deterministic event stream*.
A scheduler simulates every worker's local-computation timeline under a
straggler model and emits, per asynchronous iteration ``k``, a
:class:`ScheduleEvent` carrying exactly the quantities of the paper's compact
update (eq. 5):

    W(k) = [W(k-1) − η · G(k-1) ⊙ mask(k)] · P(k)

The *ordering* of events — not their wall-clock overlap — determines every
worker's view of its neighbors' parameters, so parameter trajectories are
faithful to a real asynchronous cluster under the same straggler draws.

Events are consumed one at a time (:meth:`Scheduler.events`, the legacy
interpreted path), packed into dense :class:`EventBatch` stacked arrays
that replay inside a single compiled ``lax.scan``, or packed into
:class:`SparseEventBatch` active-set arrays for the gather-compute-scatter
scan — the representation that makes paper-scale N=128/256 streams
affordable (a single-edge event carries a 2×2 submatrix instead of an
n×n one).  The runner packs blocks itself via the ``from_events``
classmethods (its chunking snaps to the eval grid and the run bounds);
:meth:`Scheduler.event_batches` / :meth:`Scheduler.sparse_event_batches`
are the standalone fixed-size packing APIs for benchmarks and diagnostics.

Staleness semantics: a worker's gradient is evaluated at the parameter
*snapshot it held when it started computing* (``restart_workers`` marks where
snapshots refresh).  For DSGD-AAU and synchronous DSGD the snapshot always
equals the current parameters; for AD-PSGD/AGP a neighbor may average into a
worker's parameters mid-computation, and the stale-gradient effect the paper
criticizes emerges naturally.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.consensus import metropolis_matrix
from repro.core.pathsearch import PathSearchState
from repro.core.straggler import StragglerModel, TimeSampler
from repro.core.topology import Graph

Edge = Tuple[int, int]


@dataclasses.dataclass(frozen=True)
class ScheduleEvent:
    """One asynchronous iteration of the compact update."""
    k: int                       # iteration counter (the paper's virtual counter)
    time: float                  # virtual clock at which the iteration completes
    grad_workers: np.ndarray     # bool (n,): workers whose local gradient applies
    restart_workers: np.ndarray  # bool (n,): workers that re-snapshot and restart
    P: np.ndarray                # (n, n) consensus matrix (doubly or column stochastic)
    active_edges: Tuple[Edge, ...]
    param_copies_sent: int       # parameter-vector copies moved this iteration

    @property
    def n_active(self) -> int:
        return int(self.grad_workers.sum())


@dataclasses.dataclass(frozen=True)
class EventBatch:
    """``E`` consecutive ScheduleEvents packed into stacked arrays.

    This is the *compiled* representation of the event stream: the runner
    converts one EventBatch into device arrays and advances the whole block
    inside a single ``jax.lax.scan`` (core/aau.py ``masked_gossip_scan``),
    instead of dispatching one jitted step per event from Python.  The dense
    ``P`` stack feeds the update; ``edges``/``n_edges`` are the compact
    active-edge form — fixed width per scheduler (``Scheduler.edge_bound``),
    ``-1``-padded — kept for diagnostics and communication accounting.  For
    the representation that drops the dense stack entirely, see
    :class:`SparseEventBatch` (most baselines touch 1 edge out of O(n²)
    entries; the sparse form carries only the active-set submatrices).
    """
    k0: int                         # iteration counter of the first event
    times: np.ndarray               # (E,) float64 virtual completion clocks
    P: np.ndarray                   # (E, n, n) float32 consensus matrices
    grad_workers: np.ndarray        # (E, n) bool
    restart_workers: np.ndarray     # (E, n) bool
    param_copies_sent: np.ndarray   # (E,) int64
    edges: np.ndarray               # (E, edge_bound, 2) int32, -1-padded
    n_edges: np.ndarray             # (E,) int32 valid rows of ``edges``

    @property
    def E(self) -> int:
        return len(self.times)

    @property
    def n(self) -> int:
        return self.P.shape[1]

    @property
    def n_active(self) -> np.ndarray:
        return self.grad_workers.sum(axis=1)

    @classmethod
    def from_events(cls, events: Sequence[ScheduleEvent],
                    edge_bound: Optional[int] = None) -> "EventBatch":
        if not events:
            raise ValueError("cannot pack an empty event block")
        n = events[0].P.shape[0]
        width = edge_bound if edge_bound is not None else max(
            1, max(len(ev.active_edges) for ev in events))
        edges = np.full((len(events), width, 2), -1, dtype=np.int32)
        n_edges = np.zeros(len(events), dtype=np.int32)
        for e, ev in enumerate(events):
            m = len(ev.active_edges)
            if m > width:
                raise ValueError(
                    f"event {ev.k} has {m} active edges > edge_bound {width}")
            if m:
                edges[e, :m] = np.asarray(ev.active_edges, dtype=np.int32)
            n_edges[e] = m
        return cls(
            k0=events[0].k,
            times=np.asarray([ev.time for ev in events], dtype=np.float64),
            P=np.stack([ev.P for ev in events]).astype(np.float32),
            grad_workers=np.stack([ev.grad_workers for ev in events]),
            restart_workers=np.stack([ev.restart_workers for ev in events]),
            param_copies_sent=np.asarray(
                [ev.param_copies_sent for ev in events], dtype=np.int64),
            edges=edges, n_edges=n_edges,
        )

    def pad_to(self, E: int) -> "EventBatch":
        """Pad with identity no-op events (P=I, empty masks) up to length E.

        A no-op event leaves ``(W, S, y)`` and the batch-pool pointers exactly
        unchanged, so the runner can always dispatch fixed-size blocks (one
        compiled program) even when an eval boundary or the end of the run
        truncates a block.
        """
        pad = E - self.E
        if pad < 0:
            raise ValueError(f"cannot pad E={self.E} down to {E}")
        if pad == 0:
            return self
        n = self.n
        eyeP = np.broadcast_to(np.eye(n, dtype=np.float32), (pad, n, n))
        off = np.zeros((pad, n), dtype=bool)
        return dataclasses.replace(
            self,
            times=np.concatenate(
                [self.times, np.full(pad, self.times[-1])]),
            P=np.concatenate([self.P, eyeP]),
            grad_workers=np.concatenate([self.grad_workers, off]),
            restart_workers=np.concatenate([self.restart_workers, off]),
            param_copies_sent=np.concatenate(
                [self.param_copies_sent, np.zeros(pad, dtype=np.int64)]),
            edges=np.concatenate([
                self.edges,
                np.full((pad,) + self.edges.shape[1:], -1, dtype=np.int32)]),
            n_edges=np.concatenate(
                [self.n_edges, np.zeros(pad, dtype=np.int32)]),
        )

    def to_events(self) -> List[ScheduleEvent]:
        """Unpack back into per-event form (round-trip/diagnostic helper)."""
        out = []
        for e in range(self.E):
            m = int(self.n_edges[e])
            out.append(ScheduleEvent(
                k=self.k0 + e, time=float(self.times[e]),
                grad_workers=self.grad_workers[e],
                restart_workers=self.restart_workers[e],
                P=self.P[e],
                active_edges=tuple(map(tuple, self.edges[e, :m])),
                param_copies_sent=int(self.param_copies_sent[e]),
            ))
        return out


@dataclasses.dataclass(frozen=True)
class SparseEventBatch:
    """``E`` ScheduleEvents in active-set (gather-compute-scatter) form.

    The sparse sibling of :class:`EventBatch`: instead of the dense
    ``(E, n, n)`` consensus stack it carries, per event, the sorted list of
    *active workers* (every worker that fires a gradient, restarts, or sits
    on an active edge) and the ``A×A`` consensus **sub**matrix restricted to
    that set.  Every scheduler in this module keeps P identity outside the
    active set (the invariant tests/test_scheduler.py pins), so the submatrix
    plus the index list reconstruct the event exactly — at O(A²) packed
    bytes per event instead of O(n²), which is what drops the dense ``P``
    stack entirely for single-edge schedulers (A = 2 vs n = 256).

    Lane padding: ``workers`` rows are ``-1``-padded to the scheduler's fixed
    ``active_bound`` ``A`` (stable shapes ⇒ one compiled scan for the run);
    padded lanes carry all-zero ``P_sub`` rows *and* columns and all-False
    masks, so the consumer (core/aau.py ``sparse_gossip_scan`` and the
    ``sparse_gossip`` kernel) treats them as mass-less no-ops and its
    scatter drops them.  ``grad_workers``/``restart_workers`` are per-*lane*
    bools aligned with ``workers``, not per-worker n-vectors.

    ``edges``/``n_edges`` keep the compact active-edge form of
    :class:`EventBatch` (``-1``-padded to ``edge_bound``) for diagnostics
    and communication accounting.
    """
    k0: int                         # iteration counter of the first event
    times: np.ndarray               # (E,) float64 virtual completion clocks
    workers: np.ndarray             # (E, A) int32 sorted active sets, -1-padded
    n_workers: np.ndarray           # (E,) int32 valid lanes per event
    P_sub: np.ndarray               # (E, A, A) float32 active-set submatrices
    grad_workers: np.ndarray        # (E, A) bool, per-lane
    restart_workers: np.ndarray     # (E, A) bool, per-lane
    param_copies_sent: np.ndarray   # (E,) int64
    edges: np.ndarray               # (E, edge_bound, 2) int32, -1-padded
    n_edges: np.ndarray             # (E,) int32 valid rows of ``edges``

    @property
    def E(self) -> int:
        return len(self.times)

    @property
    def A(self) -> int:
        return self.workers.shape[1]

    @property
    def n_active(self) -> np.ndarray:
        return self.grad_workers.sum(axis=1)

    @classmethod
    def from_events(cls, events: Sequence[ScheduleEvent], active_bound: int,
                    edge_bound: Optional[int] = None) -> "SparseEventBatch":
        if not events:
            raise ValueError("cannot pack an empty event block")
        A = max(1, active_bound)
        ewidth = edge_bound if edge_bound is not None else max(
            1, max(len(ev.active_edges) for ev in events))
        E = len(events)
        workers = np.full((E, A), -1, dtype=np.int32)
        n_workers = np.zeros(E, dtype=np.int32)
        P_sub = np.zeros((E, A, A), dtype=np.float32)
        gm = np.zeros((E, A), dtype=bool)
        rm = np.zeros((E, A), dtype=bool)
        edges = np.full((E, ewidth, 2), -1, dtype=np.int32)
        n_edges = np.zeros(E, dtype=np.int32)
        for e, ev in enumerate(events):
            active = set(np.nonzero(ev.grad_workers)[0].tolist())
            active |= set(np.nonzero(ev.restart_workers)[0].tolist())
            for a, b in ev.active_edges:
                active.add(int(a))
                active.add(int(b))
            w = sorted(active)
            m = len(w)
            if m > A:
                raise ValueError(
                    f"event {ev.k} touches {m} workers > active_bound {A}")
            if m:
                idx = np.asarray(w, dtype=np.intp)
                workers[e, :m] = idx
                P_sub[e, :m, :m] = ev.P[np.ix_(idx, idx)]
                gm[e, :m] = ev.grad_workers[idx]
                rm[e, :m] = ev.restart_workers[idx]
            n_workers[e] = m
            me = len(ev.active_edges)
            if me > ewidth:
                raise ValueError(
                    f"event {ev.k} has {me} active edges > edge_bound {ewidth}")
            if me:
                edges[e, :me] = np.asarray(ev.active_edges, dtype=np.int32)
            n_edges[e] = me
        return cls(
            k0=events[0].k,
            times=np.asarray([ev.time for ev in events], dtype=np.float64),
            workers=workers, n_workers=n_workers, P_sub=P_sub,
            grad_workers=gm, restart_workers=rm,
            param_copies_sent=np.asarray(
                [ev.param_copies_sent for ev in events], dtype=np.int64),
            edges=edges, n_edges=n_edges,
        )

    def pad_to(self, E: int) -> "SparseEventBatch":
        """Pad with no-op events (empty active sets) up to length E.

        An empty active set gathers nothing and scatters nothing, so the
        scan carry ``(W, S, y, ptr)`` passes through bit-exact — the sparse
        analogue of :meth:`EventBatch.pad_to`'s identity events.
        """
        pad = E - self.E
        if pad < 0:
            raise ValueError(f"cannot pad E={self.E} down to {E}")
        if pad == 0:
            return self
        A = self.A
        off = np.zeros((pad, A), dtype=bool)
        return dataclasses.replace(
            self,
            times=np.concatenate([self.times, np.full(pad, self.times[-1])]),
            workers=np.concatenate(
                [self.workers, np.full((pad, A), -1, dtype=np.int32)]),
            n_workers=np.concatenate(
                [self.n_workers, np.zeros(pad, dtype=np.int32)]),
            P_sub=np.concatenate(
                [self.P_sub, np.zeros((pad, A, A), dtype=np.float32)]),
            grad_workers=np.concatenate([self.grad_workers, off]),
            restart_workers=np.concatenate([self.restart_workers, off]),
            param_copies_sent=np.concatenate(
                [self.param_copies_sent, np.zeros(pad, dtype=np.int64)]),
            edges=np.concatenate([
                self.edges,
                np.full((pad,) + self.edges.shape[1:], -1, dtype=np.int32)]),
            n_edges=np.concatenate(
                [self.n_edges, np.zeros(pad, dtype=np.int32)]),
        )

    def to_events(self, n: int) -> List[ScheduleEvent]:
        """Reconstruct dense per-event form (round-trip/diagnostic helper)."""
        out = []
        for e in range(self.E):
            m = int(self.n_workers[e])
            idx = self.workers[e, :m].astype(np.intp)
            gw = np.zeros(n, dtype=bool)
            rw = np.zeros(n, dtype=bool)
            gw[idx] = self.grad_workers[e, :m]
            rw[idx] = self.restart_workers[e, :m]
            P = np.eye(n, dtype=np.float32)
            P[np.ix_(idx, idx)] = self.P_sub[e, :m, :m]
            me = int(self.n_edges[e])
            out.append(ScheduleEvent(
                k=self.k0 + e, time=float(self.times[e]),
                grad_workers=gw, restart_workers=rw, P=P,
                active_edges=tuple(map(tuple, self.edges[e, :me])),
                param_copies_sent=int(self.param_copies_sent[e]),
            ))
        return out


class Scheduler:
    """Base: iterate ScheduleEvents forever (caller bounds by count/time)."""

    name = "base"

    #: True when *every* event touches all n workers (barrier algorithms
    #: like synchronous DSGD).  The sparse gather-compute-scatter path is
    #: pure overhead for such streams, so the runner's ``mode="sparse_scan"``
    #: automatically falls back to the dense scan.
    global_events = False

    def __init__(self, graph: Graph, straggler: StragglerModel):
        if straggler.n != graph.n:
            raise ValueError("straggler model and graph disagree on n")
        self.graph = graph
        self.n = graph.n
        self.sampler: TimeSampler = straggler.make_sampler()

    def events(self) -> Iterator[ScheduleEvent]:
        raise NotImplementedError

    def edge_bound(self) -> int:
        """Max #active edges any single event of this scheduler can carry.

        Fixed per scheduler so every EventBatch has the same compact-edge
        width (stable shapes ⇒ no recompilation across blocks).  Subclasses
        with tighter structure (pairwise gossip, bounded groups) override.
        """
        return max(1, len(self.graph.edges))

    def active_bound(self) -> int:
        """Max #workers any single event touches (grad, restart, or edge).

        This is the fixed lane width ``A`` of :class:`SparseEventBatch` —
        the per-event cost of the sparse scan path is O(A·D) gradients plus
        O(A²·D) mixing, so tight subclass overrides (AD-PSGD/AGP: 2,
        Prague: group size) are what turn O(n²·D) events into O(D) ones.
        """
        return self.n

    def event_batches(self, block_size: int) -> Iterator[EventBatch]:
        """Pack consecutive events into EventBatches of ``block_size``.

        A finite event stream ends with one trailing partial batch (the
        built-in schedulers stream forever, but subclasses may not).
        """
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        bound = self.edge_bound()
        buf: List[ScheduleEvent] = []
        for ev in self.events():
            buf.append(ev)
            if len(buf) == block_size:
                yield EventBatch.from_events(buf, edge_bound=bound)
                buf = []
        if buf:
            yield EventBatch.from_events(buf, edge_bound=bound)

    def sparse_event_batches(self, block_size: int) -> Iterator[SparseEventBatch]:
        """Pack consecutive events into active-set SparseEventBatches."""
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        abound = self.active_bound()
        ebound = self.edge_bound()
        buf: List[ScheduleEvent] = []
        for ev in self.events():
            buf.append(ev)
            if len(buf) == block_size:
                yield SparseEventBatch.from_events(
                    buf, active_bound=abound, edge_bound=ebound)
                buf = []
        if buf:
            yield SparseEventBatch.from_events(
                buf, active_bound=abound, edge_bound=ebound)

    # -- shared helpers ---------------------------------------------------
    def _mask(self, workers) -> np.ndarray:
        m = np.zeros(self.n, dtype=bool)
        m[list(workers)] = True
        return m


class AAUScheduler(Scheduler):
    """DSGD-AAU (paper Algorithms 1–3).

    All workers compute local gradients at their own pace.  An iteration ends
    when the set of currently-finished workers contains at least one
    Pathsearch-committable edge; every finished worker then gossip-averages
    with its finished graph-neighbors using Metropolis weights, applies its
    gradient, and restarts.  Stragglers simply keep computing across
    iterations — nobody stalls on them, yet Pathsearch guarantees their
    information joins the spanning structure at least once per epoch.
    """

    name = "dsgd_aau"

    def events(self) -> Iterator[ScheduleEvent]:
        n = self.n
        ps = PathSearchState(self.graph)
        heap: List[Tuple[float, int]] = []
        for i, dt in enumerate(self.sampler.sample_batch(np.arange(n))):
            heapq.heappush(heap, (dt, i))
        finished: set = set()
        k = 0
        while True:
            t, i = heapq.heappop(heap)
            finished.add(i)
            novel = ps.novel_edges(finished)
            if n == 1:
                novel = [(0, 0)]  # degenerate single-worker case: every finish fires
            if not novel:
                continue
            if n > 1:
                ps.commit(novel)
            # All finished workers exchange with their finished graph-neighbors.
            fin = sorted(finished)
            active_edges = tuple(
                (a, b) for ai, a in enumerate(fin) for b in fin[ai + 1:]
                if self.graph.adj[a, b]
            )
            P = metropolis_matrix(n, active_edges)
            mask = self._mask(finished)
            yield ScheduleEvent(
                k=k, time=t, grad_workers=mask, restart_workers=mask, P=P,
                active_edges=active_edges,
                param_copies_sent=2 * len(active_edges),
            )
            k += 1
            # batch-draw the restarted workers' next completion times: one
            # vectorized RNG call instead of one heap-push-sized draw each
            for j, dt in zip(fin, self.sampler.sample_batch(fin)):
                heapq.heappush(heap, (t + dt, j))
            finished.clear()
            if n > 1 and ps.epoch_complete():
                ps.reset_epoch()

    # expose for diagnostics
    def make_pathsearch(self) -> PathSearchState:
        return PathSearchState(self.graph)


class SyncScheduler(Scheduler):
    """Synchronous DSGD (eq. 2): every iteration waits for *all* workers."""

    name = "dsgd_sync"
    global_events = True  # every event is a full barrier: sparse buys nothing

    def events(self) -> Iterator[ScheduleEvent]:
        n = self.n
        edges = self.graph.edges
        P = metropolis_matrix(n, edges)
        mask = np.ones(n, dtype=bool)
        t = 0.0
        k = 0
        while True:
            t += float(self.sampler.sample_all().max())  # barrier: slowest worker
            yield ScheduleEvent(
                k=k, time=t, grad_workers=mask.copy(), restart_workers=mask.copy(),
                P=P, active_edges=edges, param_copies_sent=2 * len(edges),
            )
            k += 1
