"""Event-driven virtual-clock schedulers for decentralized training.

TPU adaptation (DESIGN.md §3): JAX programs are SPMD/bulk-synchronous, so the
paper's thread-level asynchrony is realized as a *deterministic event stream*.
A scheduler simulates every worker's local-computation timeline under a
straggler model and emits, per asynchronous iteration ``k``, a
:class:`ScheduleEvent` carrying exactly the quantities of the paper's compact
update (eq. 5):

    W(k) = [W(k-1) − η · G(k-1) ⊙ mask(k)] · P(k)

The *ordering* of events — not their wall-clock overlap — determines every
worker's view of its neighbors' parameters, so parameter trajectories are
faithful to a real asynchronous cluster under the same straggler draws.

Events are **sparse-native**: a :class:`ScheduleEvent`'s primary payload is
the sorted active-worker set plus the A×A consensus submatrix restricted to
it (every scheduler keeps P identity outside the set — the invariant
tests/test_scheduler.py pins), so generating an event costs O(A²) host work
instead of the O(n²) a dense consensus matrix would. Dense views (``.P``,
``.grad_workers``, ``.restart_workers``) materialize lazily, only where a
consumer actually asks — the per-event interpreter, dense
:class:`EventBatch` packing, diagnostics.

Events are consumed one at a time (:meth:`Scheduler.events`, the legacy
interpreted path), packed into dense :class:`EventBatch` stacked arrays
that replay inside a single compiled ``lax.scan``, or packed into
:class:`SparseEventBatch` active-set arrays for the gather-compute-scatter
scan — the representation that makes paper-scale N=128/256 streams
affordable (a single-edge event carries a 2×2 submatrix instead of an
n×n one).  Both ``from_events`` packers are vectorized numpy batch
scatters (no per-event Python loop over ``np.ix_`` rectangles), so packing
keeps up with the sparse-native generators.  The runner packs blocks
itself via the ``from_events`` classmethods (its chunking snaps to the
eval grid and the run bounds); :meth:`Scheduler.event_batches` /
:meth:`Scheduler.sparse_event_batches` are the standalone fixed-size
packing APIs for benchmarks and diagnostics.

Staleness semantics: a worker's gradient is evaluated at the parameter
*snapshot it held when it started computing* (``restart_workers`` marks where
snapshots refresh).  For DSGD-AAU and synchronous DSGD the snapshot always
equals the current parameters; for AD-PSGD/AGP a neighbor may average into a
worker's parameters mid-computation, and the stale-gradient effect the paper
criticizes emerges naturally.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.consensus import metropolis_matrix, metropolis_submatrix
from repro.core.pathsearch import PathSearchState
from repro.core.topology import Graph
from repro.scenarios.base import TimeModel, TimeModelSpec

Edge = Tuple[int, int]

_EMPTY_EDGES = np.zeros((0, 2), dtype=np.int32)
_EMPTY_EDGES.flags.writeable = False  # shared across events: keep it inert


class ScheduleEvent:
    """One asynchronous iteration of the compact update, in active-set form.

    Primary payload (what schedulers construct, what the sparse packer
    reads — all O(A) / O(A²), never O(n)):

    - ``workers``: (m,) int32, the *sorted* set of workers this iteration
      touches (gradient, restart, or an active edge);
    - ``P_sub``: (m, m) float, the consensus matrix restricted to that set —
      P is identity outside it by the schedulers' construction;
    - ``grad_lanes`` / ``restart_lanes``: (m,) bool, aligned with
      ``workers``;
    - ``edges``: (e, 2) int32 active-edge endpoints (global indices).

    Dense views — ``.P`` (n, n), ``.grad_workers`` / ``.restart_workers``
    (n,) bool, ``.active_edges`` tuple-of-pairs — are materialized lazily on
    first access and cached, so consumers that never ask (the sparse scan
    path, the generation benchmarks) never pay for them.  ``.P`` scatters
    ``P_sub`` into an identity matrix, which reproduces the historical dense
    build bit-exactly (see :func:`repro.core.consensus.metropolis_submatrix`
    for why the submatrices themselves are exact).
    """

    __slots__ = ("k", "time", "n", "workers", "P_sub", "grad_lanes",
                 "restart_lanes", "edges", "param_copies_sent",
                 "finish_lanes", "_P", "_gw", "_rw", "_ae")

    def __init__(self, k: int, time: float, n: int, workers: np.ndarray,
                 P_sub: np.ndarray, grad_lanes: np.ndarray,
                 restart_lanes: np.ndarray, edges: np.ndarray,
                 param_copies_sent: int,
                 dense_P: Optional[np.ndarray] = None,
                 dense_grad: Optional[np.ndarray] = None,
                 dense_restart: Optional[np.ndarray] = None,
                 finish_lanes: Optional[np.ndarray] = None):
        self.k = k
        self.time = time
        self.n = n
        self.workers = workers
        self.P_sub = P_sub
        self.grad_lanes = grad_lanes
        self.restart_lanes = restart_lanes
        self.edges = edges
        self.param_copies_sent = param_copies_sent
        # per-lane raw local-computation completion clocks, aligned with
        # ``workers`` — the event fires at ``time`` ≥ every lane's finish
        # (clique formation / averaging locks impose the wait); telemetry
        # splits busy vs idle virtual time on that gap.  None ⇒ every lane
        # finished exactly at ``time``.
        self.finish_lanes = finish_lanes
        self._P = dense_P
        self._gw = dense_grad
        self._rw = dense_restart
        self._ae = None

    @classmethod
    def from_dense(cls, k: int, time: float, grad_workers: np.ndarray,
                   restart_workers: np.ndarray, P: np.ndarray,
                   active_edges: Sequence[Edge],
                   param_copies_sent: int) -> "ScheduleEvent":
        """Build from the dense representation (custom schedulers, round
        trips).  The active set is the union of gradient workers, restarting
        workers, and active-edge endpoints; P must be identity outside it.
        The dense arrays are kept as the event's cached views, so round
        trips through this constructor are exact.
        """
        n = len(grad_workers)
        gw = np.asarray(grad_workers, dtype=bool)
        rw = np.asarray(restart_workers, dtype=bool)
        active = gw | rw
        edges = (np.asarray(active_edges, dtype=np.int32).reshape(-1, 2)
                 if len(active_edges) else _EMPTY_EDGES)
        if edges.size:
            active = active.copy()
            active[edges.ravel()] = True
        widx = np.nonzero(active)[0].astype(np.int32)
        return cls(
            k=k, time=time, n=n, workers=widx,
            P_sub=P[np.ix_(widx, widx)],
            grad_lanes=gw[widx], restart_lanes=rw[widx],
            edges=edges, param_copies_sent=param_copies_sent,
            dense_P=P, dense_grad=gw, dense_restart=rw,
        )

    # -- lazy dense views --------------------------------------------------
    @property
    def P(self) -> np.ndarray:
        """Dense (n, n) consensus matrix: identity off the active set."""
        if self._P is None:
            P = np.eye(self.n, dtype=self.P_sub.dtype
                       if self.P_sub.size else np.float64)
            if self.workers.size:
                P[np.ix_(self.workers, self.workers)] = self.P_sub
            self._P = P
        return self._P

    @property
    def grad_workers(self) -> np.ndarray:
        """Dense (n,) bool: workers whose local gradient applies."""
        if self._gw is None:
            gw = np.zeros(self.n, dtype=bool)
            gw[self.workers[self.grad_lanes]] = True
            self._gw = gw
        return self._gw

    @property
    def restart_workers(self) -> np.ndarray:
        """Dense (n,) bool: workers that re-snapshot and restart."""
        if self._rw is None:
            rw = np.zeros(self.n, dtype=bool)
            rw[self.workers[self.restart_lanes]] = True
            self._rw = rw
        return self._rw

    @property
    def active_edges(self) -> Tuple[Edge, ...]:
        if self._ae is None:
            self._ae = tuple((int(a), int(b)) for a, b in self.edges)
        return self._ae

    @property
    def n_active(self) -> int:
        return int(self.grad_lanes.sum())

    def __repr__(self) -> str:  # slots class: give diagnostics a readable form
        return (f"ScheduleEvent(k={self.k}, time={self.time:.4f}, "
                f"n={self.n}, workers={self.workers.tolist()}, "
                f"edges={self.active_edges}, "
                f"copies={self.param_copies_sent})")


def _ragged_arange(lens: np.ndarray) -> np.ndarray:
    """[0..lens[0]), [0..lens[1]), ... concatenated (vectorized)."""
    total = int(lens.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    starts = np.cumsum(lens) - lens
    return np.arange(total, dtype=np.int64) - np.repeat(starts, lens)


def _pack_edges(events: Sequence["ScheduleEvent"],
                edge_bound: Optional[int]) -> Tuple[np.ndarray, np.ndarray]:
    """Compact active-edge arrays: (E, width, 2) int32 -1-padded + counts."""
    E = len(events)
    elens = np.fromiter((len(ev.edges) for ev in events),
                        dtype=np.int64, count=E)
    width = edge_bound if edge_bound is not None else max(1, int(elens.max()))
    if elens.max(initial=0) > width:
        bad = int(np.argmax(elens))
        raise ValueError(
            f"event {events[bad].k} has {int(elens[bad])} active edges > "
            f"edge_bound {width}")
    edges = np.full((E, width, 2), -1, dtype=np.int32)
    if int(elens.sum()):
        rows = np.repeat(np.arange(E), elens)
        cols = _ragged_arange(elens)
        edges[rows, cols] = np.concatenate(
            [ev.edges for ev in events if len(ev.edges)])
    return edges, elens.astype(np.int32)


def _worker_scatter_indices(wlens: np.ndarray, flat_workers: np.ndarray):
    """Batch-scatter indices for the (E, A, A) submatrix blocks.

    Returns ``(bi, lr, lc, gr, gc)``: for every entry of every event's
    m_e×m_e submatrix (row-major), the event index, local row/col within the
    block, and the global worker indices at those lanes.
    """
    E = len(wlens)
    m2 = wlens * wlens
    bi = np.repeat(np.arange(E), m2)
    mrep = np.repeat(wlens, m2)
    within = _ragged_arange(m2)
    lr = within // np.maximum(mrep, 1)
    lc = within - lr * mrep
    starts = np.repeat(np.cumsum(wlens) - wlens, m2)
    gr = flat_workers[starts + lr]
    gc = flat_workers[starts + lc]
    return bi, lr, lc, gr, gc


@dataclasses.dataclass(frozen=True)
class EventBatch:
    """``E`` consecutive ScheduleEvents packed into stacked arrays.

    This is the *compiled* representation of the event stream: the runner
    converts one EventBatch into device arrays and advances the whole block
    inside a single ``jax.lax.scan`` (core/aau.py ``masked_gossip_scan``),
    instead of dispatching one jitted step per event from Python.  The dense
    ``P`` stack feeds the update; ``edges``/``n_edges`` are the compact
    active-edge form — fixed width per scheduler (``Scheduler.edge_bound``),
    ``-1``-padded — kept for diagnostics and communication accounting.
    Packing never materializes per-event dense matrices: the stack is one
    broadcast identity plus one vectorized scatter of the events' active-set
    submatrices.  For the representation that drops the dense stack
    entirely, see :class:`SparseEventBatch` (most baselines touch 1 edge out
    of O(n²) entries; the sparse form carries only the active-set
    submatrices).
    """
    k0: int                         # iteration counter of the first event
    times: np.ndarray               # (E,) float64 virtual completion clocks
    P: np.ndarray                   # (E, n, n) float32 consensus matrices
    grad_workers: np.ndarray        # (E, n) bool
    restart_workers: np.ndarray     # (E, n) bool
    param_copies_sent: np.ndarray   # (E,) int64
    edges: np.ndarray               # (E, edge_bound, 2) int32, -1-padded
    n_edges: np.ndarray             # (E,) int32 valid rows of ``edges``
    finish: Optional[np.ndarray] = None  # (E, n) float64 raw completion
    #   clocks (= times broadcast for non-active workers); None when the
    #   source events carried no finish_lanes (telemetry then treats every
    #   restart as finishing at the event clock)

    @property
    def E(self) -> int:
        return len(self.times)

    @property
    def n(self) -> int:
        return self.P.shape[1]

    @property
    def n_active(self) -> np.ndarray:
        return self.grad_workers.sum(axis=1)

    @classmethod
    def from_events(cls, events: Sequence[ScheduleEvent],
                    edge_bound: Optional[int] = None) -> "EventBatch":
        if not events:
            raise ValueError("cannot pack an empty event block")
        n = events[0].n
        E = len(events)
        edges, n_edges = _pack_edges(events, edge_bound)
        wlens = np.fromiter((len(ev.workers) for ev in events),
                            dtype=np.int64, count=E)
        flatw = (np.concatenate([ev.workers for ev in events if
                                 len(ev.workers)])
                 if int(wlens.sum()) else np.zeros(0, dtype=np.int32))
        P = np.broadcast_to(np.eye(n, dtype=np.float32), (E, n, n)).copy()
        gm = np.zeros((E, n), dtype=bool)
        rm = np.zeros((E, n), dtype=bool)
        times = np.fromiter((ev.time for ev in events),
                            dtype=np.float64, count=E)
        finish = np.repeat(times[:, None], n, axis=1)
        if flatw.size:
            bi, _, _, gr, gc = _worker_scatter_indices(wlens, flatw)
            P[bi, gr, gc] = np.concatenate(
                [ev.P_sub.ravel() for ev in events if len(ev.workers)])
            rows = np.repeat(np.arange(E), wlens)
            gm[rows, flatw] = np.concatenate(
                [ev.grad_lanes for ev in events if len(ev.workers)])
            rm[rows, flatw] = np.concatenate(
                [ev.restart_lanes for ev in events if len(ev.workers)])
            finish[rows, flatw] = np.concatenate([
                (ev.finish_lanes if ev.finish_lanes is not None
                 else np.full(len(ev.workers), ev.time))
                for ev in events if len(ev.workers)])
        return cls(
            k0=events[0].k,
            times=times,
            P=P, grad_workers=gm, restart_workers=rm,
            param_copies_sent=np.fromiter(
                (ev.param_copies_sent for ev in events),
                dtype=np.int64, count=E),
            edges=edges, n_edges=n_edges, finish=finish,
        )

    def pad_to(self, E: int) -> "EventBatch":
        """Pad with identity no-op events (P=I, empty masks) up to length E.

        A no-op event leaves ``(W, S, y)`` and the batch-pool pointers exactly
        unchanged, so the runner can always dispatch fixed-size blocks (one
        compiled program) even when an eval boundary or the end of the run
        truncates a block.
        """
        pad = E - self.E
        if pad < 0:
            raise ValueError(f"cannot pad E={self.E} down to {E}")
        if pad == 0:
            return self
        n = self.n
        eyeP = np.broadcast_to(np.eye(n, dtype=np.float32), (pad, n, n))
        off = np.zeros((pad, n), dtype=bool)
        return dataclasses.replace(
            self,
            times=np.concatenate(
                [self.times, np.full(pad, self.times[-1])]),
            P=np.concatenate([self.P, eyeP]),
            grad_workers=np.concatenate([self.grad_workers, off]),
            restart_workers=np.concatenate([self.restart_workers, off]),
            param_copies_sent=np.concatenate(
                [self.param_copies_sent, np.zeros(pad, dtype=np.int64)]),
            edges=np.concatenate([
                self.edges,
                np.full((pad,) + self.edges.shape[1:], -1, dtype=np.int32)]),
            n_edges=np.concatenate(
                [self.n_edges, np.zeros(pad, dtype=np.int32)]),
            finish=(np.concatenate(
                [self.finish, np.full((pad, n), self.times[-1])])
                if self.finish is not None else None),
        )

    def to_events(self) -> List[ScheduleEvent]:
        """Unpack back into per-event form (round-trip/diagnostic helper)."""
        out = []
        for e in range(self.E):
            m = int(self.n_edges[e])
            out.append(ScheduleEvent.from_dense(
                k=self.k0 + e, time=float(self.times[e]),
                grad_workers=self.grad_workers[e],
                restart_workers=self.restart_workers[e],
                P=self.P[e],
                active_edges=self.edges[e, :m],
                param_copies_sent=int(self.param_copies_sent[e]),
            ))
        return out


@dataclasses.dataclass(frozen=True)
class SparseEventBatch:
    """``E`` ScheduleEvents in active-set (gather-compute-scatter) form.

    The sparse sibling of :class:`EventBatch`: instead of the dense
    ``(E, n, n)`` consensus stack it carries, per event, the sorted list of
    *active workers* (every worker that fires a gradient, restarts, or sits
    on an active edge) and the ``A×A`` consensus **sub**matrix restricted to
    that set.  Every scheduler in this module keeps P identity outside the
    active set (the invariant tests/test_scheduler.py pins), so the submatrix
    plus the index list reconstruct the event exactly — at O(A²) packed
    bytes per event instead of O(n²), which is what drops the dense ``P``
    stack entirely for single-edge schedulers (A = 2 vs n = 256).  Since
    events are sparse-native, packing is a pure reshape: one vectorized
    batch scatter of the events' lanes and submatrices into the padded
    arrays, no per-event Python work.

    Lane padding: ``workers`` rows are ``-1``-padded to the scheduler's fixed
    ``active_bound`` ``A`` (stable shapes ⇒ one compiled scan for the run);
    padded lanes carry all-zero ``P_sub`` rows *and* columns and all-False
    masks, so the consumer (core/aau.py ``sparse_gossip_scan`` and the
    ``sparse_gossip`` kernel) treats them as mass-less no-ops and its
    scatter drops them.  ``grad_workers``/``restart_workers`` are per-*lane*
    bools aligned with ``workers``, not per-worker n-vectors.

    ``edges``/``n_edges`` keep the compact active-edge form of
    :class:`EventBatch` (``-1``-padded to ``edge_bound``) for diagnostics
    and communication accounting.
    """
    k0: int                         # iteration counter of the first event
    times: np.ndarray               # (E,) float64 virtual completion clocks
    workers: np.ndarray             # (E, A) int32 sorted active sets, -1-padded
    n_workers: np.ndarray           # (E,) int32 valid lanes per event
    P_sub: np.ndarray               # (E, A, A) float32 active-set submatrices
    grad_workers: np.ndarray        # (E, A) bool, per-lane
    restart_workers: np.ndarray     # (E, A) bool, per-lane
    param_copies_sent: np.ndarray   # (E,) int64
    edges: np.ndarray               # (E, edge_bound, 2) int32, -1-padded
    n_edges: np.ndarray             # (E,) int32 valid rows of ``edges``
    finish: Optional[np.ndarray] = None  # (E, A) float64 per-lane raw
    #   completion clocks (= times broadcast on pad lanes); None when the
    #   source events carried no finish_lanes

    @property
    def E(self) -> int:
        return len(self.times)

    @property
    def A(self) -> int:
        return self.workers.shape[1]

    @property
    def n_active(self) -> np.ndarray:
        return self.grad_workers.sum(axis=1)

    @classmethod
    def from_events(cls, events: Sequence[ScheduleEvent], active_bound: int,
                    edge_bound: Optional[int] = None) -> "SparseEventBatch":
        if not events:
            raise ValueError("cannot pack an empty event block")
        A = max(1, active_bound)
        E = len(events)
        wlens = np.fromiter((len(ev.workers) for ev in events),
                            dtype=np.int64, count=E)
        if wlens.max(initial=0) > A:
            bad = int(np.argmax(wlens))
            raise ValueError(
                f"event {events[bad].k} touches {int(wlens[bad])} workers > "
                f"active_bound {A}")
        workers = np.full((E, A), -1, dtype=np.int32)
        P_sub = np.zeros((E, A, A), dtype=np.float32)
        gm = np.zeros((E, A), dtype=bool)
        rm = np.zeros((E, A), dtype=bool)
        times = np.fromiter((ev.time for ev in events),
                            dtype=np.float64, count=E)
        finish = np.repeat(times[:, None], A, axis=1)
        if int(wlens.sum()):
            nonempty = [ev for ev in events if len(ev.workers)]
            flatw = np.concatenate([ev.workers for ev in nonempty])
            rows = np.repeat(np.arange(E), wlens)
            cols = _ragged_arange(wlens)
            workers[rows, cols] = flatw
            gm[rows, cols] = np.concatenate(
                [ev.grad_lanes for ev in nonempty])
            rm[rows, cols] = np.concatenate(
                [ev.restart_lanes for ev in nonempty])
            finish[rows, cols] = np.concatenate([
                (ev.finish_lanes if ev.finish_lanes is not None
                 else np.full(len(ev.workers), ev.time))
                for ev in nonempty])
            bi, lr, lc, _, _ = _worker_scatter_indices(wlens, flatw)
            P_sub[bi, lr, lc] = np.concatenate(
                [ev.P_sub.ravel() for ev in nonempty])
        edges, n_edges = _pack_edges(events, edge_bound)
        return cls(
            k0=events[0].k,
            times=times,
            workers=workers, n_workers=wlens.astype(np.int32), P_sub=P_sub,
            grad_workers=gm, restart_workers=rm,
            param_copies_sent=np.fromiter(
                (ev.param_copies_sent for ev in events),
                dtype=np.int64, count=E),
            edges=edges, n_edges=n_edges, finish=finish,
        )

    def pad_to(self, E: int) -> "SparseEventBatch":
        """Pad with no-op events (empty active sets) up to length E.

        An empty active set gathers nothing and scatters nothing, so the
        scan carry ``(W, S, y, ptr)`` passes through bit-exact — the sparse
        analogue of :meth:`EventBatch.pad_to`'s identity events.
        """
        pad = E - self.E
        if pad < 0:
            raise ValueError(f"cannot pad E={self.E} down to {E}")
        if pad == 0:
            return self
        A = self.A
        off = np.zeros((pad, A), dtype=bool)
        return dataclasses.replace(
            self,
            times=np.concatenate([self.times, np.full(pad, self.times[-1])]),
            workers=np.concatenate(
                [self.workers, np.full((pad, A), -1, dtype=np.int32)]),
            n_workers=np.concatenate(
                [self.n_workers, np.zeros(pad, dtype=np.int32)]),
            P_sub=np.concatenate(
                [self.P_sub, np.zeros((pad, A, A), dtype=np.float32)]),
            grad_workers=np.concatenate([self.grad_workers, off]),
            restart_workers=np.concatenate([self.restart_workers, off]),
            param_copies_sent=np.concatenate(
                [self.param_copies_sent, np.zeros(pad, dtype=np.int64)]),
            edges=np.concatenate([
                self.edges,
                np.full((pad,) + self.edges.shape[1:], -1, dtype=np.int32)]),
            n_edges=np.concatenate(
                [self.n_edges, np.zeros(pad, dtype=np.int32)]),
            finish=(np.concatenate(
                [self.finish, np.full((pad, A), self.times[-1])])
                if self.finish is not None else None),
        )

    def slice(self, start: int, stop: int) -> "SparseEventBatch":
        """Contiguous event range ``[start, stop)`` as its own batch.

        Pure numpy views (no copies) — the bucketed dispatcher carves each
        same-bucket stream segment out of its bucket's packed arrays with
        this.  ``k0`` shifts with ``start``, which is only meaningful when
        the batch's own events are k-consecutive (bucket batches are not;
        :class:`BucketedSparseEventBatch` restores stream ``k`` itself).
        """
        if not (0 <= start < stop <= self.E):
            raise ValueError(f"bad slice [{start}, {stop}) of E={self.E}")
        return dataclasses.replace(
            self, k0=self.k0 + start,
            times=self.times[start:stop],
            workers=self.workers[start:stop],
            n_workers=self.n_workers[start:stop],
            P_sub=self.P_sub[start:stop],
            grad_workers=self.grad_workers[start:stop],
            restart_workers=self.restart_workers[start:stop],
            param_copies_sent=self.param_copies_sent[start:stop],
            edges=self.edges[start:stop],
            n_edges=self.n_edges[start:stop],
            finish=(self.finish[start:stop]
                    if self.finish is not None else None),
        )

    def head(self, j: int) -> "SparseEventBatch":
        """The first ``j`` events (no-op when ``j >= E``).

        The packed-stream consumer truncates a chunk here when ``max_time``
        lands inside it — the array analogue of the per-event loop's
        ``ev.time > max_time`` break.
        """
        if j >= self.E:
            return self
        return self.slice(0, j)

    # -- stream-order metadata (uniform with BucketedSparseEventBatch) ----
    def stream_times(self) -> np.ndarray:
        return self.times

    def stream_copies(self) -> np.ndarray:
        return self.param_copies_sent

    def stream_n_active(self) -> np.ndarray:
        return self.n_active

    def to_events(self, n: int) -> List[ScheduleEvent]:
        """Reconstruct per-event form (round-trip/diagnostic helper).

        The returned events are sparse-native views of the packed lanes;
        their dense ``.P`` (an identity with the float32 submatrix scattered
        in) materializes lazily like any other event's.
        """
        out = []
        for e in range(self.E):
            m = int(self.n_workers[e])
            me = int(self.n_edges[e])
            out.append(ScheduleEvent(
                k=self.k0 + e, time=float(self.times[e]), n=n,
                workers=self.workers[e, :m],
                P_sub=self.P_sub[e, :m, :m],
                grad_lanes=self.grad_workers[e, :m],
                restart_lanes=self.restart_workers[e, :m],
                edges=self.edges[e, :me],
                param_copies_sent=int(self.param_copies_sent[e]),
                finish_lanes=(self.finish[e, :m]
                              if self.finish is not None else None),
            ))
        return out


def merge_event_groups(batch: SparseEventBatch,
                       K: int) -> Tuple[SparseEventBatch, np.ndarray]:
    """Merge runs of conflict-free events into compact K·A-lane rows.

    The packing half of the event-blocked scan (PR 6 measured ~100 µs of
    per-``lax.scan``-step thunk overhead *independent of N* — the dominant
    sparse-path cost for narrow lanes): consecutive events whose active
    sets are pairwise disjoint commute as state updates (each touches only
    its own ``(W, S, y, ptr)`` rows and gathers only rows the others never
    write), so a run of them is replayed *exactly* by one K·A-lane "event"
    whose ``P_sub`` is the block-diagonal stack of the members' submatrices
    and whose lanes are their concatenation.  The existing
    ``sparse_gossip_scan`` body consumes the merged row unchanged — the
    gather, the masked einsum (zero cross-blocks contribute exact zeros in
    order, so partial sums are bit-identical), and the unique-index scatter
    are all oblivious to the grouping — which amortizes the thunk overhead
    group-size-fold while keeping the replay bit-exact against the
    per-event dispatch.

    Packing is *compact*: each member contributes only its ``n_workers``
    valid lanes (its pad lanes are dropped), so a group holds as many
    events as fit in the K·A lane budget — for low-fill streams (DSGD-AAU
    rungs pack ~30% of their lanes) that is ~3× more events per scan step
    than block-slot placement at the same per-step lane cost.  Grouping is
    greedy in stream order and breaks at the first conflict or full budget,
    so order of application never matters within a group.  Returns the
    merged batch of lane width ``K·A`` plus ``lane_off``: (G, K·A) int32
    mapping every merged lane to its source event's offset within ``batch``
    (for per-lane η decay); pad lanes map to offset 0 — their masks are
    False, so their η is never applied.

    Merged rows are an *execution* form only: lanes are not globally
    sorted and ``times``/``k0`` keep whole-group granularity (``times`` =
    last member's clock, ``param_copies_sent`` = the group's sum) —
    round-trip via ``to_events`` is not supported.
    """
    E, A = batch.E, batch.A
    if K <= 1:
        off = np.broadcast_to(np.arange(E, dtype=np.int32)[:, None], (E, A))
        return batch, off
    AK = A * K
    groups: List[Tuple[int, int]] = []      # (start, count)
    start, count, lanes = 0, 0, 0
    used: set = set()
    for e in range(E):
        m = int(batch.n_workers[e])
        ws = batch.workers[e, :m].tolist()
        if count and (lanes + m > AK or not used.isdisjoint(ws)):
            groups.append((start, count))
            start, count, lanes = e, 0, 0
            used.clear()
        used.update(ws)
        count += 1
        lanes += m
    groups.append((start, count))
    G = len(groups)
    ew_m = max(1, int(max(batch.n_edges[s:s + c].sum()
                          for s, c in groups)))
    workers = np.full((G, AK), -1, dtype=np.int32)
    P_sub = np.zeros((G, AK, AK), dtype=np.float32)
    gm = np.zeros((G, AK), dtype=bool)
    rm = np.zeros((G, AK), dtype=bool)
    lane_off = np.zeros((G, AK), dtype=np.int32)
    edges = np.full((G, ew_m, 2), -1, dtype=np.int32)
    n_edges = np.zeros(G, dtype=np.int32)
    times = np.empty(G, dtype=np.float64)
    finish = np.zeros((G, AK), dtype=np.float64)
    copies = np.zeros(G, dtype=np.int64)
    for gi, (s, c) in enumerate(groups):
        o = 0
        for j in range(c):
            m = int(batch.n_workers[s + j])
            workers[gi, o:o + m] = batch.workers[s + j, :m]
            P_sub[gi, o:o + m, o:o + m] = batch.P_sub[s + j, :m, :m]
            gm[gi, o:o + m] = batch.grad_workers[s + j, :m]
            rm[gi, o:o + m] = batch.restart_workers[s + j, :m]
            finish[gi, o:o + m] = (batch.finish[s + j, :m]
                                   if batch.finish is not None
                                   else batch.times[s + j])
            lane_off[gi, o:o + m] = s + j
            o += m
            ne = int(batch.n_edges[s + j])
            if ne:
                e0 = int(n_edges[gi])
                edges[gi, e0:e0 + ne] = batch.edges[s + j, :ne]
                n_edges[gi] += ne
        times[gi] = batch.times[s + c - 1]
        copies[gi] = int(batch.param_copies_sent[s:s + c].sum())
    merged = SparseEventBatch(
        k0=batch.k0, times=times, workers=workers,
        n_workers=(workers >= 0).sum(axis=1).astype(np.int32),
        P_sub=P_sub, grad_workers=gm, restart_workers=rm,
        param_copies_sent=copies, edges=edges, n_edges=n_edges,
        finish=finish)
    return merged, lane_off


def geometric_buckets(n: int, base: int = 16, ratio: int = 4) -> Tuple[int, ...]:
    """Ascending lane-width ladder ``(base, base·ratio, …, n)`` capped at n.

    The bucketing granularity for schedulers whose per-event active-set
    size is a *distribution* rather than a constant (DSGD-AAU).  The ladder
    is deliberately coarse: measured AAU streams at N=256 put ~90% of
    events at ≤16 workers with a heavy tail up to ~n, and a fine (pow2)
    ladder fragments the stream into single-event bucket runs — with
    ratio 4 starting at 16, consecutive events almost always share a
    bucket, so the runner dispatches long homogeneous chunks.  The last
    rung is always exactly ``n``: the dense-fallback bucket that absorbs
    the rare epoch-boundary barrier events.
    """
    if n <= base:
        return (max(1, n),)
    ladder = []
    w = base
    while w < n:
        ladder.append(w)
        w *= ratio
    ladder.append(n)
    return tuple(ladder)


def bucket_index(buckets: Sequence[int], size: int) -> int:
    """Smallest bucket whose lane width fits ``size`` active workers."""
    for b, width in enumerate(buckets):
        if size <= width:
            return b
    raise ValueError(
        f"active-set size {size} exceeds the widest bucket {buckets[-1]}")


@dataclasses.dataclass(frozen=True)
class BucketedSparseEventBatch:
    """``E`` ScheduleEvents partitioned into lane-width buckets.

    The bucketed sibling of :class:`SparseEventBatch`: instead of padding
    every event to one scheduler-wide ``active_bound`` (for DSGD-AAU that
    is A = n — the whole sparse path degenerates to dense padding), events
    are grouped by active-set size into a small ladder of lane widths
    (:meth:`Scheduler.active_buckets`) and packed once per bucket.  Each
    bucket holds its events *in stream order*; ``event_bucket`` /
    ``positions`` record, for every stream position, which bucket the event
    went to and where it sits inside that bucket's packed arrays, so the
    original order is always reconstructible (:meth:`to_events`).

    Execution stays order-exact: state updates are sequential, so the
    consumer never replays a whole bucket at once — :meth:`segments` yields
    the stream's maximal runs of same-bucket events (contiguous both in the
    stream and inside their bucket's arrays), and the runner dispatches
    those runs in order, each through the compiled program of its bucket's
    lane width.  ``-1``-padded lanes inside a bucket keep the
    :class:`SparseEventBatch` no-op semantics, so a size-5 event in the
    A=16 bucket is exact, just 11 lanes lighter than the old A=n padding.
    """
    k0: int                                  # iteration counter of stream pos 0
    buckets: Tuple[int, ...]                 # ascending lane widths
    batches: Tuple[Optional[SparseEventBatch], ...]  # one per bucket (None: empty)
    event_bucket: np.ndarray                 # (E,) int32 bucket index per stream pos
    positions: np.ndarray                    # (E,) int32 row within the bucket batch

    @property
    def E(self) -> int:
        return len(self.event_bucket)

    @classmethod
    def from_events(cls, events: Sequence[ScheduleEvent],
                    buckets: Sequence[int],
                    edge_bound: Optional[int] = None
                    ) -> "BucketedSparseEventBatch":
        if not events:
            raise ValueError("cannot pack an empty event block")
        buckets = tuple(buckets)
        if list(buckets) != sorted(set(buckets)):
            raise ValueError(f"buckets must be ascending and unique: {buckets}")
        eb = np.fromiter(
            (bucket_index(buckets, len(ev.workers)) for ev in events),
            dtype=np.int32, count=len(events))
        positions = np.zeros(len(events), dtype=np.int32)
        per_bucket: List[List[ScheduleEvent]] = [[] for _ in buckets]
        for i, ev in enumerate(events):
            b = int(eb[i])
            positions[i] = len(per_bucket[b])
            per_bucket[b].append(ev)
        batches = tuple(
            SparseEventBatch.from_events(
                evs, active_bound=buckets[b],
                # a bucket of A-worker events carries at most the A-clique's
                # edges — no reason to pad its edge arrays to the graph width
                edge_bound=min(edge_bound,
                               max(1, buckets[b] * (buckets[b] - 1) // 2))
                if edge_bound is not None else None)
            if evs else None
            for b, evs in enumerate(per_bucket))
        return cls(k0=events[0].k, buckets=buckets, batches=batches,
                   event_bucket=eb, positions=positions)

    def segments(self) -> Iterator[Tuple[int, int, int]]:
        """Maximal same-bucket runs, in stream order.

        Yields ``(bucket, start, stop)``: stream positions ``[start, stop)``
        all live in ``bucket``, and (because stream order is preserved
        within each bucket) they occupy the *contiguous* row range
        ``[positions[start], positions[start] + stop - start)`` of
        ``batches[bucket]``.
        """
        eb = self.event_bucket
        start = 0
        for i in range(1, len(eb)):
            if eb[i] != eb[start]:
                yield int(eb[start]), start, i
                start = i
        yield int(eb[start]), start, len(eb)

    def segment_batches(self) -> Iterator[Tuple[int, int, SparseEventBatch]]:
        """(bucket, stream_start, packed slice) per segment, in stream order."""
        for b, start, stop in self.segments():
            p0 = int(self.positions[start])
            yield b, start, self.batches[b].slice(p0, p0 + (stop - start))

    def head(self, j: int) -> "BucketedSparseEventBatch":
        """The first ``j`` stream positions (no-op when ``j >= E``).

        Each bucket keeps exactly its events among the first ``j`` — stream
        order is preserved within buckets, so that is a prefix of every
        bucket's packed rows.  Used by the packed-stream consumer to
        truncate a chunk at a ``max_time`` crossing.
        """
        if j >= self.E:
            return self
        eb = self.event_bucket[:j]
        counts = np.bincount(eb, minlength=len(self.buckets))
        batches = tuple(
            batch.slice(0, int(c)) if (batch is not None and c) else None
            for batch, c in zip(self.batches, counts))
        return dataclasses.replace(self, batches=batches, event_bucket=eb,
                                   positions=self.positions[:j])

    def _stream_gather(self, field: str, dtype) -> np.ndarray:
        out = np.zeros(self.E, dtype=dtype)
        for b, batch in enumerate(self.batches):
            if batch is None:
                continue
            mask = self.event_bucket == b
            out[mask] = getattr(batch, field)[self.positions[mask]]
        return out

    def stream_times(self) -> np.ndarray:
        """Per-event virtual clocks in stream order."""
        return self._stream_gather("times", np.float64)

    def stream_copies(self) -> np.ndarray:
        """Per-event parameter copies sent, in stream order."""
        return self._stream_gather("param_copies_sent", np.int64)

    def stream_n_active(self) -> np.ndarray:
        """Per-event active-gradient counts, in stream order."""
        out = np.zeros(self.E, dtype=np.int64)
        for b, batch in enumerate(self.batches):
            if batch is None:
                continue
            mask = self.event_bucket == b
            out[mask] = batch.n_active[self.positions[mask]]
        return out

    def to_events(self, n: int) -> List[ScheduleEvent]:
        """Reconstruct the stream-ordered per-event form."""
        unpacked = [batch.to_events(n) if batch is not None else []
                    for batch in self.batches]
        out = []
        for i, (b, p) in enumerate(zip(self.event_bucket, self.positions)):
            ev = unpacked[int(b)][int(p)]
            ev.k = self.k0 + i      # bucket-local k0+pos → stream counter
            out.append(ev)
        return out

    def occupancy(self) -> List[Dict[str, float]]:
        """Per-bucket packing stats: how full the lanes actually are.

        ``lane_fill`` is Σ active workers / (events · A) for the bucket —
        the padding-waste measure the static ``active_bound`` hid (the old
        single-bound packing of a DSGD-AAU stream at N=256 sat under 4%
        fill).  ``events`` counts the bucket's stream share.
        """
        out = []
        for b, batch in enumerate(self.batches):
            if batch is None:
                out.append({"A": int(self.buckets[b]), "events": 0,
                            "lane_fill": 0.0})
                continue
            fill = float(batch.n_workers.sum()) / (batch.E * batch.A)
            out.append({"A": int(self.buckets[b]), "events": int(batch.E),
                        "lane_fill": fill})
        return out


class PackedEventStream:
    """Pull-based packed-chunk view of a scheduler's event stream.

    The consumption protocol of the runner's sparse path: ``next_chunk(k)``
    returns the next ``k`` events already packed — a
    :class:`SparseEventBatch` for single-rung schedulers, a
    :class:`BucketedSparseEventBatch` for multi-rung ladders — or a shorter
    final chunk / ``None`` when a finite stream ends.  This base adapter
    wraps any scheduler's ``events()`` iterator and packs with the
    ``from_events`` classmethods, so every scheduler conforms; schedulers
    with a *native* generator (``Scheduler._native_packed_stream``) fill the
    packed arrays directly inside their event loop and skip the per-event
    ``ScheduleEvent`` objects entirely.
    """

    def __init__(self, scheduler: "Scheduler"):
        self.scheduler = scheduler
        self.buckets = scheduler.active_buckets()
        self._ebound = scheduler.edge_bound()
        self._iter = scheduler.events()

    @property
    def bucketed(self) -> bool:
        return len(self.buckets) > 1

    def next_chunk(self, k: int):
        buf = []
        for ev in self._iter:
            buf.append(ev)
            if len(buf) == k:
                break
        if not buf:
            return None
        if self.bucketed:
            return BucketedSparseEventBatch.from_events(
                buf, buckets=self.buckets, edge_bound=self._ebound)
        return SparseEventBatch.from_events(
            buf, active_bound=self.buckets[-1], edge_bound=self._ebound)


class CliquePackedStream(PackedEventStream):
    """Array-native packing for clique-event schedulers (AAU/Prague/sync).

    Consumes a *tuple* generator — ``(t, workers, P_sub, edges, copies)``
    per event, every lane grad+restart active (the shape all clique
    schedulers share) — and fills the packed chunk arrays directly: the
    per-event ``ScheduleEvent`` object, its lane masks, and the
    ``from_events`` re-scatter all disappear from the generation hot loop.
    The produced chunks are bit-identical to the object path's (same float
    casts, same ``k0``/edge-width conventions), which the round-trip tests
    pin.
    """

    def __init__(self, scheduler: "Scheduler", tuples: Iterator[tuple]):
        self.scheduler = scheduler
        self.buckets = scheduler.active_buckets()
        self._ebound = scheduler.edge_bound()
        self._tuples = tuples
        self._k = 0

    def next_chunk(self, k: int):
        buf = []
        for tup in self._tuples:
            buf.append(tup)
            if len(buf) == k:
                break
        if not buf:
            return None
        chunk = (self._pack_bucketed(buf) if self.bucketed
                 else self._pack_flat(buf))
        self._k += len(buf)
        return chunk

    @staticmethod
    def _alloc(E: int, A: int, ew: int):
        return dict(
            workers=np.full((E, A), -1, dtype=np.int32),
            n_workers=np.zeros(E, dtype=np.int32),
            P_sub=np.zeros((E, A, A), dtype=np.float32),
            grad_workers=np.zeros((E, A), dtype=bool),
            restart_workers=np.zeros((E, A), dtype=bool),
            edges=np.full((E, ew, 2), -1, dtype=np.int32),
            n_edges=np.zeros(E, dtype=np.int32),
            times=np.empty(E, dtype=np.float64),
            param_copies_sent=np.zeros(E, dtype=np.int64),
            finish=np.zeros((E, A), dtype=np.float64),
        )

    @staticmethod
    def _fill(a: dict, row: int, t, widx, P_sub, edges, copies,
              finish=None) -> None:
        m = len(widx)
        a["workers"][row, :m] = widx
        a["n_workers"][row] = m
        a["P_sub"][row, :m, :m] = P_sub
        a["grad_workers"][row, :m] = True
        a["restart_workers"][row, :m] = True
        e = len(edges)
        if e:
            a["edges"][row, :e] = edges
        a["n_edges"][row] = e
        a["times"][row] = t
        a["param_copies_sent"][row] = copies
        a["finish"][row] = t            # pad lanes read the event clock
        if finish is not None:
            a["finish"][row, :m] = finish

    def _pack_flat(self, buf) -> SparseEventBatch:
        a = self._alloc(len(buf), self.buckets[-1], self._ebound)
        for row, tup in enumerate(buf):
            self._fill(a, row, *tup)
        return SparseEventBatch(k0=self._k, **a)

    def _pack_bucketed(self, buf) -> BucketedSparseEventBatch:
        buckets = self.buckets
        E = len(buf)
        eb = np.empty(E, dtype=np.int32)
        pos = np.empty(E, dtype=np.int32)
        counts = [0] * len(buckets)
        for j, tup in enumerate(buf):
            b = bucket_index(buckets, len(tup[1]))
            eb[j] = b
            pos[j] = counts[b]
            counts[b] += 1
        allocs = [
            self._alloc(c, A, min(self._ebound, max(1, A * (A - 1) // 2)))
            if c else None for c, A in zip(counts, buckets)]
        k0s = [None] * len(buckets)
        for j, tup in enumerate(buf):
            b = int(eb[j])
            if k0s[b] is None:
                k0s[b] = self._k + j
            self._fill(allocs[b], int(pos[j]), *tup)
        batches = tuple(
            SparseEventBatch(k0=k0s[b], **a) if a is not None else None
            for b, a in enumerate(allocs))
        return BucketedSparseEventBatch(k0=self._k, buckets=buckets,
                                        batches=batches, event_bucket=eb,
                                        positions=pos)


class Scheduler:
    """Base: iterate ScheduleEvents forever (caller bounds by count/time)."""

    name = "base"

    #: True when *every* event touches all n workers (barrier algorithms
    #: like synchronous DSGD).  The sparse gather-compute-scatter path is
    #: pure overhead for such streams, so the runner's ``mode="sparse_scan"``
    #: automatically falls back to the dense scan.
    global_events = False

    def __init__(self, graph: Graph, straggler: TimeModelSpec):
        # ``straggler`` is anything satisfying the TimeModelSpec protocol:
        # the paper's StragglerModel or any registered Scenario
        # (repro/scenarios) — schedulers only ever touch the sampler's
        # TimeModel surface (sample / sample_batch / sample_horizon /
        # sample_all / base).
        if straggler.n != graph.n:
            raise ValueError("time model and graph disagree on n")
        self.graph = graph
        self.n = graph.n
        self.sampler: TimeModel = straggler.make_sampler()

    def events(self) -> Iterator[ScheduleEvent]:
        raise NotImplementedError

    def edge_bound(self) -> int:
        """Max #active edges any single event of this scheduler can carry.

        Fixed per scheduler so every EventBatch has the same compact-edge
        width (stable shapes ⇒ no recompilation across blocks).  Subclasses
        with tighter structure (pairwise gossip, bounded groups) override.
        """
        return max(1, len(self.graph.edges))

    def active_bound(self) -> int:
        """Max #workers any single event touches (grad, restart, or edge).

        This is the fixed lane width ``A`` of :class:`SparseEventBatch` —
        the per-event cost of the sparse scan path is O(A·D) gradients plus
        O(A²·D) mixing, so tight subclass overrides (AD-PSGD/AGP: 2,
        Prague: group size) are what turn O(n²·D) events into O(D) ones.
        """
        return self.n

    def active_buckets(self) -> Tuple[int, ...]:
        """Ascending lane-width ladder this scheduler's events pack into.

        The generalization of :meth:`active_bound` from a scalar to a
        distribution: schedulers whose events all share one size keep the
        degenerate single-bucket default (AD-PSGD/AGP always ``(2,)``,
        Prague ``(group_size,)``, the sync barrier ``(n,)``) and the runner
        compiles exactly the programs it always did.  Schedulers whose
        active-set size *varies* per event (DSGD-AAU: clique sizes from 2 up
        to n at epoch barriers) override with a multi-rung ladder so the
        common small events stop paying the worst case's padding.  The last
        rung must equal :meth:`active_bound` — it is the dense fallback that
        makes every event packable.
        """
        return (self.active_bound(),)

    def _native_packed_stream(self) -> Optional[PackedEventStream]:
        """Native packed-generation fast path, or None to use the adapter.

        Subclasses with a generator that fills ``SparseEventBatch`` /
        ``BucketedSparseEventBatch`` arrays directly (no intermediate
        ``ScheduleEvent`` objects) return their stream here.  The packed
        arrays must be *bit-identical* to the adapter path's — same RNG
        consumption order, same float casts — which
        tests/test_fused_stream.py pins chunk-by-chunk for every scheduler.
        """
        return None

    def packed_stream(self, native: bool = True) -> PackedEventStream:
        """The event stream in packed-chunk (``next_chunk``) form.

        ``native=True`` (default) uses the scheduler's array-native
        generator when it has one; ``native=False`` forces the
        object-path adapter (equivalence tests, custom ``events()``
        overrides).
        """
        if native:
            stream = self._native_packed_stream()
            if stream is not None:
                return stream
        return PackedEventStream(self)

    def event_batches(self, block_size: int) -> Iterator[EventBatch]:
        """Pack consecutive events into EventBatches of ``block_size``.

        A finite event stream ends with one trailing partial batch (the
        built-in schedulers stream forever, but subclasses may not).
        """
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        bound = self.edge_bound()
        buf: List[ScheduleEvent] = []
        for ev in self.events():
            buf.append(ev)
            if len(buf) == block_size:
                yield EventBatch.from_events(buf, edge_bound=bound)
                buf = []
        if buf:
            yield EventBatch.from_events(buf, edge_bound=bound)

    def sparse_event_batches(self, block_size: int,
                             native: bool = True) -> Iterator[SparseEventBatch]:
        """Pack consecutive events into active-set SparseEventBatches.

        With ``native=True`` (default) single-rung schedulers that carry an
        array-native generator fill the packed arrays directly — no
        per-event ``ScheduleEvent`` objects — producing bit-identical
        batches to the object path (``native=False``).
        """
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        if native and len(self.active_buckets()) == 1:
            stream = self._native_packed_stream()
            if stream is not None and not stream.bucketed:
                while True:
                    chunk = stream.next_chunk(block_size)
                    if chunk is None:
                        return
                    yield chunk
                    if chunk.E < block_size:
                        return
        abound = self.active_bound()
        ebound = self.edge_bound()
        buf: List[ScheduleEvent] = []
        for ev in self.events():
            buf.append(ev)
            if len(buf) == block_size:
                yield SparseEventBatch.from_events(
                    buf, active_bound=abound, edge_bound=ebound)
                buf = []
        if buf:
            yield SparseEventBatch.from_events(
                buf, active_bound=abound, edge_bound=ebound)

    def bucketed_sparse_event_batches(
            self, block_size: int,
            native: bool = True) -> Iterator[BucketedSparseEventBatch]:
        """Pack consecutive events into bucketed lane-width batches.

        ``native=True`` (default) takes the scheduler's array-native
        generator when it produces bucketed chunks (multi-rung ladders).
        """
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        if native and len(self.active_buckets()) > 1:
            stream = self._native_packed_stream()
            if stream is not None and stream.bucketed:
                while True:
                    chunk = stream.next_chunk(block_size)
                    if chunk is None:
                        return
                    yield chunk
                    if chunk.E < block_size:
                        return
        buckets = self.active_buckets()
        ebound = self.edge_bound()
        buf: List[ScheduleEvent] = []
        for ev in self.events():
            buf.append(ev)
            if len(buf) == block_size:
                yield BucketedSparseEventBatch.from_events(
                    buf, buckets=buckets, edge_bound=ebound)
                buf = []
        if buf:
            yield BucketedSparseEventBatch.from_events(
                buf, buckets=buckets, edge_bound=ebound)

    # -- shared helpers ---------------------------------------------------
    def _mask(self, workers) -> np.ndarray:
        m = np.zeros(self.n, dtype=bool)
        m[list(workers)] = True
        return m


class AAUScheduler(Scheduler):
    """DSGD-AAU (paper Algorithms 1–3).

    All workers compute local gradients at their own pace.  An iteration ends
    when the set of currently-finished workers contains at least one
    Pathsearch-committable edge; every finished worker then gossip-averages
    with its finished graph-neighbors using Metropolis weights, applies its
    gradient, and restarts.  Stragglers simply keep computing across
    iterations — nobody stalls on them, yet Pathsearch guarantees their
    information joins the spanning structure at least once per epoch.
    """

    name = "dsgd_aau"

    def __init__(self, graph: Graph, straggler: TimeModelSpec,
                 buckets: Optional[Sequence[int]] = None):
        super().__init__(graph, straggler)
        if buckets is not None:
            buckets = tuple(buckets)
            if not buckets or buckets[-1] != self.n:
                raise ValueError(
                    f"AAU buckets must end at n={self.n} (the dense "
                    f"fallback for epoch-boundary barriers): {buckets}")
        self._buckets = buckets

    def active_buckets(self) -> Tuple[int, ...]:
        """Coarse geometric ladder over the finished-clique size distribution.

        AAU's event sizes are heavy-tailed — measured streams at N=256 put
        the median finished clique at ~5 workers and p90 at ~13, with a thin
        tail reaching n at Pathsearch epoch boundaries — so a static
        ``active_bound()`` lane width of n pads the typical event ~30×.
        :func:`geometric_buckets`' defaults (start 16, ratio 4) were chosen
        against that measurement: ≳90% of events land in the first rung and
        consecutive events almost always share a bucket, keeping the
        runner's same-bucket dispatch segments long.  ``buckets=`` at
        construction overrides the ladder (tests force fine ladders to
        exercise multi-bucket streams at small n).
        """
        return self._buckets if self._buckets is not None \
            else geometric_buckets(self.n)

    def _clique_tuples(self) -> Iterator[tuple]:
        """The AAU event process as packed-ready tuples.

        Single source of truth for the simulation loop: yields
        ``(t, workers, P_sub, edges, copies, finish)`` per event —
        ``finish`` the per-lane raw completion clocks (clique members wait
        for the newest finisher, so ``finish ≤ t`` lane-wise);
        :meth:`events` wraps each into a :class:`ScheduleEvent` for the
        legacy paths and :meth:`_native_packed_stream` feeds them straight
        into :class:`CliquePackedStream` array fills.
        """
        n = self.n
        adj = self.graph.adj
        ps = PathSearchState(self.graph)
        sample_batch = self.sampler.sample_batch
        heap: List[Tuple[float, int]] = []
        for i, dt in enumerate(sample_batch(np.arange(n))):
            heapq.heappush(heap, (dt, i))
        finished = np.zeros(n, dtype=bool)
        finish_at = np.zeros(n, dtype=np.float64)
        while True:
            t, i = heapq.heappop(heap)
            finished[i] = True
            finish_at[i] = t
            if n > 1:
                # One O(deg) neighborhood scan per worker finish instead of
                # an O(|finished|²) rescan: between commits the component
                # partition is frozen and earlier finishes found nothing, so
                # the committable set is exactly the edges incident to the
                # newest finisher (PathSearchState.novel_edges_incident).
                novel = ps.novel_edges_incident(i, finished)
                if not novel:
                    continue
                ps.commit(novel)
            # degenerate single-worker case (n == 1): every finish fires
            # All finished workers exchange with their finished graph-neighbors:
            # the event is the finished clique's Metropolis mixing, built as an
            # m×m submatrix — the dense (n, n) matrix never exists here.
            fin = np.flatnonzero(finished)
            widx = fin.astype(np.int32)
            sub_adj = adj[np.ix_(widx, widx)]
            er, ec = np.nonzero(np.triu(sub_adj, k=1))
            edges = np.stack([widx[er], widx[ec]], axis=1) if er.size \
                else _EMPTY_EDGES
            yield (t, widx, metropolis_submatrix(n, widx, sub_adj),
                   edges, 2 * len(edges), finish_at[widx].copy())
            # batch-draw the restarted workers' next completion times: one
            # vectorized RNG call instead of one heap-push-sized draw each
            fl = fin.tolist()
            for j, dt in zip(fl, sample_batch(fl)):
                heapq.heappush(heap, (t + dt, j))
            finished[:] = False
            if n > 1 and ps.epoch_complete():
                ps.reset_epoch()

    def events(self) -> Iterator[ScheduleEvent]:
        n = self.n
        for k, (t, widx, P_sub, edges, copies, fin) in \
                enumerate(self._clique_tuples()):
            lanes = np.ones(len(widx), dtype=bool)
            yield ScheduleEvent(
                k=k, time=t, n=n, workers=widx, P_sub=P_sub,
                grad_lanes=lanes, restart_lanes=lanes,
                edges=edges, param_copies_sent=copies,
                finish_lanes=fin,
            )

    def _native_packed_stream(self) -> Optional[PackedEventStream]:
        return CliquePackedStream(self, self._clique_tuples())

    # expose for diagnostics
    def make_pathsearch(self) -> PathSearchState:
        return PathSearchState(self.graph)


class SyncScheduler(Scheduler):
    """Synchronous DSGD (eq. 2): every iteration waits for *all* workers."""

    name = "dsgd_sync"
    global_events = True  # every event is a full barrier: sparse buys nothing

    def events(self) -> Iterator[ScheduleEvent]:
        n = self.n
        edge_list = self.graph.edges
        # The barrier mixes the whole static graph every iteration: one dense
        # Metropolis build up front, shared by every event (m = n, so the
        # "submatrix" is the full matrix and the dense view is pre-cached).
        P = metropolis_matrix(n, edge_list)
        workers = np.arange(n, dtype=np.int32)
        edges = (np.asarray(edge_list, dtype=np.int32).reshape(-1, 2)
                 if edge_list else _EMPTY_EDGES)
        t = 0.0
        k = 0
        while True:
            dur = self.sampler.sample_all()  # one draw, as before
            fin = t + dur                    # per-worker completion clocks
            t += float(dur.max())            # barrier: slowest worker
            # independent mask copies per role (a consumer mutating one view
            # must not flip the other); P is shared across events as before
            gl = np.ones(n, dtype=bool)
            rl = np.ones(n, dtype=bool)
            yield ScheduleEvent(
                k=k, time=t, n=n, workers=workers, P_sub=P,
                grad_lanes=gl, restart_lanes=rl, edges=edges,
                param_copies_sent=2 * len(edge_list),
                dense_P=P, dense_grad=gl, dense_restart=rl,
                finish_lanes=fin,
            )
            k += 1

    def _sync_tuples(self) -> Iterator[tuple]:
        n = self.n
        edge_list = self.graph.edges
        P = metropolis_matrix(n, edge_list)
        workers = np.arange(n, dtype=np.int32)
        edges = (np.asarray(edge_list, dtype=np.int32).reshape(-1, 2)
                 if edge_list else _EMPTY_EDGES)
        copies = 2 * len(edge_list)
        t = 0.0
        while True:
            dur = self.sampler.sample_all()
            fin = t + dur
            t += float(dur.max())
            yield (t, workers, P, edges, copies, fin)

    def _native_packed_stream(self) -> Optional[PackedEventStream]:
        # The runner never routes the barrier stream through the sparse
        # path (global_events forces the dense fallback), but the packed
        # round-trip tests cover all five schedulers, so keep it native.
        return CliquePackedStream(self, self._sync_tuples())
