"""DSGD-AAU parameter updates in JAX.

Three execution modes share the same math (eq. 5, ``W(k) = [W(k−1) − ηG] P(k)``):

1. **Per-event simulator** (`masked_gossip_step` / `build_event_step`): all N
   workers' parameters live in one pytree with a leading worker axis; one
   jitted dispatch advances one ScheduleEvent.  Kept as the reference path
   (the scan path is equivalence-tested against it).  The mixing contraction
   optionally runs through the Pallas ``gossip_mix`` kernels — with
   ``use_kernel`` the whole event (gradient step + mixing) is the single
   fused ``masked_gossip_mix`` kernel call.

2. **Block-compiled simulator** (`masked_gossip_scan` / `build_event_scan`):
   an entire :class:`~repro.core.scheduler.EventBatch` — stacked
   ``(E, n, n)`` consensus matrices, ``(E, n)`` masks, ``(E,)`` step sizes —
   advances ``(W, S, y)`` inside one ``jax.lax.scan``, i.e. one XLA dispatch
   per E events instead of E dispatches.  Per-worker batch refresh happens
   *on device*: each worker owns a pre-drawn sample pool (leading axes
   ``(n, pool)``) indexed by a restart counter ``ptr`` that the scan carries
   and bumps wherever ``restart_workers`` fires, eliminating the host
   round-trip the legacy runner paid per event.  ``ptr`` wraps modulo the
   pool size, so runs longer than the pool revisit samples cyclically —
   size the pool to the expected restart count for exact per-event
   equivalence.

2b. **Sparse active-set simulator** (`sparse_gossip_scan` /
   `build_sparse_event_scan`): the same block-compiled scan consuming
   :class:`~repro.core.scheduler.SparseEventBatch` arrays — per event it
   *gathers* the ≤A active workers' rows, snapshots, and pool batches,
   evaluates gradients only for those lanes, mixes with the A×A consensus
   submatrix (optionally via the Pallas ``sparse_gossip`` gather-fused
   kernel), and *scatters* the updated rows back.  O(A·D) gradient work and
   O(A²·D) mixing per event instead of O(n·D)/O(n²·D) — the active-set cut
   that makes single-edge schedulers (AD-PSGD/AGP, A=2) cheap at N=256.

3. **Sharded production gossip** (`ring_gossip`, `graph_gossip`): inside
   ``shard_map`` over the mesh ``data``/worker axis, neighbor exchange is one
   ``jax.lax.ppermute`` per edge-direction — the TPU-native analogue of the
   paper's MPI peer-to-peer sends, touching only ICI neighbor links instead of
   a global all-reduce.  Used by launch/train.py and the dry-run.
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.obs.metrics import dense_metrics_update, sparse_metrics_update

Pytree = object


# ---------------------------------------------------------------------------
# Stacked-worker simulator updates
# ---------------------------------------------------------------------------

def gossip_mix_dense(W: Pytree, P: jax.Array, use_kernel: bool = False) -> Pytree:
    """out[j] = Σ_i P[i, j] · W[i]  for every leaf (leading axis = worker)."""
    if use_kernel:
        from repro.kernels.gossip_mix import ops as gossip_ops
        return jax.tree.map(lambda x: gossip_ops.gossip_mix(x, P.astype(x.dtype)), W)
    def mix(x):
        flat = x.reshape(x.shape[0], -1)
        out = jnp.einsum("nd,nj->jd", flat, P.astype(x.dtype),
                         precision=jax.lax.Precision.HIGHEST)
        return out.reshape(x.shape)
    return jax.tree.map(mix, W)


def masked_gossip_step(
    W: Pytree,
    S: Pytree,
    y: jax.Array,
    grads: Pytree,
    P: jax.Array,
    grad_mask: jax.Array,
    restart_mask: jax.Array,
    eta: jax.Array,
    use_kernel: bool = False,
) -> Tuple[Pytree, Pytree, jax.Array]:
    """One ScheduleEvent applied to stacked worker state.

    W: current parameters, leading axis N.
    S: snapshots at which in-flight gradients were evaluated.
    y: push-sum weights (stays all-ones for doubly-stochastic algorithms).
    grads: ∇F_j evaluated at S (all workers; masked here).
    Returns (W', S', y').
    """
    def expand(mask, leaf):
        return mask.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)

    gm = grad_mask
    # η is traced as float32; fold it into the 0/1 mask *before* casting to
    # each leaf's dtype (exact for fp32 — the product is η or 0) so a bf16
    # worker state stays bf16 through the update instead of being promoted
    # by the f32 scalar (a scan carry must keep its dtype).
    scaled = eta * gm.astype(jnp.float32)
    with jax.named_scope("masked_gossip_step"):
        if use_kernel:
            # Fused Pallas path: Pᵀ·(W − η·mask⊙G) in one kernel per leaf.
            from repro.kernels.gossip_mix import ops as gossip_ops
            Wn = jax.tree.map(
                lambda w, g: gossip_ops.masked_gossip_mix(
                    w, g, P.astype(w.dtype), scaled.astype(w.dtype)),
                W, grads)
        else:
            Wg = jax.tree.map(lambda w, g: w - expand(scaled, w) * g, W, grads)
            Wn = gossip_mix_dense(Wg, P, use_kernel=False)
        yn = jnp.einsum("n,nj->j", y, P.astype(y.dtype))
        rm = restart_mask
        Sn = jax.tree.map(lambda s, w: jnp.where(expand(rm, w) > 0, w, s), S, Wn)
    return Wn, Sn, yn


def debiased_average(W: Pytree, y: jax.Array) -> Pytree:
    """Network average of push-sum de-biased estimates: mean_j (W_j / y_j)."""
    def avg(x):
        yb = y.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)
        return jnp.mean(x / yb, axis=0)
    return jax.tree.map(avg, W)


# ---------------------------------------------------------------------------
# Sharded production gossip (shard_map over the worker axis)
# ---------------------------------------------------------------------------

def ring_gossip(x: jax.Array, axis_name: str, n: int,
                self_w: jax.Array, left_w: jax.Array, right_w: jax.Array) -> jax.Array:
    """Weighted ring gossip along a mesh axis: one ppermute per direction.

    ``out_j = self_w·x_j + left_w·x_{j−1} + right_w·x_{j+1}`` (indices mod n).
    With Metropolis ring weights (1/3, 1/3, 1/3) this is the doubly-stochastic
    mixing of a static ring; the weights may be masked per-step to express an
    AAU active-edge subset (a zero weight deactivates the edge — the permute
    still lowers, which is what the dry-run measures as worst-case traffic).
    """
    if n == 1:
        return x
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [((i + 1) % n, i) for i in range(n)]
    from_left = jax.lax.ppermute(x, axis_name, fwd)    # j receives x_{j-1}
    from_right = jax.lax.ppermute(x, axis_name, bwd)   # j receives x_{j+1}
    return self_w * x + left_w * from_left + right_w * from_right


def tree_ring_gossip(params: Pytree, axis_name: str, n: int,
                     self_w, left_w, right_w) -> Pytree:
    return jax.tree.map(
        lambda p: ring_gossip(p, axis_name, n, self_w.astype(p.dtype),
                              left_w.astype(p.dtype), right_w.astype(p.dtype)),
        params)


def graph_gossip(x: jax.Array, axis_name: str,
                 perms: Sequence[Sequence[Tuple[int, int]]],
                 weights: jax.Array, self_weight: jax.Array) -> jax.Array:
    """General static-topology gossip: one ppermute per neighbor-offset class.

    ``perms[e]`` is a full permutation (list of (src, dst)) delivering each
    worker its e-th neighbor's shard; ``weights[e]`` scales that contribution.
    Used for torus / multipod topologies where each worker has the same number
    of neighbor classes.
    """
    out = self_weight.astype(x.dtype) * x
    for e, perm in enumerate(perms):
        out = out + weights[e].astype(x.dtype) * jax.lax.ppermute(x, axis_name, perm)
    return out


def tree_graph_gossip(params: Pytree, axis_name: str, perms, weights, self_weight):
    return jax.tree.map(
        lambda p: graph_gossip(p, axis_name, perms, weights, self_weight), params)


# ---------------------------------------------------------------------------
# Convenience: build a jitted event-step for a given loss function
# ---------------------------------------------------------------------------

def build_event_step(loss_fn: Callable, use_kernel: bool = False):
    """Returns jit(step)(W, S, y, batches, P, grad_mask, restart_mask, eta).

    ``loss_fn(params, batch) -> scalar``; batches carry a leading worker axis.
    Gradients are evaluated at the snapshots S (staleness-correct, see
    core/scheduler.py docstring).
    """
    grad_fn = jax.grad(loss_fn)

    @jax.jit
    def step(W, S, y, batches, P, grad_mask, restart_mask, eta):
        grads = jax.vmap(grad_fn)(S, batches)
        return masked_gossip_step(
            W, S, y, grads, P, grad_mask, restart_mask, eta, use_kernel=use_kernel)

    return step


# ---------------------------------------------------------------------------
# Block-compiled path: one lax.scan over a whole EventBatch
# ---------------------------------------------------------------------------

def select_pool_batch(pools: Pytree, ptr: jax.Array) -> Pytree:
    """Each worker's current batch from its pre-drawn sample pool.

    ``pools`` leaves have shape (n, pool, ...); worker i's batch is
    ``pool[i, ptr[i] mod pool]`` — the on-device replacement for the legacy
    runner's host-side ``_refresh_batches``.
    """
    def sel(pool):
        idx = ptr % pool.shape[1]
        pick = jax.vmap(
            lambda row, p: jax.lax.dynamic_index_in_dim(
                row, p, axis=0, keepdims=False))
        return pick(pool, idx)
    return jax.tree.map(sel, pools)


def masked_gossip_scan(
    W: Pytree,
    S: Pytree,
    y: jax.Array,
    ptr: jax.Array,
    pools: Pytree,
    grad_fn: Callable,
    P_seq: jax.Array,
    grad_masks: jax.Array,
    restart_masks: jax.Array,
    etas: jax.Array,
    use_kernel: bool = False,
) -> Tuple[Pytree, Pytree, jax.Array, jax.Array]:
    """Advance (W, S, y) through a whole EventBatch in one ``lax.scan``.

    P_seq: (E, n, n); grad_masks/restart_masks: (E, n); etas: (E,).
    ptr: (n,) int32 restart counters indexing each worker's sample pool;
    incremented wherever ``restart_masks`` fires (a restarted worker starts
    its next local computation on a fresh batch).  Identity-padded no-op
    events (P=I, masks all-False — see EventBatch.pad_to) leave the carry
    bit-exact, so fixed-size blocks are safe.

    Returns the updated ``(W, S, y, ptr)``.
    """
    def body(carry, ev):
        W, S, y, ptr = carry
        P, gm, rm, eta = ev
        batches = select_pool_batch(pools, ptr)
        grads = jax.vmap(grad_fn)(S, batches)
        W, S, y = masked_gossip_step(
            W, S, y, grads, P, gm, rm, eta, use_kernel=use_kernel)
        ptr = ptr + rm.astype(ptr.dtype)
        return (W, S, y, ptr), None

    carry, _ = jax.lax.scan(
        body, (W, S, y, ptr), (P_seq, grad_masks, restart_masks, etas))
    return carry


def build_event_scan(loss_fn: Callable, use_kernel: bool = False,
                     telemetry: bool = False):
    """Returns jit(block)(W, S, y, ptr, pools, P_seq, gm_seq, rm_seq, etas).

    One compiled call advances the stacked state through E events — the
    block-compiled execution model (module docstring, mode 2).  Block length
    and pool size are baked into the trace, so keep them fixed across calls
    (the runner pads truncated blocks with no-op events).

    With ``telemetry`` the block additionally threads a
    :class:`~repro.obs.metrics.MetricsCarry` ``M`` (inserted after ``ptr``)
    and consumes per-event telemetry xs — ``ts`` (E,) f32 event clocks,
    ``fin`` (E, n) f32 raw completion clocks, ``ks`` (E,) i32 event
    indices, ``copies`` (E,) i32 — updating ``M`` once per scan step on
    device.  The ``(W, S, y, ptr)`` trajectory is bit-identical either
    way: the metrics update reads the state but never writes it.
    """
    grad_fn = jax.grad(loss_fn)

    if not telemetry:
        @jax.jit
        def block(W, S, y, ptr, pools, P_seq, grad_masks, restart_masks,
                  etas):
            return masked_gossip_scan(
                W, S, y, ptr, pools, grad_fn, P_seq, grad_masks,
                restart_masks, etas, use_kernel=use_kernel)

        return block

    @jax.jit
    def block_tel(W, S, y, ptr, M, pools, P_seq, grad_masks, restart_masks,
                  etas, ts, fin, ks, copies):
        def body(carry, ev):
            W, S, y, ptr, M = carry
            P, gm, rm, eta, t, f, k, cp = ev
            batches = select_pool_batch(pools, ptr)
            grads = jax.vmap(grad_fn)(S, batches)
            W, S, y = masked_gossip_step(
                W, S, y, grads, P, gm, rm, eta, use_kernel=use_kernel)
            ptr = ptr + rm.astype(ptr.dtype)
            with jax.named_scope("metrics_update"):
                M = dense_metrics_update(M, P, gm, rm, t, f, k, cp)
            return (W, S, y, ptr, M), None

        carry, _ = jax.lax.scan(
            body, (W, S, y, ptr, M),
            (P_seq, grad_masks, restart_masks, etas, ts, fin, ks, copies))
        return carry

    return block_tel


# ---------------------------------------------------------------------------
# Sparse active-set path: gather → compute → scatter per event
# ---------------------------------------------------------------------------

def select_pool_batch_at(pools: Pytree, widx: jax.Array,
                         ptra: jax.Array) -> Pytree:
    """Active-set batches: lane a gets ``pool[widx[a], ptra[a] mod pool]``.

    The sparse sibling of :func:`select_pool_batch`: instead of every
    worker's current batch it gathers only the A active lanes' batches —
    pools stay untouched for the other n − A workers.
    """
    def sel(pool):
        return pool[widx, ptra % pool.shape[1]]
    return jax.tree.map(sel, pools)


def sparse_gossip_scan(
    W: Pytree,
    S: Pytree,
    y: jax.Array,
    ptr: jax.Array,
    pools: Pytree,
    grad_fn: Callable,
    workers_seq: jax.Array,
    P_sub_seq: jax.Array,
    grad_masks: jax.Array,
    restart_masks: jax.Array,
    etas: jax.Array,
    use_kernel: bool = False,
) -> Tuple[Pytree, Pytree, jax.Array, jax.Array]:
    """Advance (W, S, y) through a :class:`SparseEventBatch` in one scan.

    The active-set execution of eq. (5): each scan step *gathers* the A
    active workers' snapshots, pool batches, and parameter rows, evaluates
    gradients **only for those lanes** (the ~n× vmap-grad cut for
    single-edge schedulers), mixes with the A×A consensus submatrix, and
    *scatters* the A updated rows back — every other worker's ``(W, S, y,
    ptr)`` row is never touched, read-modify-written only by the scatter's
    identity complement.

    workers_seq: (E, A) int32, ``-1``-padded (SparseEventBatch lanes);
    P_sub_seq: (E, A, A); grad_masks/restart_masks: (E, A) per-lane bools;
    etas: (E,) — one step size per event — or (E, A) per *lane* (merged
    block-diagonal rows, :func:`~repro.core.scheduler.merge_event_groups`,
    where one scan step replays K source events whose η-schedule positions
    differ).  Padded lanes carry zero P_sub rows/columns, so they gather
    row 0 harmlessly, contribute no mass, and their scatter index is mapped
    out of bounds (dropped).  Returns the updated ``(W, S, y, ptr)``.
    """
    if etas.ndim == 1:
        # broadcast to per-lane: the body's `eta * mask` product is then
        # elementwise either way, and one trace serves both calling forms
        etas = jnp.broadcast_to(etas[:, None], grad_masks.shape)

    def body(carry, ev):
        workers, P_sub, gm, rm, eta = ev

        def step(c):
            W, S, y, ptr = c
            return sparse_event_update(W, S, y, ptr, pools, grad_fn,
                                       workers, P_sub, gm, rm, eta,
                                       use_kernel=use_kernel)

        # Fixed-shape blocks arrive tail-padded with no-op rows (pad_to:
        # every lane -1; real rows always carry lane 0 — packing is
        # valid-first).  The whole gather-compute-scatter for a no-op row
        # is the identity, so skip it: the O(A²·D) mix of a padded step
        # would otherwise cost the same as a real event's, and short
        # same-bucket segments are mostly padding.
        return jax.lax.cond(workers[0] >= 0, step, lambda c: c, carry), None

    carry, _ = jax.lax.scan(
        body, (W, S, y, ptr),
        (workers_seq, P_sub_seq, grad_masks, restart_masks, etas))
    return carry


def sparse_event_update(
    W: Pytree,
    S: Pytree,
    y: jax.Array,
    ptr: jax.Array,
    pools: Pytree,
    grad_fn: Callable,
    workers: jax.Array,
    P_sub: jax.Array,
    gm: jax.Array,
    rm: jax.Array,
    eta: jax.Array,
    use_kernel: bool = False,
) -> Tuple[Pytree, Pytree, jax.Array, jax.Array]:
    """One active-set event against the stacked carry — the single scan step
    of :func:`sparse_gossip_scan`, factored out so the fused
    generate-and-consume scan (core/fused.py) applies the *identical*
    traced computation to events it materializes on device.

    workers: (A,) int32 ``-1``-padded; P_sub: (A, A); gm/rm: (A,) bools;
    eta: scalar or (A,) per-lane.  Returns the updated ``(W, S, y, ptr)``.
    """
    n = y.shape[0]

    def expand(mask, leaf):
        return mask.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)

    valid = workers >= 0
    gidx = jnp.where(valid, workers, 0)      # clamped gather index
    sidx = jnp.where(valid, workers, n)      # OOB ⇒ scatter drops the lane
    # -- gather ------------------------------------------------------
    with jax.named_scope("sparse_gather"):
        Sa = jax.tree.map(lambda s: s[gidx], S)
        ptra = ptr[gidx]
        batches = select_pool_batch_at(pools, gidx, ptra)
        grads = jax.vmap(grad_fn)(Sa, batches)   # A gradient lanes, not n
    scaled = eta * (gm & valid).astype(jnp.float32)
    # -- compute: P_subᵀ·(W_a − η·mask⊙G) ----------------------------
    if use_kernel:
        from repro.kernels.sparse_gossip import ops as sparse_ops
        Wn = jax.tree.map(
            lambda w, g: sparse_ops.sparse_gossip_rows(
                w, g, P_sub.astype(w.dtype), scaled.astype(w.dtype),
                gidx),
            W, grads)
    else:
        vf = valid.astype(jnp.float32)
        Pm = P_sub * vf[:, None] * vf[None, :]

        def mix(w, g):
            Wa = w[gidx]
            stepped = (Wa - expand(scaled, Wa) * g).reshape(
                Wa.shape[0], -1)
            out = jnp.einsum("ad,ab->bd", stepped, Pm.astype(Wa.dtype),
                             precision=jax.lax.Precision.HIGHEST)
            return out.reshape(Wa.shape)

        Wn = jax.tree.map(mix, W, grads)
    ya = jnp.einsum("a,ab->b", y[gidx], P_sub.astype(y.dtype))
    Sn = jax.tree.map(lambda s, w: jnp.where(expand(rm, w) > 0, w, s),
                      Sa, Wn)
    # -- scatter -----------------------------------------------------
    with jax.named_scope("sparse_scatter"):
        if use_kernel:
            # kernel scatter-into-carry: the (n, ...) parameter leaves are
            # updated through input/output aliasing (only the A active
            # windows are written) instead of XLA's fresh-buffer scatter;
            # the O(n) vector leaves (y, ptr) stay on the cheap XLA path.
            W = jax.tree.map(
                lambda w, rows: sparse_ops.sparse_scatter_rows(
                    w, rows.astype(w.dtype), workers),
                W, Wn)
            S = jax.tree.map(
                lambda s, rows: sparse_ops.sparse_scatter_rows(
                    s, rows.astype(s.dtype), workers),
                S, Sn)
        else:
            W = jax.tree.map(
                lambda w, rows: w.at[sidx].set(rows.astype(w.dtype),
                                               mode="drop"),
                W, Wn)
            S = jax.tree.map(
                lambda s, rows: s.at[sidx].set(rows.astype(s.dtype),
                                               mode="drop"),
                S, Sn)
        y = y.at[sidx].set(ya.astype(y.dtype), mode="drop")
        ptr = ptr.at[sidx].set(ptra + rm.astype(ptr.dtype), mode="drop")
    return W, S, y, ptr


def build_sparse_event_scan(loss_fn: Callable, use_kernel: bool = False,
                            telemetry: bool = False):
    """Returns jit(block)(W, S, y, ptr, pools, workers, P_sub, gm, rm, etas).

    One compiled call advances the stacked state through E active-set
    events (``SparseEventBatch`` arrays).  The lane width A and block length
    E are baked into the trace — fixed per scheduler *bucket*, so a handful
    of compiled programs (one per (A, E) shape the dispatcher emits) serves
    the whole stream.

    The ``(W, S, y, ptr)`` carry buffers are **donated**: the caller always
    threads the returned carry into the next block and never reuses the
    arguments (the runner's contract), so XLA reuses their n-row buffers
    in place instead of allocating a fresh copy per block — at N=1024 the
    W+S stack is ~0.7 GB of float32, twice per block without donation.

    With ``telemetry`` the block signature gains a
    :class:`~repro.obs.metrics.MetricsCarry` ``M`` after ``ptr`` (donated
    with the rest of the carry) and per-event xs — ``ts``/``fin`` (E, A)
    f32 per-lane event / raw-completion clocks, ``ks`` (E, A) i32 per-lane
    event indices (merged rows carry each member event's own clock and
    index), ``copies`` (E,) i32.  The state trajectory is bit-identical
    to the non-telemetry block's; padded no-op rows skip the metrics
    update along with the state update (same ``lax.cond``).
    """
    grad_fn = jax.grad(loss_fn)

    if not telemetry:
        @functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3))
        def block(W, S, y, ptr, pools, workers_seq, P_sub_seq, grad_masks,
                  restart_masks, etas):
            return sparse_gossip_scan(
                W, S, y, ptr, pools, grad_fn, workers_seq, P_sub_seq,
                grad_masks, restart_masks, etas, use_kernel=use_kernel)

        return block

    @functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4))
    def block_tel(W, S, y, ptr, M, pools, workers_seq, P_sub_seq,
                  grad_masks, restart_masks, etas, ts, fin, ks, copies):
        if etas.ndim == 1:
            etas_seq = jnp.broadcast_to(etas[:, None], grad_masks.shape)
        else:
            etas_seq = etas

        def body(carry, ev):
            workers, P_sub, gm, rm, eta, t, f, k, cp = ev

            def step(c):
                W, S, y, ptr, M = c
                W, S, y, ptr = sparse_event_update(
                    W, S, y, ptr, pools, grad_fn, workers, P_sub, gm, rm,
                    eta, use_kernel=use_kernel)
                with jax.named_scope("metrics_update"):
                    M = sparse_metrics_update(M, workers, P_sub, gm, rm,
                                              t, f, k, cp)
                return W, S, y, ptr, M

            return jax.lax.cond(workers[0] >= 0, step, lambda c: c,
                                carry), None

        carry, _ = jax.lax.scan(
            body, (W, S, y, ptr, M),
            (workers_seq, P_sub_seq, grad_masks, restart_masks, etas_seq,
             ts, fin, ks, copies))
        return carry

    return block_tel
