"""Pathsearch (paper Algorithm 3): decentralized strongly-connected-graph search.

Across an *epoch*, workers opportunistically commit edges into a shared edge
set ``P`` (with vertex set ``V``) until the accumulated graph G' = (V, P) is
strongly connected with V = N; then both sets reset and a new epoch begins.
Within an epoch, one *iteration* ends whenever at least one new edge is
committed; every worker that has finished its local gradient by that moment
participates in the iteration's gossip-average with its finished neighbors.

Implementation note (documented deviation): the paper commits an edge (i, j)
when "(i,j) ∈ E ∖ P and (i ∉ V or j ∉ V)".  Taken literally this only ever
grows single-node-attached trees and can deadlock when two partial components
of V need to merge (no edge between them has an endpoint outside V).  We use
the equivalent-intent condition *"the edge connects two distinct components of
G' (unseen nodes count as their own component)"* — i.e. G' is grown as a
spanning forest until it becomes a single spanning tree.  This preserves the
paper's guarantees: epochs still terminate after at most N−1 committed edges
(the bound B ≤ N−1 used in Remark 4 and the staleness bound), and G' is
strongly connected with V = N at epoch end.
"""
from __future__ import annotations

import dataclasses
from typing import List, Set, Tuple

import numpy as np

from repro.core.topology import Graph

Edge = Tuple[int, int]


class _UnionFind:
    def __init__(self, n: int):
        self.parent = list(range(n))
        self.rank = [0] * n

    def find(self, x: int) -> int:
        while self.parent[x] != x:
            self.parent[x] = self.parent[self.parent[x]]
            x = self.parent[x]
        return x

    def union(self, a: int, b: int) -> bool:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return False
        if self.rank[ra] < self.rank[rb]:
            ra, rb = rb, ra
        self.parent[rb] = ra
        if self.rank[ra] == self.rank[rb]:
            self.rank[ra] += 1
        return True


@dataclasses.dataclass
class PathSearchState:
    """Consensus sets (P, V) of the current epoch, shared by all workers.

    In a real deployment every worker holds a local copy kept in sync by ID
    gossip (paper Remark 4: O(2NB) IDs, negligible next to parameter traffic).
    The simulator keeps the consensus copy directly.
    """
    graph: Graph
    committed: Set[Edge] = dataclasses.field(default_factory=set)   # P
    vertices: Set[int] = dataclasses.field(default_factory=set)     # V
    epochs_completed: int = 0

    def __post_init__(self):
        self._uf = _UnionFind(self.graph.n)
        self._nbr_cache = None
        # component root per worker, mirroring _UnionFind.find: updated on
        # every successful union (rare — ≤ n−1 per epoch), so the hot
        # membership tests vectorize over it instead of chasing parents
        self._roots = np.arange(self.graph.n, dtype=np.int32)

    # ------------------------------------------------------------------
    def novel_edges(self, finished: Set[int]) -> List[Edge]:
        """Committable edges among currently-finished workers.

        An edge is committable iff it is a graph edge between two distinct
        components of G' (see module docstring).  The candidate pairs come
        from one vectorized adjacency-submatrix scan (this runs on *every*
        worker finish, and the finished set grows large between AAU events
        at paper scale — the scalar double loop it replaces was the
        event-generation ceiling for DSGD-AAU); the returned order is the
        double loop's row-major upper-triangular order, which ``commit``
        depends on for deterministic union-find evolution.
        """
        fin = sorted(finished)
        if len(fin) < 2:
            return []
        widx = np.asarray(fin, dtype=np.intp)
        sub = np.triu(self.graph.adj[np.ix_(widx, widx)], k=1)
        ai, bi = np.nonzero(sub)
        if not ai.size:
            return []
        roots = self._roots[widx]
        return [(fin[a], fin[b]) for a, b in zip(ai.tolist(), bi.tolist())
                if roots[a] != roots[b]]

    def novel_edges_incident(self, i: int, finished) -> List[Edge]:
        """Committable graph edges between the just-finished ``i`` and the
        rest of the finished set — the incremental form of
        :meth:`novel_edges`.  Between commits the component partition is
        frozen, so scanning only the newly finished worker's neighborhood
        accumulates, finish by finish, exactly the edge *set* a full
        :meth:`novel_edges` scan would return at event time (the list order
        differs, but :meth:`commit` yields the same components and vertex
        set for any order of the same edge set — only which spanning-tree
        edges get recorded in ``committed`` varies).  O(deg) per finish,
        which is what keeps DSGD-AAU event generation flat in n.

        ``finished`` is either a set of worker ids or an (n,) bool mask —
        the mask form lets the whole neighborhood filter vectorize.
        """
        nb = self.graph.neighbor_lists[i]
        if isinstance(finished, np.ndarray):
            sel = nb[finished[nb] & (self._roots[nb] != self._roots[i])]
            return [(i, j) if i < j else (j, i) for j in sel.tolist()]
        if self._nbr_cache is None:
            # plain-int view of the graph's cached neighbor arrays (python
            # ints hash/compare faster in the set-membership test below)
            self._nbr_cache = [a.tolist() for a in self.graph.neighbor_lists]
        ri = int(self._roots[i])
        roots = self._roots
        out: List[Edge] = []
        for j in self._nbr_cache[i]:
            if j in finished and roots[j] != ri:
                out.append((i, j) if i < j else (j, i))
        return out

    def commit(self, edges: List[Edge]) -> None:
        for i, j in edges:
            ra, rb = self._uf.find(i), self._uf.find(j)
            if ra != rb:
                self._uf.union(i, j)
                # mirror the merge into the flat roots array: one of ra/rb
                # survived as the combined component's root
                rn = self._uf.find(i)
                ro = rb if rn == ra else ra
                self._roots[self._roots == ro] = rn
                self.committed.add((min(i, j), max(i, j)))
                self.vertices.update((i, j))

    def epoch_complete(self) -> bool:
        """G' = (V, P) strongly connected with V = N?"""
        if len(self.vertices) != self.graph.n:
            return False
        root = self._uf.find(0)
        return all(self._uf.find(i) == root for i in range(self.graph.n))

    def reset_epoch(self) -> None:
        self.committed.clear()
        self.vertices.clear()
        self._uf = _UnionFind(self.graph.n)
        self._roots[:] = np.arange(self.graph.n, dtype=np.int32)
        self.epochs_completed += 1

    # -- diagnostics ----------------------------------------------------
    @property
    def num_components(self) -> int:
        return len({self._uf.find(i) for i in range(self.graph.n)})
