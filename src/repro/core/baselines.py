"""Baseline schedulers the paper compares against (§6): AD-PSGD, Prague, AGP.

Each baseline is expressed as a scheduler emitting the same ``ScheduleEvent``
stream as DSGD-AAU, so the *identical* JAX update (core/aau.py) runs all
algorithms — only the (N(k), P(k)) sequence differs.  This mirrors the paper's
framing where every algorithm is an instance of eq. (5) with a different
consensus-matrix process.

Events are sparse-native (see core/scheduler.py): a single-edge event is two
int32 lanes plus a 2×2 submatrix, never an (n, n) matrix, which keeps event
*generation* O(1) per event — the host-side heap loop used to be the
consumer's ceiling at paper scale.  Per-scheduler ``edge_bound`` /
``active_bound`` overrides keep the packed arrays at their true width
(AD-PSGD/AGP touch one edge per event, Prague at most one group's clique)
instead of the full graph's.  Because every baseline's events all share one
size, the bucketed lane-width contract (``Scheduler.active_buckets``)
stays at its degenerate single-bucket default — ``(2,)`` for the
single-edge pair, ``(group_size,)`` for Prague — and the runner's sparse
dispatch is byte-for-byte the single-program path it always was; only
DSGD-AAU, whose finished-clique size is a distribution, carries a
multi-rung ladder.

Event-horizon batching: the single-edge schedulers accept ``horizon=K`` to
pre-draw K future completion-time factors and K neighbor picks in two
vectorized RNG calls, replacing the per-event ``heapq`` push/pop with an
argmin over a numpy reorder buffer of per-worker next-completion times.
The horizon stream is fully deterministic and distributionally identical,
but consumes the RNG streams in a different order than the per-event path
(vector draws cannot interleave with numpy's scalar ziggurat draws), so it
is a *different* realization: leave ``horizon=None`` (the default) wherever
bit-exact reproduction of recorded runs matters.
"""
from __future__ import annotations

import heapq
from typing import Dict, Iterator, List, Optional, Set, Tuple

import numpy as np

from repro.core.scheduler import (_EMPTY_EDGES, CliquePackedStream,
                                  PackedEventStream, Scheduler, ScheduleEvent,
                                  SparseEventBatch)
from repro.scenarios.base import TimeModelSpec
from repro.core.topology import Graph


def _frozen(a: np.ndarray) -> np.ndarray:
    """Shared per-class event payloads: mark read-only so aliasing is safe."""
    a.flags.writeable = False
    return a


# Pairwise-averaging submatrix (AD-PSGD) and per-lane masks for a sorted
# worker pair (a, b): shared across every event of every scheduler instance.
_P_PAIR_AVG = _frozen(np.full((2, 2), 0.5))
_P_SELF = _frozen(np.ones((1, 1)))
# Push-sum split: the *sender's row* keeps half and pushes half (AGP).
_P_PUSH_FIRST = _frozen(np.array([[0.5, 0.5], [0.0, 1.0]]))
_P_PUSH_SECOND = _frozen(np.array([[1.0, 0.0], [0.5, 0.5]]))
_LANE_FIRST = _frozen(np.array([True, False]))
_LANE_SECOND = _frozen(np.array([False, True]))
_LANE_SELF = _frozen(np.ones(1, dtype=bool))


class _PairPackedStream(PackedEventStream):
    """Array-native exact pair stream (AD-PSGD/AGP fast generation path).

    Replays :meth:`_SingleEdgeScheduler._events_exact` — same heap, same
    per-event RNG consumption order (neighbor pick then next completion
    draw), same lock arithmetic — but writes each event straight into the
    chunk's :class:`SparseEventBatch` arrays: no ``ScheduleEvent`` object,
    no payload tuple, no ``from_events`` re-scatter.  Bit-identical chunks
    to ``packed_stream(native=False)``, pinned by
    tests/test_fused_stream.py.
    """

    def __init__(self, scheduler: "_SingleEdgeScheduler"):
        self.scheduler = scheduler
        self.buckets = scheduler.active_buckets()      # always (2,)
        self._ebound = scheduler.edge_bound()          # always 1
        self._k = 0
        self._lock_free_at = 0.0
        heap: List[Tuple[float, int]] = []
        for i, dt in enumerate(
                scheduler.sampler.sample_batch(np.arange(scheduler.n))):
            heapq.heappush(heap, (dt, i))
        self._heap = heap
        # shared pair payloads, pre-cast once to the packed dtypes
        _, P1, l1, copies = scheduler._pair_payload(0, 1)
        _, P2, l2, _ = scheduler._pair_payload(1, 0)
        self._P1 = np.ascontiguousarray(P1, dtype=np.float32)
        self._P2 = np.ascontiguousarray(P2, dtype=np.float32)
        self._l1 = np.asarray(l1, dtype=bool)
        self._l2 = np.asarray(l2, dtype=bool)
        self._copies = int(copies)

    def next_chunk(self, k: int):
        sched = self.scheduler
        sampler = sched.sampler
        rng = sched._rng
        nbrs_list = sched._nbrs
        lock_dt = sched.lock_time
        heap = self._heap
        push, pop = heapq.heappush, heapq.heappop
        lock_free_at = self._lock_free_at
        P1, P2, l1, l2 = self._P1, self._P2, self._l1, self._l2
        copies_pair = self._copies
        a = CliquePackedStream._alloc(k, 2, self._ebound)
        workers, P_sub = a["workers"], a["P_sub"]
        gm, rm = a["grad_workers"], a["restart_workers"]
        edges, n_edges = a["edges"], a["n_edges"]
        times, n_workers = a["times"], a["n_workers"]
        copies = a["param_copies_sent"]
        finish = a["finish"]
        for j in range(k):
            t, i = pop(heap)
            t_raw = t                  # raw completion, before any lock wait
            nbrs = nbrs_list[i]
            m = len(nbrs)
            if m:
                if lock_dt:
                    t = (t if t > lock_free_at else lock_free_at) + lock_dt
                    lock_free_at = t
                r = int(nbrs[rng.integers(0, m)])
                # the finisher's lane carries its raw completion clock; the
                # passive partner (rm=False) reads the event clock
                finish[j] = t
                finish[j, 0 if i < r else 1] = t_raw
                if i < r:
                    workers[j, 0] = i
                    workers[j, 1] = r
                    P_sub[j] = P1
                    gm[j] = l1
                    rm[j] = l1
                    edges[j, 0, 0] = i
                    edges[j, 0, 1] = r
                else:
                    workers[j, 0] = r
                    workers[j, 1] = i
                    P_sub[j] = P2
                    gm[j] = l2
                    rm[j] = l2
                    edges[j, 0, 0] = r
                    edges[j, 0, 1] = i
                n_workers[j] = 2
                n_edges[j] = 1
                copies[j] = copies_pair
            else:
                workers[j, 0] = i
                n_workers[j] = 1
                P_sub[j, 0, 0] = 1.0
                gm[j, 0] = True
                rm[j, 0] = True
                finish[j] = t          # no lock: fires at its own completion
            times[j] = t
            push(heap, (t + sampler.sample(i), i))
        self._lock_free_at = lock_free_at
        batch = SparseEventBatch(k0=self._k, **a)
        self._k += k
        return batch


class _SingleEdgeScheduler(Scheduler):
    """Shared machinery for the one-edge-per-event baselines (AD-PSGD, AGP).

    Subclasses define the pair event via ``_pair_payload`` and whether an
    event serializes on the atomic-averaging lock (``lock_time`` > 0).
    """

    lock_time = 0.0

    #: Sampler surface (checked by repro.check's rng-order rule): the only
    #: methods allowed to draw from ``self._rng``.  The draw order *is* the
    #: pinned event stream — a draw anywhere else forks it silently.
    #: ``_PairPackedStream.next_chunk`` draws via ``sched._rng`` on the
    #: scheduler's behalf as the vectorized replay of ``_events_exact``.
    rng_methods = ("_events_exact", "_events_horizon", "fused_draws")

    def __init__(self, graph: Graph, straggler: TimeModelSpec, seed: int,
                 horizon: Optional[int] = None):
        super().__init__(graph, straggler)
        self._rng = np.random.default_rng(seed)
        if horizon is not None and horizon < 1:
            raise ValueError("horizon must be a positive chunk size or None")
        self.horizon = horizon
        self._nbrs = graph.neighbor_lists

    def edge_bound(self) -> int:
        return 1  # one pairwise exchange per event

    def active_bound(self) -> int:
        return 2  # the finisher and its chosen neighbor

    # -- subclass hooks ----------------------------------------------------
    def _pair_payload(self, i: int, r: int):
        """(workers, P_sub, grad_lanes, copies) for finisher i and pick r."""
        raise NotImplementedError

    def _pair_event(self, k: int, t: float, i: int, r: int,
                    t_raw: Optional[float] = None) -> ScheduleEvent:
        workers, P_sub, lanes, copies = self._pair_payload(i, r)
        a = int(workers[0])
        b = int(workers[1])
        # the finisher's lane carries its raw (pre-lock) completion clock;
        # the passive partner's lane reads the event clock (its restart mask
        # is False — telemetry never splits busy/idle on it)
        fin = np.full(2, t)
        fin[0 if i < r else 1] = t if t_raw is None else t_raw
        return ScheduleEvent(
            k=k, time=t, n=self.n, workers=workers, P_sub=P_sub,
            grad_lanes=lanes, restart_lanes=lanes,
            edges=np.array(((a, b),), dtype=np.int32),
            param_copies_sent=copies, finish_lanes=fin,
        )

    def _isolated_event(self, k: int, t: float, i: int) -> ScheduleEvent:
        """A worker with no graph neighbors: purely local gradient step."""
        return ScheduleEvent(
            k=k, time=t, n=self.n,
            workers=np.array((i,), dtype=np.int32), P_sub=_P_SELF,
            grad_lanes=_LANE_SELF, restart_lanes=_LANE_SELF,
            edges=_EMPTY_EDGES, param_copies_sent=0,
        )

    def _needs_sorted_emission(self) -> bool:
        """Lock-shifted and lock-free event times can interleave out of
        order only when the lock exists *and* some worker skips it (no
        neighbors): isolated workers fire at raw completion times while
        locked events fire at the (later) serialized lock times.  Consumers
        bound runs by ``event.time > max_time``, so the stream must stay
        time-sorted — those graphs route events through a small reorder
        heap.  Connected graphs (and lock-free schedulers like AGP) are
        already monotone and skip the buffer entirely.
        """
        return bool(self.lock_time) and any(
            len(nb) == 0 for nb in self._nbrs)

    # -- event generation --------------------------------------------------
    def events(self) -> Iterator[ScheduleEvent]:
        if self.horizon:
            return self._events_horizon(self.horizon)
        return self._events_exact()

    def _native_packed_stream(self) -> Optional[PackedEventStream]:
        # The native stream replays the *exact* per-event RNG order, so a
        # horizon-batched scheduler (different draw order by construction)
        # and the reorder-buffered mixed lock/no-lock graphs keep the
        # object-path adapter.
        if self.horizon or self._needs_sorted_emission():
            return None
        return _PairPackedStream(self)

    # -- fused pure-JAX generation (core/fused.py) -------------------------
    def fused_supported(self) -> bool:
        """Whether the on-device fused generator can replay this stream.

        Requires per-worker completion-time factors that are iid draws
        (``TimeModel.iid_horizon``): the fused scan pre-draws a flat factor
        stream and assigns factors to workers *by event order decided on
        device*, which is only distribution-preserving when the factor law
        doesn't depend on which worker consumes it.  Scenario samplers with
        worker- or history-dependent factors (diurnal) are excluded.
        """
        return bool(getattr(self.sampler, "iid_horizon", False))

    def fused_spec(self) -> Dict[str, object]:
        """Static device constants for the fused generator's scan body."""
        n = self.n
        deg = np.fromiter((len(nb) for nb in self._nbrs),
                          dtype=np.int32, count=n)
        width = max(1, int(deg.max(initial=1)))
        nbr_table = np.zeros((n, width), dtype=np.int32)
        for i, nb in enumerate(self._nbrs):
            if len(nb):
                nbr_table[i, :len(nb)] = nb
        _, P1, l1, copies = self._pair_payload(0, 1)
        _, P2, l2, _ = self._pair_payload(1, 0)
        return dict(
            n=n, deg=deg, nbr_table=nbr_table,
            base=np.asarray(self.sampler.base, dtype=np.float32),
            lock_dt=float(self.lock_time),
            P_first=np.asarray(P1, dtype=np.float32),
            P_second=np.asarray(P2, dtype=np.float32),
            lane_first=np.asarray(l1, dtype=bool),
            lane_second=np.asarray(l2, dtype=bool),
            copies_pair=int(copies),
        )

    def fused_draws(self, E: int) -> Tuple[np.ndarray, np.ndarray]:
        """Host RNG for one fused block: ``(factors, picks)``, both (E,) f32.

        One ``sample_horizon`` + one uniform vector call per block — the
        horizon batcher's draw order, so the fused stream is a
        different-but-deterministic realization exactly like ``horizon=K``
        (see the module docstring); determinism per (seed, block size) is
        pinned by tests/test_fused_stream.py.
        """
        factors = np.asarray(self.sampler.sample_horizon(E), dtype=np.float32)
        picks = self._rng.random(E).astype(np.float32)
        return factors, picks

    def fused_initial_times(self) -> np.ndarray:
        """(n,) f32 first completion times (same draw as the heap init)."""
        return np.asarray(self.sampler.sample_batch(np.arange(self.n)),
                          dtype=np.float32)

    def _events_exact(self) -> Iterator[ScheduleEvent]:
        """The canonical stream: RNG draws happen per event, in event order,
        so recorded runs replay bit-exactly across refactors."""
        n = self.n
        sampler = self.sampler
        rng = self._rng
        nbrs_list = self._nbrs
        lock_dt = self.lock_time
        heap: List[Tuple[float, int]] = []
        for i, dt in enumerate(sampler.sample_batch(np.arange(n))):
            heapq.heappush(heap, (dt, i))
        push, pop = heapq.heappush, heapq.heappop
        # Reorder heap for time-sorted emission (only engaged on graphs that
        # mix locked and lock-free events — see _needs_sorted_emission): an
        # event computed at heap-pop time t can be emitted once the pop
        # clock reaches its (possibly lock-shifted) time, because every
        # later-computed event's time is >= the pop clock.
        out: Optional[List[Tuple[float, int, ScheduleEvent]]] = (
            [] if self._needs_sorted_emission() else None)
        seq = 0
        k = 0
        lock_free_at = 0.0
        while True:
            t, i = pop(heap)
            t_raw = t
            if out is not None:
                while out and out[0][0] <= t:
                    ev = heapq.heappop(out)[2]
                    ev.k = k
                    k += 1
                    yield ev
            nbrs = nbrs_list[i]
            m = len(nbrs)
            if m:
                if lock_dt:
                    # serialized atomic averaging: wait for the lock
                    t = (t if t > lock_free_at else lock_free_at) + lock_dt
                    lock_free_at = t
                r = int(nbrs[rng.integers(0, m)])
                ev = self._pair_event(k, t, i, r, t_raw=t_raw)
            else:
                # an isolated worker averages with nobody: no neighbor draw,
                # no lock acquisition, no copies moved — its gradient lands
                # at its own completion time
                ev = self._isolated_event(k, t, i)
            if out is None:
                k += 1
                yield ev
            else:
                heapq.heappush(out, (float(t), seq, ev))
                seq += 1
            push(heap, (t + sampler.sample(i), i))

    def _events_horizon(self, K: int) -> Iterator[ScheduleEvent]:
        """Event-horizon batching: K events' RNG ahead of time, argmin pops.

        Draws K completion-time factors (one lognormal + one uniform vector
        call, ``TimeSampler.sample_horizon``) and K neighbor picks (one
        uniform vector call) per chunk, and replaces the heap with a (n,)
        numpy reorder buffer of next-completion times — per-event work is
        one ``argmin`` plus array stores.  Deterministic, but a different
        RNG-stream order than :meth:`_events_exact` (see module docstring).
        """
        n = self.n
        sampler = self.sampler
        base = sampler.base
        nbrs_list = self._nbrs
        lock_dt = self.lock_time
        times = np.asarray(sampler.sample_batch(np.arange(n)), dtype=np.float64)
        out: Optional[List[Tuple[float, int, ScheduleEvent]]] = (
            [] if self._needs_sorted_emission() else None)
        seq = 0
        k = 0
        lock_free_at = 0.0
        while True:
            factors = sampler.sample_horizon(K)
            picks = self._rng.random(K)
            for j in range(K):
                i = int(times.argmin())
                t = float(times[i])
                t_raw = t
                if out is not None:
                    while out and out[0][0] <= t:
                        ev = heapq.heappop(out)[2]
                        ev.k = k
                        k += 1
                        yield ev
                nbrs = nbrs_list[i]
                m = len(nbrs)
                if m:
                    if lock_dt:
                        t = (t if t > lock_free_at else lock_free_at) + lock_dt
                        lock_free_at = t
                    r = int(nbrs[int(picks[j] * m)])
                    ev = self._pair_event(k, t, i, r, t_raw=t_raw)
                else:
                    ev = self._isolated_event(k, t, i)
                if out is None:
                    k += 1
                    yield ev
                else:
                    heapq.heappush(out, (t, seq, ev))
                    seq += 1
                times[i] = t + base[i] * factors[j]


class ADPSGDScheduler(_SingleEdgeScheduler):
    """AD-PSGD [Lian et al. 2018].

    A worker that finishes its gradient immediately averages pairwise with one
    uniformly-random graph-neighbor and restarts; the neighbor is *not*
    interrupted — its in-flight gradient will later be applied to the averaged
    parameters (staleness).  Atomic-update requirement (paper §3 / Prague's
    motivation): conflicting concurrent averagings must serialize, so each
    average occupies the "update lock" for ``avg_time`` virtual seconds and
    queued workers wait — the throughput ceiling that makes AD-PSGD stop
    scaling with N.  Workers with no neighbors never average, so they skip
    the lock entirely and send nothing.  P(k) is doubly stochastic: identity
    except a 2×2 block of 1/2.
    """

    name = "ad_psgd"

    def __init__(self, graph: Graph, straggler: TimeModelSpec, seed: int = 1,
                 avg_time: float = 0.05, horizon: Optional[int] = None):
        super().__init__(graph, straggler, seed=seed, horizon=horizon)
        self.avg_time = avg_time * straggler.base_time
        self.lock_time = self.avg_time

    def _pair_payload(self, i: int, r: int):
        if i < r:
            return (np.array((i, r), dtype=np.int32), _P_PAIR_AVG,
                    _LANE_FIRST, 2)
        return (np.array((r, i), dtype=np.int32), _P_PAIR_AVG,
                _LANE_SECOND, 2)


class PragueScheduler(Scheduler):
    """Prague [Luo et al. 2020]: partial all-reduce over randomized groups.

    A Group Generator assigns each finishing worker to a random group of size
    ``group_size``; the group's partial all-reduce fires once *all* members
    have finished their current local computation, then members restart.
    Groups are logical (not topology-constrained), as in the paper.  Because
    membership is random, stragglers still land in groups and stall their
    groupmates — the effect DSGD-AAU avoids.
    """

    name = "prague"

    #: rng-order sampler surface: group membership is the only draw.
    rng_methods = ("_group_tuples",)

    def __init__(self, graph: Graph, straggler: TimeModelSpec,
                 group_size: int = 4, seed: int = 2):
        super().__init__(graph, straggler)
        self.group_size = max(2, min(group_size, graph.n))
        self._rng = np.random.default_rng(seed)

    def edge_bound(self) -> int:
        g = self.group_size
        return g * (g - 1) // 2  # one group clique per event

    def active_bound(self) -> int:
        return self.group_size  # one group's members per event

    def _group_tuples(self) -> Iterator[tuple]:
        """The Prague event process as packed-ready clique tuples.

        Yields ``(t, workers, P_sub, edges, copies, finish)`` per group
        all-reduce — ``finish`` the members' raw completion clocks (the
        group fires when its *last* member finishes; earlier members waited
        since their own) — the single source of truth consumed both by
        :meth:`events` (object wrapper) and by the array-native
        :class:`CliquePackedStream`.
        """
        n = self.n
        heap: List[Tuple[float, int]] = []
        for i, dt in enumerate(self.sampler.sample_batch(np.arange(n))):
            heapq.heappush(heap, (dt, i))
        finish_at = np.zeros(n, dtype=np.float64)
        in_group: Dict[int, int] = {}          # worker -> group id
        groups: Dict[int, Set[int]] = {}       # group id -> members
        ready: Dict[int, Set[int]] = {}        # group id -> members finished
        next_gid = 0
        while True:
            t, i = heapq.heappop(heap)
            finish_at[i] = t
            if i not in in_group:
                # Group Generator: form a fresh group around i from workers
                # not currently claimed by a pending group.
                free = [w for w in range(n) if w != i and w not in in_group]
                size = min(self.group_size - 1, len(free))
                members = {i} | set(
                    int(x) for x in self._rng.choice(free, size=size, replace=False)
                ) if size > 0 else {i}
                gid = next_gid
                next_gid += 1
                groups[gid] = members
                ready[gid] = set()
                for m in members:
                    in_group[m] = gid
            gid = in_group[i]
            ready[gid].add(i)
            if ready[gid] != groups[gid]:
                continue  # group still waiting on a member (possibly a straggler)
            members = sorted(groups[gid])
            g = len(members)
            widx = np.asarray(members, dtype=np.int32)
            # the group's partial all-reduce: a g×g block of 1/g, identity
            # outside — built at its true size, never as an (n, n) matrix
            iu, ju = np.triu_indices(g, k=1)
            yield (t, widx, np.full((g, g), 1.0 / g),
                   np.stack([widx[iu], widx[ju]], axis=1) if g > 1
                   else _EMPTY_EDGES,
                   # ring partial all-reduce: 2·(g−1)/g vector-copies per member
                   2 * (g - 1), finish_at[widx].copy())
            for m, dt in zip(members, self.sampler.sample_batch(members)):
                del in_group[m]
                heapq.heappush(heap, (t + dt, m))
            del groups[gid], ready[gid]

    def events(self) -> Iterator[ScheduleEvent]:
        n = self.n
        for k, (t, widx, P_sub, edges, copies, fin) in \
                enumerate(self._group_tuples()):
            lanes = np.ones(len(widx), dtype=bool)
            yield ScheduleEvent(
                k=k, time=t, n=n, workers=widx, P_sub=P_sub,
                grad_lanes=lanes, restart_lanes=lanes,
                edges=edges, param_copies_sent=copies,
                finish_lanes=fin,
            )

    def _native_packed_stream(self) -> Optional[PackedEventStream]:
        return CliquePackedStream(self, self._group_tuples())


class AGPScheduler(_SingleEdgeScheduler):
    """Asynchronous Gradient Push [Assran & Rabbat 2020].

    Push-sum on a directed view of the graph: a finishing worker applies its
    gradient, keeps half of its (parameter, weight) mass and pushes the other
    half to one random out-neighbor.  In the paper's W·P(k) orientation
    (out_j = Σ_i P_ij·W_i) the push matrix is *row*-stochastic only (each
    sender's row distributes its mass), i.e. the transpose of the
    column-stochastic matrix in AGP's x ← A·x notation; the runner de-biases
    estimates with the push-sum weight vector y(k) = y(k−1)·P(k).
    """

    name = "agp"

    def __init__(self, graph: Graph, straggler: TimeModelSpec, seed: int = 3,
                 horizon: Optional[int] = None):
        super().__init__(graph, straggler, seed=seed, horizon=horizon)

    def _pair_payload(self, i: int, r: int):
        # sender i's ROW splits its mass between i and r; one directed push
        if i < r:
            return (np.array((i, r), dtype=np.int32), _P_PUSH_FIRST,
                    _LANE_FIRST, 1)
        return (np.array((r, i), dtype=np.int32), _P_PUSH_SECOND,
                _LANE_SECOND, 1)


def make_scheduler(name: str, graph: Graph, straggler: TimeModelSpec, **kw) -> Scheduler:
    from repro.core.scheduler import AAUScheduler, SyncScheduler
    table = {
        "dsgd_aau": AAUScheduler,
        "dsgd_sync": SyncScheduler,
        "ad_psgd": ADPSGDScheduler,
        "prague": PragueScheduler,
        "agp": AGPScheduler,
    }
    if name not in table:
        raise KeyError(f"unknown algorithm {name!r}; have {sorted(table)}")
    return table[name](graph, straggler, **kw)
