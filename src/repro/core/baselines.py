"""Baseline schedulers the paper compares against (§6): AD-PSGD, Prague, AGP.

Each baseline is expressed as a scheduler emitting the same ``ScheduleEvent``
stream as DSGD-AAU, so the *identical* JAX update (core/aau.py) runs all
algorithms — only the (N(k), P(k)) sequence differs.  This mirrors the paper's
framing where every algorithm is an instance of eq. (5) with a different
consensus-matrix process.

The compiled scan path packs these streams into EventBatches like any
other scheduler's; per-scheduler ``edge_bound`` overrides keep the
EventBatch compact-edge arrays at their true width (AD-PSGD/AGP touch one
edge per event, Prague at most one group's clique) instead of the full
graph's.
"""
from __future__ import annotations

import heapq
from typing import Dict, Iterator, List, Set, Tuple

import numpy as np

from repro.core.scheduler import Scheduler, ScheduleEvent
from repro.core.straggler import StragglerModel
from repro.core.topology import Graph


class ADPSGDScheduler(Scheduler):
    """AD-PSGD [Lian et al. 2018].

    A worker that finishes its gradient immediately averages pairwise with one
    uniformly-random graph-neighbor and restarts; the neighbor is *not*
    interrupted — its in-flight gradient will later be applied to the averaged
    parameters (staleness).  Atomic-update requirement (paper §3 / Prague's
    motivation): conflicting concurrent averagings must serialize, so each
    average occupies the "update lock" for ``avg_time`` virtual seconds and
    queued workers wait — the throughput ceiling that makes AD-PSGD stop
    scaling with N.  P(k) is doubly stochastic: identity except a 2×2 block
    of 1/2.
    """

    name = "ad_psgd"

    def __init__(self, graph: Graph, straggler: StragglerModel, seed: int = 1,
                 avg_time: float = 0.05):
        super().__init__(graph, straggler)
        self._rng = np.random.default_rng(seed)
        self.avg_time = avg_time * straggler.base_time

    def edge_bound(self) -> int:
        return 1  # one pairwise averaging per event

    def active_bound(self) -> int:
        return 2  # the finisher and its chosen neighbor

    def events(self) -> Iterator[ScheduleEvent]:
        n = self.n
        heap: List[Tuple[float, int]] = []
        for i, dt in enumerate(self.sampler.sample_batch(np.arange(n))):
            heapq.heappush(heap, (dt, i))
        k = 0
        lock_free_at = 0.0
        while True:
            t, i = heapq.heappop(heap)
            t = max(t, lock_free_at) + self.avg_time   # serialized averaging
            lock_free_at = t
            nbrs = self.graph.neighbors(i)
            P = np.eye(n)
            edges: Tuple[Tuple[int, int], ...] = ()
            copies = 0
            if len(nbrs):
                r = int(self._rng.choice(nbrs))
                P[i, i] = P[r, r] = 0.5
                P[i, r] = P[r, i] = 0.5
                edges = ((min(i, r), max(i, r)),)
                copies = 2
            yield ScheduleEvent(
                k=k, time=t,
                grad_workers=self._mask([i]),
                restart_workers=self._mask([i]),  # neighbor keeps its stale snapshot
                P=P, active_edges=edges, param_copies_sent=copies,
            )
            k += 1
            heapq.heappush(heap, (t + self.sampler.sample(i), i))


class PragueScheduler(Scheduler):
    """Prague [Luo et al. 2020]: partial all-reduce over randomized groups.

    A Group Generator assigns each finishing worker to a random group of size
    ``group_size``; the group's partial all-reduce fires once *all* members
    have finished their current local computation, then members restart.
    Groups are logical (not topology-constrained), as in the paper.  Because
    membership is random, stragglers still land in groups and stall their
    groupmates — the effect DSGD-AAU avoids.
    """

    name = "prague"

    def __init__(self, graph: Graph, straggler: StragglerModel,
                 group_size: int = 4, seed: int = 2):
        super().__init__(graph, straggler)
        self.group_size = max(2, min(group_size, graph.n))
        self._rng = np.random.default_rng(seed)

    def edge_bound(self) -> int:
        g = self.group_size
        return g * (g - 1) // 2  # one group clique per event

    def active_bound(self) -> int:
        return self.group_size  # one group's members per event

    def events(self) -> Iterator[ScheduleEvent]:
        n = self.n
        heap: List[Tuple[float, int]] = []
        for i, dt in enumerate(self.sampler.sample_batch(np.arange(n))):
            heapq.heappush(heap, (dt, i))
        in_group: Dict[int, int] = {}          # worker -> group id
        groups: Dict[int, Set[int]] = {}       # group id -> members
        ready: Dict[int, Set[int]] = {}        # group id -> members finished
        next_gid = 0
        k = 0
        while True:
            t, i = heapq.heappop(heap)
            if i not in in_group:
                # Group Generator: form a fresh group around i from workers
                # not currently claimed by a pending group.
                free = [w for w in range(n) if w != i and w not in in_group]
                size = min(self.group_size - 1, len(free))
                members = {i} | set(
                    int(x) for x in self._rng.choice(free, size=size, replace=False)
                ) if size > 0 else {i}
                gid = next_gid
                next_gid += 1
                groups[gid] = members
                ready[gid] = set()
                for m in members:
                    in_group[m] = gid
            gid = in_group[i]
            ready[gid].add(i)
            if ready[gid] != groups[gid]:
                continue  # group still waiting on a member (possibly a straggler)
            members = sorted(groups[gid])
            g = len(members)
            P = np.eye(n)
            for a in members:
                for b in members:
                    P[a, b] = 1.0 / g
            edges = tuple(
                (members[x], members[y]) for x in range(g) for y in range(x + 1, g)
            )
            mask = self._mask(members)
            yield ScheduleEvent(
                k=k, time=t, grad_workers=mask, restart_workers=mask, P=P,
                active_edges=edges,
                # ring partial all-reduce: 2·(g−1)/g vector-copies per member
                param_copies_sent=2 * (g - 1),
            )
            k += 1
            for m, dt in zip(members, self.sampler.sample_batch(members)):
                del in_group[m]
                heapq.heappush(heap, (t + dt, m))
            del groups[gid], ready[gid]


class AGPScheduler(Scheduler):
    """Asynchronous Gradient Push [Assran & Rabbat 2020].

    Push-sum on a directed view of the graph: a finishing worker applies its
    gradient, keeps half of its (parameter, weight) mass and pushes the other
    half to one random out-neighbor.  In the paper's W·P(k) orientation
    (out_j = Σ_i P_ij·W_i) the push matrix is *row*-stochastic only (each
    sender's row distributes its mass), i.e. the transpose of the
    column-stochastic matrix in AGP's x ← A·x notation; the runner de-biases
    estimates with the push-sum weight vector y(k) = y(k−1)·P(k).
    """

    name = "agp"

    def __init__(self, graph: Graph, straggler: StragglerModel, seed: int = 3):
        super().__init__(graph, straggler)
        self._rng = np.random.default_rng(seed)

    def edge_bound(self) -> int:
        return 1  # one directed push per event

    def active_bound(self) -> int:
        return 2  # the pusher and its chosen out-neighbor

    def events(self) -> Iterator[ScheduleEvent]:
        n = self.n
        heap: List[Tuple[float, int]] = []
        for i, dt in enumerate(self.sampler.sample_batch(np.arange(n))):
            heapq.heappush(heap, (dt, i))
        k = 0
        while True:
            t, i = heapq.heappop(heap)
            nbrs = self.graph.neighbors(i)
            P = np.eye(n)
            edges: Tuple[Tuple[int, int], ...] = ()
            copies = 0
            if len(nbrs):
                r = int(self._rng.choice(nbrs))
                # sender i's ROW splits its mass between i and r
                P[i, i] = 0.5
                P[i, r] = 0.5
                edges = ((min(i, r), max(i, r)),)
                copies = 1  # one directed push
            yield ScheduleEvent(
                k=k, time=t,
                grad_workers=self._mask([i]),
                restart_workers=self._mask([i]),
                P=P, active_edges=edges, param_copies_sent=copies,
            )
            k += 1
            heapq.heappush(heap, (t + self.sampler.sample(i), i))


def make_scheduler(name: str, graph: Graph, straggler: StragglerModel, **kw) -> Scheduler:
    from repro.core.scheduler import AAUScheduler, SyncScheduler
    table = {
        "dsgd_aau": AAUScheduler,
        "dsgd_sync": SyncScheduler,
        "ad_psgd": ADPSGDScheduler,
        "prague": PragueScheduler,
        "agp": AGPScheduler,
    }
    if name not in table:
        raise KeyError(f"unknown algorithm {name!r}; have {sorted(table)}")
    return table[name](graph, straggler, **kw)
