"""Version shims over jax API drift (mesh axis types, shard_map location).

The repo targets recent jax, but must also run on jax 0.4.x where
``jax.sharding.AxisType`` / the ``axis_types=`` kwarg and the top-level
``jax.shard_map`` entry point do not exist yet.  Everything that builds a mesh
or wraps a shard_map goes through this module so the rest of the codebase can
be written against the modern API.
"""
from __future__ import annotations

import enum
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh

try:  # jax >= 0.4.38
    from jax.sharding import AxisType  # type: ignore[attr-defined]

    HAS_AXIS_TYPE = True
except ImportError:  # jax 0.4.37 and earlier: placeholder with the same names
    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    HAS_AXIS_TYPE = False


def auto_axis_types(n: int) -> Tuple[AxisType, ...]:
    return (AxisType.Auto,) * n


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              axis_types: Optional[Sequence[AxisType]] = None,
              devices=None) -> Mesh:
    """``jax.make_mesh`` that tolerates jax versions without ``axis_types``."""
    kw = {} if devices is None else {"devices": devices}
    if HAS_AXIS_TYPE and axis_types is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=tuple(axis_types), **kw)
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(axis_shapes, axis_names, **kw)


def mesh_from_devices(devices, axis_names: Sequence[str], *,
                      axis_types: Optional[Sequence[AxisType]] = None) -> Mesh:
    """``Mesh(devices, names)`` that tolerates jax versions without axis_types."""
    if HAS_AXIS_TYPE and axis_types is not None:
        try:
            return Mesh(devices, axis_names, axis_types=tuple(axis_types))
        except TypeError:
            pass
    return Mesh(devices, axis_names)


def shard_map(f=None, /, **kw):
    """Top-level ``jax.shard_map`` with fallback to the experimental module.

    Newer jax renamed ``check_rep`` to ``check_vma``; we accept either spelling
    and translate for whichever implementation is present.
    """
    impl = getattr(jax, "shard_map", None)
    legacy = impl is None
    if legacy:
        from jax.experimental.shard_map import shard_map as impl  # type: ignore
        if "check_vma" in kw:
            kw["check_rep"] = kw.pop("check_vma")
    elif "check_rep" in kw:
        kw["check_vma"] = kw.pop("check_rep")
    if f is None:
        return lambda g: impl(g, **kw)
    return impl(f, **kw)
