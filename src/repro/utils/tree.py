"""Pytree utilities used across the framework.

The decentralized simulator keeps all N workers' parameters as a single pytree
whose leaves carry a leading worker axis (``tree_stack``).  The gossip mixing
step then operates on that axis; everything here is jit-friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_stack(trees):
    """Stack a list of identically-structured pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree, n):
    """Inverse of :func:`tree_stack`: a list of n pytrees."""
    return [jax.tree.map(lambda x, i=i: x[i], tree) for i in range(n)]


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_axpy(a, x, y):
    """a * x + y, leafwise."""
    return jax.tree.map(lambda xi, yi: a * xi + yi, x, y)


def tree_scale(a, x):
    return jax.tree.map(lambda xi: a * xi, x)


def tree_add(x, y):
    return jax.tree.map(jnp.add, x, y)


def tree_sub(x, y):
    return jax.tree.map(jnp.subtract, x, y)


def tree_dot(x, y):
    leaves = jax.tree.leaves(jax.tree.map(lambda a, b: jnp.vdot(a, b), x, y))
    return sum(leaves)


def tree_norm(x):
    return jnp.sqrt(tree_dot(x, x))


def tree_size(tree) -> int:
    """Total number of scalars in the tree (static)."""
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(tree)))


def flatten_to_vector(tree):
    """Flatten a pytree into a single 1-D vector (and return an unflattener)."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [int(np.prod(s)) for s in shapes]
    vec = jnp.concatenate([jnp.ravel(l) for l in leaves]) if leaves else jnp.zeros((0,))

    def unflatten(v):
        out, off = [], 0
        for s, sz in zip(shapes, sizes):
            out.append(jnp.reshape(v[off:off + sz], s))
            off += sz
        return jax.tree.unflatten(treedef, out)

    return vec, unflatten


def unflatten_from_vector(vec, like):
    _, unflatten = flatten_to_vector(like)
    return unflatten(vec)
