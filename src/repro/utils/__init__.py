from repro.utils.tree import (
    tree_stack,
    tree_unstack,
    tree_zeros_like,
    tree_axpy,
    tree_scale,
    tree_add,
    tree_sub,
    tree_dot,
    tree_norm,
    tree_size,
    flatten_to_vector,
    unflatten_from_vector,
)
