"""The paper's own experiment-scale models (fidelity experiments, §6).

The paper evaluates 2-NN / AlexNet / VGG-13 / ResNet-18 / LSTM on CIFAR-10 /
MNIST / Tiny-ImageNet / Shakespeare.  CNN archs are outside the assigned
transformer pool; for the convergence-fidelity experiments we keep the 2-NN
(exact table-3 shape) and a small decoder LM standing in for the LSTM
next-character task, both trained on the synthetic non-iid data pipeline.
"""
from repro.configs.base import ModelConfig, register

# 2-NN: 3072 -> 256 -> 256 -> 10 fully-connected net (paper Table 3).
PAPER_2NN = dict(d_in=3072, d_hidden=256, n_classes=10)

# Next-character LM standing in for the paper's LSTM (Table 7 scale).
CONFIG_CHAR_LM = register(ModelConfig(
    name="paper-char-lm",
    family="dense",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=512,
    vocab_size=80,          # Shakespeare character vocabulary
    param_dtype="float32",
    compute_dtype="float32",
    source="paper §6 (LSTM task stand-in)",
))
