"""Grok-1 314B — MoE, 8 experts top-2 [hf:xai-org/grok-1]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,           # GQA kv=8
    d_ff=32768,
    vocab_size=131072,
    n_experts=8,
    top_k=2,
    moe_groups=16,           # GShard dispatch groups = data-shard count
    source="hf:xai-org/grok-1",
    notes="8-expert top-2 MoE; expert-parallel over the model axis",
))
