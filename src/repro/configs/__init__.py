"""Assigned-architecture registry.  Importing this package registers all archs."""
from repro.configs.base import ModelConfig, get_config, list_configs, register

# assigned pool (10 archs, 6 families)
from repro.configs import (  # noqa: F401
    deepseek_67b,
    rwkv6_1_6b,
    minicpm_2b,
    musicgen_large,
    grok_1_314b,
    mistral_nemo_12b,
    arctic_480b,
    llava_next_mistral_7b,
    recurrentgemma_2b,
    qwen3_8b,
    paper_models,
)

ASSIGNED = (
    "deepseek-67b",
    "rwkv6-1.6b",
    "minicpm-2b",
    "musicgen-large",
    "grok-1-314b",
    "mistral-nemo-12b",
    "arctic-480b",
    "llava-next-mistral-7b",
    "recurrentgemma-2b",
    "qwen3-8b",
)
