"""Mistral-NeMo 12B — dense GQA, 128k context [hf:mistralai/Mistral-Nemo-Base-2407]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,           # GQA kv=8
    d_head=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,  # 128k-context rope base
    source="hf:mistralai/Mistral-Nemo-Base-2407",
    notes="128k ctx; long_500k via Mistral-style rolling-window swa8192 variant",
))
