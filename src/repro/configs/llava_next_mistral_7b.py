"""LLaVA-NeXT (Mistral-7B backbone) — VLM with anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Vision frontend (SigLIP/CLIP ViT + projector) is a stub per the brief:
``input_specs()`` provides projected patch embeddings (anyres tiling → up to
2880 patches = 4 tiles + base, 576 patches each) prepended to the token stream.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,           # GQA kv=8
    d_ff=14336,
    vocab_size=32000,
    frontend="vision",
    n_prefix_tokens=2880,   # anyres: 5 tiles x 576 projected patches
    rope_theta=1_000_000.0,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    notes="anyres tiling stubbed as precomputed patch embeddings",
))
