"""DeepSeek-67B — dense llama-arch [arXiv:2401.02954]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,           # GQA kv=8
    d_ff=22016,
    vocab_size=102400,
    rope_theta=10000.0,
    source="arXiv:2401.02954 (DeepSeek LLM 67B)",
    notes="llama-arch dense; long_500k runs via the swa8192 variant",
))
