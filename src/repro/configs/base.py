"""Architecture configuration system.

Every assigned architecture is a :class:`ModelConfig` in its own module under
``repro/configs/`` and is selectable via ``--arch <id>`` in the launchers.
``reduced()`` returns the smoke-test variant (≤2 layers, d_model ≤ 512,
≤4 experts) of the same family, exercised on CPU by tests/test_arch_smoke.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                      # 0 for attention-free (ssm)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                   # default d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    dense_residual_ff: int = 0        # arctic: dense MLP in parallel with MoE
    moe_capacity_factor: float = 1.25  # Switch-style expert capacity
    moe_groups: int = 1               # GShard-style dispatch groups (per data shard)
    # --- attention details ---
    qk_norm: bool = False             # qwen3
    rope_theta: float = 10000.0
    attn_window: Optional[int] = None  # sliding-window attention (tokens)
    # --- hybrid (recurrentgemma) ---
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn"); empty = homogeneous
    rnn_width: int = 0                # RG-LRU state width (default d_model)
    conv_width: int = 4
    # --- ssm (rwkv6) ---
    rwkv_head_dim: int = 64
    # --- multimodal stub frontend ---
    frontend: Optional[str] = None    # None | "audio" | "vision"
    n_prefix_tokens: int = 0          # patch/frame embeddings prepended
    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    source: str = ""                  # citation of paper / model card
    notes: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.d_head == 0 and self.n_heads > 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.family == "hybrid" and self.rnn_width == 0:
            object.__setattr__(self, "rnn_width", self.d_model)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic path available (native SSM/hybrid or SWA variant)."""
        return True  # every arch has SSM/hybrid recurrence or the SWA variant

    def with_sliding_window(self, window: int = 8192) -> "ModelConfig":
        """SWA variant used for the long_500k decode shape on quadratic archs."""
        if self.family in ("ssm",):
            return self  # natively O(1) state
        return dataclasses.replace(self, attn_window=window,
                                   notes=self.notes + f" [swa{window} variant]")

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        total = v * d                                   # embed
        if not self.tie_embeddings:
            total += d * v                              # lm head
        total += d                                      # final norm
        per_attn = (d * self.n_heads * self.d_head     # wq
                    + 2 * d * self.n_kv_heads * self.d_head  # wk, wv
                    + self.n_heads * self.d_head * d)   # wo
        if self.qk_norm:
            per_attn += 2 * self.d_head
        per_mlp_dense = 3 * d * f
        per_norms = 2 * d
        if self.family == "moe":
            per_ffn = self.n_experts * 3 * d * f + d * self.n_experts
            if self.dense_residual_ff:
                per_ffn += 3 * d * self.dense_residual_ff
        else:
            per_ffn = per_mlp_dense
        if self.family == "ssm":
            # rwkv6: time-mix (r,k,v,g,o,decay lora) + channel-mix, roughly 12 d²
            per_layer = 12 * d * d + per_norms
            return total + L * per_layer
        if self.family == "hybrid":
            n_attn = sum(1 for b in self._pattern_expanded() if b == "attn")
            n_rec = L - n_attn
            w = self.rnn_width
            per_rec = (2 * d * w              # in/gate proj
                       + self.conv_width * w  # conv1d
                       + 2 * w                # RG-LRU gates' diagonal params
                       + 2 * w * d // 1       # rec gates (input/recurrence) small
                       + w * d)               # out proj
            per_rec += 2 * w * w // max(w, 1)  # negligible
            return (total + n_attn * (per_attn + per_mlp_dense + per_norms)
                    + n_rec * (per_rec + per_mlp_dense + per_norms))
        return total + L * (per_attn + per_ffn + per_norms)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        full = self.param_count()
        moe_all = L * self.n_experts * 3 * d * f
        moe_active = L * self.top_k * 3 * d * f
        return full - moe_all + moe_active

    def _pattern_expanded(self) -> Tuple[str, ...]:
        if not self.block_pattern:
            return tuple(["attn"] * self.n_layers)
        reps = -(-self.n_layers // len(self.block_pattern))
        return (self.block_pattern * reps)[: self.n_layers]

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family/features, tiny dims."""
        d = min(self.d_model, 256)
        heads = min(self.n_heads, 4) if self.n_heads else 0
        kv = min(self.n_kv_heads, heads) if heads else 0
        if kv and heads % kv:
            kv = 1
        pattern = self.block_pattern[: 3] if self.block_pattern else ()
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=2 if not pattern else len(pattern),
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            d_head=d // heads if heads else 0,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_groups=1,
            dense_residual_ff=min(self.dense_residual_ff, 256) if self.dense_residual_ff else 0,
            rnn_width=min(self.rnn_width, d) if self.rnn_width else 0,
            attn_window=min(self.attn_window, 64) if self.attn_window else None,
            n_prefix_tokens=min(self.n_prefix_tokens, 8) if self.n_prefix_tokens else 0,
            param_dtype="float32",
            compute_dtype="float32",
        )


_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # import arch modules lazily so the registry is populated
    import repro.configs  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    import repro.configs  # noqa: F401
    return dict(_REGISTRY)
