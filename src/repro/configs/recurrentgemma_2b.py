"""RecurrentGemma-2B (Griffin) — RG-LRU + local attention, 1:2 [arXiv:2402.19427]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,           # GQA kv=1 (MQA) for the local-attention blocks
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("rec", "rec", "attn"),  # 1 local-attn : 2 recurrent
    rnn_width=2560,
    conv_width=4,
    attn_window=2048,       # Griffin local attention window
    source="arXiv:2402.19427 (Griffin / RecurrentGemma)",
    notes="RG-LRU recurrence + 2048-window local attn; native long_500k",
))
