"""MusicGen-large — decoder-only over EnCodec tokens [arXiv:2306.05284].

Audio frontend (EnCodec + mel feature extraction) is a stub per the brief:
``input_specs()`` supplies precomputed conditioning frame embeddings; the
decoder consumes EnCodec token ids (vocab 2048) directly.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,          # GQA kv=32 (MHA)
    d_ff=8192,
    vocab_size=2048,        # EnCodec codebook size
    frontend="audio",
    n_prefix_tokens=256,    # conditioning frame embeddings (stub frontend)
    source="arXiv:2306.05284 (MusicGen)",
    notes="decoder-only over EnCodec tokens; long_500k via swa8192 variant",
))
