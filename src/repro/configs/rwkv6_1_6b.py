"""RWKV6 (Finch) 1.6B — attention-free SSM, data-dependent decay [arXiv:2404.05892]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=0,              # attention-free
    n_kv_heads=0,
    d_ff=7168,
    vocab_size=65536,
    rwkv_head_dim=64,
    source="arXiv:2404.05892 (Eagle & Finch: RWKV-5/6)",
    notes="data-dependent decay; O(1) decode state; native long_500k",
))
