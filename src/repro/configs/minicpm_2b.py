"""MiniCPM-2B — llama-like dense, trained with the WSD schedule [arXiv:2404.06395]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,          # GQA kv=36 (i.e. MHA)
    d_ff=5760,
    vocab_size=122753,
    tie_embeddings=True,    # MiniCPM ties input/output embeddings
    source="arXiv:2404.06395 (MiniCPM)",
    notes="WSD schedule implemented in repro.optim.schedules.wsd",
))
