"""Snowflake Arctic 480B — 128-expert top-2 MoE + dense residual [hf:Snowflake/snowflake-arctic-base]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,           # GQA kv=8
    d_ff=4864,              # per-expert FFN width
    vocab_size=32000,
    n_experts=128,
    top_k=2,
    dense_residual_ff=4864,  # dense-MoE hybrid: dense MLP residual in parallel
    moe_groups=16,           # GShard dispatch groups = data-shard count
    source="hf:Snowflake/snowflake-arctic-base",
    notes="128e top-2 + dense residual; heaviest replica — hierarchical worker/fsdp split",
))
