"""CLI for the experiment harness.

  python -m repro.xp [--preset paper_figures] [--out BENCH_paper_figures.json]
  python -m repro.xp --smoke            # CI dry-run tier (N=8, all scenarios)

Prints ``name,us_per_call,derived`` CSV rows (the benchmark-harness
contract) and writes the JSON artifact only when ``--out`` is given, so a
smoke run can never clobber recorded results.  Render tables from a
recorded artifact with ``python experiments/render_tables.py paper_figures``.
"""
from __future__ import annotations

import argparse
import sys

from repro.xp.artifacts import artifact_payload, csv_rows, write_artifact
from repro.xp.presets import PRESETS, get_preset
from repro.xp.sweep import run_spec


def _csv_tuple(s, conv=str):
    return tuple(conv(x) for x in s.split(",") if x)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.xp")
    ap.add_argument("--preset", default="paper_figures",
                    choices=sorted(PRESETS))
    ap.add_argument("--smoke", action="store_true",
                    help="shortcut for --preset smoke (CI dry-run tier)")
    ap.add_argument("--out", default=None,
                    help="write the JSON artifact here (omit: print only)")
    ap.add_argument("--scales", default=None,
                    help="override worker counts, e.g. 32,64")
    ap.add_argument("--seeds", default=None, help="override seeds, e.g. 0,1")
    ap.add_argument("--scenarios", default=None,
                    help="override scenario names, e.g. paper_default,churn")
    ap.add_argument("--dtype", default=None,
                    help="worker-state dtype policy: float32 | bfloat16")
    ap.add_argument("--max-time", type=float, default=None,
                    help="override the async virtual-time budget")
    ap.add_argument("--telemetry", action="store_true",
                    help="record device-resident per-worker telemetry "
                         "(repro.obs) into the artifact's telemetry section")
    ap.add_argument("--trace", action="store_true",
                    help="record event-identity traces and the wait-blame / "
                         "straggler-tax summary (repro.obs.trace) into the "
                         "artifact's trace section")
    ap.add_argument("--run-log", default=None,
                    help="append structured JSONL run events here")
    args = ap.parse_args(argv)

    spec = get_preset("smoke" if args.smoke else args.preset)
    over = {}
    if args.scales:
        over["scales"] = _csv_tuple(args.scales, int)
    if args.seeds:
        over["seeds"] = _csv_tuple(args.seeds, int)
    if args.scenarios:
        over["scenarios"] = _csv_tuple(args.scenarios)
    if args.dtype:
        over["dtype"] = args.dtype
    if args.max_time is not None:
        # an explicit time budget must actually bind: drop any event bound
        # the preset carries (event bounds take precedence in the sweep)
        over["max_time"] = args.max_time
        over["max_events"] = None
    if args.telemetry:
        over["telemetry"] = True
    if args.trace:
        over["trace"] = True
    if args.run_log:
        over["run_log"] = args.run_log
    if over:
        spec = spec.replace(**over)

    sweep = run_spec(spec, log=lambda s: print(s, file=sys.stderr))
    payload = artifact_payload(sweep)
    print("name,us_per_call,derived")
    for row in csv_rows(payload):
        print(row)
    if args.out:
        write_artifact(args.out, payload)
        print(f"# wrote {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
