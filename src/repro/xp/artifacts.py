"""Artifact writers: BENCH_paper_figures.json + benchmark CSV rows.

The JSON artifact schema (consumed by experiments/render_tables.py):

```
{
  "meta":        {spec: {...}, jax: "...", generated_unix: float},
  "scenarios":   {name: Scenario.describe() at the largest scale},
  "speedup_vs_n":  [ {scenario, n, algorithm, speedup_mean, speedup_std,
                      t_target_mean, t_sync_mean, n_seeds, unreached} ],
  "convergence":   [ {scenario, n, algorithm, n_seeds,
                      points: [{k, time_mean, loss_mean, loss_std,
                                metric_mean}]} ],
  "dtype_policy":  [ {dtype, scenario, algorithm, n, events, final_loss,
                      final_metric, wall_s, events_per_s} ],
  "telemetry":     [ {scenario, n, algorithm, n_seeds, utilization_mean,
                      utilization_min, stale_mean, stale_max,
                      stale_hist: [16 log2-binned counts], comm_copies,
                      grad_steps_total,
                      staleness_bound?: {bound, observed_max, ok},
                      bucket_occupancy?: [{A, events, lane_fill}]} ],
  "trace":         [ {scenario, n, algorithm, n_seeds, events,
                      straggler_tax_mean, busy_t_mean, wait_t_mean,
                      blame_total_mean, residual_wait_mean,
                      blame_concentration, blame_top: [{worker, blame_t,
                      share}], cp_wait_frac_mean} ],
}
```

The ``telemetry`` section is present only when the spec ran with
``telemetry=True`` (device-resident counters drained once per run — see
repro/obs); ``staleness_bound`` appears for DSGD-AAU rows (the 2N−4
event-staleness monitor induced by the B ≤ N−1 per-epoch commit bound)
and ``bucket_occupancy`` for bucketed sparse streams.  The ``trace``
section likewise appears only for ``trace=True`` runs — the wait-blame /
straggler-tax decomposition of repro/obs/critical_path (the numbers
behind ``render_tables.straggler_tax_table``).

``speedup_mean`` is NaN (serialized as the JSON string "nan") whenever a
run never reached the target loss inside its budget — the ``unreached``
count says how many seeds that was — so an artifact can never be misread as
"no speedup" when the truth is "budget too small".
"""
from __future__ import annotations

import json
import math
import time
from typing import Dict, List

import jax

from repro.xp.sweep import (SweepResult, convergence_rows, speedup_rows,
                            telemetry_rows, trace_rows)


def _json_safe(obj):
    """NaN/Inf → strings, tuples → lists (json.dump with allow_nan=False)."""
    if isinstance(obj, float):
        if math.isnan(obj):
            return "nan"
        if math.isinf(obj):
            return "inf" if obj > 0 else "-inf"
        return obj
    if isinstance(obj, dict):
        return {k: _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    return obj


def parse_float(v) -> float:
    """Inverse of the NaN/Inf serialization above (for artifact readers);
    ``float()`` parses plain numbers and the "nan"/"inf" strings alike."""
    return float(v)


def artifact_payload(sweep: SweepResult) -> Dict[str, object]:
    payload = {
        "meta": {
            "spec": sweep.spec.to_dict(),
            "jax": jax.__version__,
            "generated_unix": round(time.time(), 1),
        },
        "scenarios": sweep.scenario_meta,
        "speedup_vs_n": speedup_rows(sweep),
        "convergence": convergence_rows(sweep),
        "dtype_policy": sweep.dtype_rows,
    }
    rows = telemetry_rows(sweep)
    if rows:  # present only for telemetry=True runs (see module docstring)
        payload["telemetry"] = rows
    t_rows = trace_rows(sweep)
    if t_rows:  # present only for trace=True runs
        payload["trace"] = t_rows
    return payload


def write_artifact(path: str, payload: Dict[str, object]) -> None:
    with open(path, "w") as f:
        json.dump(_json_safe(payload), f, indent=1, allow_nan=False)
        f.write("\n")


def load_artifact(path: str) -> Dict[str, object]:
    with open(path) as f:
        return json.load(f)


def csv_rows(payload: Dict[str, object]) -> List[str]:
    """The benchmark-harness CSV contract: ``name,us_per_call,derived``."""
    out = []
    for r in payload["speedup_vs_n"]:
        mean = parse_float(r["speedup_mean"])
        std = parse_float(r["speedup_std"])
        t_t = parse_float(r["t_target_mean"])
        t_s = parse_float(r["t_sync_mean"])
        fmt = lambda v: "unreached" if math.isnan(v) else f"{v:.1f}"
        if math.isnan(mean):
            # distinguish "the algorithm never got there" from "the sync
            # reference's budget fell short" — keep whichever time exists
            derived = (f"speedup_vs_sync=nan;t_target={fmt(t_t)};"
                       f"t_sync={fmt(t_s)};"
                       f"unreached={r['unreached']}/{r['n_seeds']};"
                       f"unreached_ref={r.get('unreached_ref', 0)}"
                       f"/{r['n_seeds']}")
        else:
            derived = (f"speedup_vs_sync={mean:.2f};std={std:.2f};"
                       f"t_target={fmt(t_t)};t_sync={fmt(t_s)};"
                       f"unreached={r['unreached']}/{r['n_seeds']}")
        out.append(f"paper_figures/speedup/{r['scenario']}/N{r['n']}/"
                   f"{r['algorithm']},0.0,{derived}")
    for r in payload.get("dtype_policy", []):
        out.append(
            f"paper_figures/dtype/{r['dtype']}/{r['algorithm']}/N{r['n']},"
            f"0.0,final_loss={parse_float(r['final_loss']):.4f};"
            f"events_per_s={parse_float(r['events_per_s']):.1f}")
    for r in payload.get("telemetry", []):
        derived = (f"util={parse_float(r['utilization_mean']):.3f};"
                   f"stale_mean={parse_float(r['stale_mean']):.2f};"
                   f"stale_max={r['stale_max']}")
        b = r.get("staleness_bound")
        if b is not None:
            derived += (f";bound={b['bound']};"
                        f"bound_ok={'yes' if b['ok'] else 'VIOLATED'}")
        out.append(f"paper_figures/telemetry/{r['scenario']}/N{r['n']}/"
                   f"{r['algorithm']},0.0,{derived}")
    for r in payload.get("trace", []):
        derived = (f"tax={parse_float(r['straggler_tax_mean']):.3f};"
                   f"blame_conc={parse_float(r['blame_concentration']):.3f};"
                   f"cp_wait={parse_float(r['cp_wait_frac_mean']):.3f}")
        out.append(f"paper_figures/trace/{r['scenario']}/N{r['n']}/"
                   f"{r['algorithm']},0.0,{derived}")
    return out
