"""Named experiment presets.

``paper_figures`` is the recorded configuration behind
``BENCH_paper_figures.json`` — Figures 3–5 at N ∈ {32, 64, 128, 256} on the
sparse path under three scenarios.  ``paper_figures_xl`` extends it to
N ∈ {512, 1024} (bucketed sparse path, no synchronous reference).
``smoke`` is the CI dry-run tier: every registered scenario at N = 8 for a
handful of events, proving the whole harness (spec → sweep → artifact)
stays importable and runnable; ``smoke_xl`` is its N = 512 sibling that
pins the multi-bucket dispatch path in CI.  ``trace_tables`` records the
wait-blame / straggler-tax artifact (``BENCH_trace.json``) behind
``render_tables.straggler_tax_table``.
"""
from __future__ import annotations

from repro.scenarios import scenario_names
from repro.xp.spec import ExperimentSpec


def paper_figures_spec() -> ExperimentSpec:
    return ExperimentSpec(
        name="paper_figures",
        algorithms=("dsgd_aau", "ad_psgd", "prague", "agp"),
        reference="dsgd_sync",
        scenarios=("paper_default", "heavy_tail", "bimodal"),
        scales=(32, 64, 128, 256),
        seeds=(0, 1),
        mode="sparse_scan",
        # probed at N∈{32, 256}: every algorithm reaches the 0.9 target
        # within ~33 unscaled virtual seconds (AD-PSGD at N=256 is the
        # slowest — its averaging lock caps throughput, the paper's point)
        max_time=30.0,
        ref_max_time=400.0,
        ref_max_events=160,
        eval_every=10,
        ref_eval_every=2,
        target_loss=0.9,
        dtype_probe=True,
    )


def paper_figures_xl_spec() -> ExperimentSpec:
    """Beyond-paper scales the bucketed lane-width ladder unlocks.

    N ∈ {512, 1024} on the sparse path only.  No synchronous reference —
    a barrier over 1024 workers would dominate the sweep's wall clock for
    a speedup denominator the paper never reports at this scale (the
    artifact keeps convergence rows; ``speedup_rows`` degrades to empty).
    """
    return ExperimentSpec(
        name="paper_figures_xl",
        algorithms=("dsgd_aau", "ad_psgd", "prague"),
        reference=None,
        scenarios=("paper_default", "heavy_tail"),
        scales=(512, 1024),
        seeds=(0,),
        mode="sparse_scan",
        block_size=128,
        # event-bounded, not time-bounded: virtual-time horizons calibrated
        # at N≤256 over-run at 4× the workers (events/second of virtual
        # time grows with n), and the point here is path coverage + wall
        # throughput, not matching a figure.
        max_events=512,
        max_time=None,
        eval_every=64,
        target_loss=0.9,
    )


def smoke_spec() -> ExperimentSpec:
    return ExperimentSpec(
        name="smoke",
        algorithms=("dsgd_aau", "ad_psgd"),
        reference="dsgd_sync",
        scenarios=scenario_names(),        # every registered scenario
        scales=(8,),
        seeds=(0,),
        # auto resolves to the dense scan at N=8 (choose_mode crossover) —
        # the sparse path's per-lane gathers only pay off at larger n, and
        # CI should exercise the resolution logic end to end
        mode="auto",
        max_events=24,
        eval_every=12,
        ref_eval_every=12,
        target_loss=0.9,
        dtype_probe=True,
        dtype_probe_events=16,
    )


def smoke_xl_spec() -> ExperimentSpec:
    """CI tier for the bucketed sparse path at N=512.

    One multi-rung algorithm (DSGD-AAU — the only scheduler whose
    ``active_buckets`` ladder has more than one rung at default settings)
    for a few blocks: proves the bucketed dispatch compiles and runs at a
    scale where the static single-bucket padding would be prohibitive.
    """
    return ExperimentSpec(
        name="smoke_xl",
        algorithms=("dsgd_aau",),
        reference=None,
        scenarios=("paper_default",),
        scales=(512,),
        seeds=(0,),
        mode="sparse_scan",
        block_size=32,
        max_events=48,
        max_time=None,
        eval_every=24,
        target_loss=0.9,
    )


def fused_smoke_spec() -> ExperimentSpec:
    """CI tier for ``mode="fused"`` — the device-resident event generator.

    The two single-edge gossip algorithms whose event processes admit a
    pure-JAX generator (AD-PSGD, AGP) under an iid-horizon scenario, for a
    few blocks: proves the fused generate-and-consume scan compiles, runs,
    and keeps exact communication accounting end to end.  Event-bounded by
    construction — fused runs keep the virtual clock on device.
    """
    return ExperimentSpec(
        name="fused_smoke",
        algorithms=("ad_psgd", "agp"),
        reference=None,
        scenarios=("paper_default",),
        scales=(8,),
        seeds=(0,),
        mode="fused",
        block_size=16,
        max_events=48,
        max_time=None,
        eval_every=24,
        target_loss=0.9,
    )


def trace_tables_spec() -> ExperimentSpec:
    """Recorded configuration behind ``BENCH_trace.json``.

    The wait-blame / straggler-tax comparison the paper's narrative makes
    qualitatively: DSGD-AAU vs AD-PSGD against the synchronous reference,
    under the default and heavy-tailed duration regimes.  Event-bounded so
    the three algorithms attribute blame over the same number of events,
    and small enough (N = 16) that the table regenerates in seconds.
    """
    return ExperimentSpec(
        name="trace_tables",
        algorithms=("dsgd_aau", "ad_psgd"),
        reference="dsgd_sync",
        scenarios=("paper_default", "heavy_tail"),
        scales=(16,),
        seeds=(0, 1),
        mode="auto",
        max_events=200,
        max_time=None,
        ref_max_events=200,
        eval_every=100,
        ref_eval_every=100,
        target_loss=0.9,
        trace=True,
    )


PRESETS = {
    "paper_figures": paper_figures_spec,
    "paper_figures_xl": paper_figures_xl_spec,
    "smoke": smoke_spec,
    "smoke_xl": smoke_xl_spec,
    "fused_smoke": fused_smoke_spec,
    "trace_tables": trace_tables_spec,
}


def get_preset(name: str) -> ExperimentSpec:
    if name not in PRESETS:
        raise KeyError(f"unknown preset {name!r}; have {sorted(PRESETS)}")
    return PRESETS[name]()
