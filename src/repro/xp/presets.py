"""Named experiment presets.

``paper_figures`` is the recorded configuration behind
``BENCH_paper_figures.json`` — Figures 3–5 at N ∈ {32, 64, 128, 256} on the
sparse path under three scenarios.  ``smoke`` is the CI dry-run tier: every
registered scenario at N = 8 for a handful of events, proving the whole
harness (spec → sweep → artifact) stays importable and runnable.
"""
from __future__ import annotations

from repro.scenarios import scenario_names
from repro.xp.spec import ExperimentSpec


def paper_figures_spec() -> ExperimentSpec:
    return ExperimentSpec(
        name="paper_figures",
        algorithms=("dsgd_aau", "ad_psgd", "prague", "agp"),
        reference="dsgd_sync",
        scenarios=("paper_default", "heavy_tail", "bimodal"),
        scales=(32, 64, 128, 256),
        seeds=(0, 1),
        mode="sparse_scan",
        # probed at N∈{32, 256}: every algorithm reaches the 0.9 target
        # within ~33 unscaled virtual seconds (AD-PSGD at N=256 is the
        # slowest — its averaging lock caps throughput, the paper's point)
        max_time=30.0,
        ref_max_time=400.0,
        ref_max_events=160,
        eval_every=10,
        ref_eval_every=2,
        target_loss=0.9,
        dtype_probe=True,
    )


def smoke_spec() -> ExperimentSpec:
    return ExperimentSpec(
        name="smoke",
        algorithms=("dsgd_aau", "ad_psgd"),
        reference="dsgd_sync",
        scenarios=scenario_names(),        # every registered scenario
        scales=(8,),
        seeds=(0,),
        mode="sparse_scan",
        max_events=24,
        eval_every=12,
        ref_eval_every=12,
        target_loss=0.9,
        dtype_probe=True,
        dtype_probe_events=16,
    )


PRESETS = {
    "paper_figures": paper_figures_spec,
    "smoke": smoke_spec,
}


def get_preset(name: str) -> ExperimentSpec:
    if name not in PRESETS:
        raise KeyError(f"unknown preset {name!r}; have {sorted(PRESETS)}")
    return PRESETS[name]()
