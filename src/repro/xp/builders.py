"""Model and trainer builders shared by the harness and the benchmarks.

The paper's 2-NN classifier (Table 3 shape, reduced input dim for the
synthetic Gaussian-mixture data) lives here so both the declarative
experiment harness (repro/xp/sweep.py) and the legacy benchmark helpers
(benchmarks/common.py) build byte-identical trainers.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topology
from repro.core.baselines import make_scheduler
from repro.core.runner import DecentralizedTrainer
from repro.data import ClassificationData
from repro.scenarios import Scenario, get_scenario
from repro.xp.spec import ExperimentSpec


def mlp2nn_loss(params, batch):
    """The paper's 2-NN (Table 3 shape, reduced input dim for synthetic data)."""
    h = jax.nn.relu(batch["x"] @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    logits = h @ params["w3"] + params["b3"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], axis=1))


def mlp2nn_eval(params, batch):
    h = jax.nn.relu(batch["x"] @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    logits = h @ params["w3"] + params["b3"]
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
    return mlp2nn_loss(params, batch), acc


def mlp2nn_init(d_in=64, d_h=256, n_cls=10):
    def init(key):
        ks = jax.random.split(key, 3)
        s = lambda k, a, b: jax.random.normal(k, (a, b)) / np.sqrt(a)
        return {"w1": s(ks[0], d_in, d_h), "b1": jnp.zeros(d_h),
                "w2": s(ks[1], d_h, d_h), "b2": jnp.zeros(d_h),
                "w3": s(ks[2], d_h, n_cls), "b3": jnp.zeros(n_cls)}
    return init


def build_graph(kind: str, n: int, **kw) -> topology.Graph:
    """Topology factory for ExperimentSpec.topology."""
    if kind == "erdos_renyi":
        p = kw.get("p")
        if p is None:
            p = max(0.15, 4.0 / n)
        return topology.erdos_renyi(n, p, seed=kw.get("seed", 1))
    if kind == "ring":
        return topology.ring(n)
    if kind == "fully_connected":
        return topology.fully_connected(n)
    raise KeyError(f"unknown topology {kind!r}; "
                   "have erdos_renyi, ring, fully_connected")


# Per-algorithm scheduler-RNG seed bases — the historical class defaults, so
# a sweep at seed 0 reproduces today's bench streams exactly; other sweep
# seeds shift every stream by a large co-prime stride.
_SCHED_SEED_BASE = {"ad_psgd": 1, "prague": 2, "agp": 3}


def build_scenario(spec: ExperimentSpec, name: str, n: int,
                   seed: int) -> Scenario:
    kw = dict(spec.scenario_kw.get(name, {}))
    # a spec may pin a scenario's RNG explicitly; n always comes from the
    # sweep's scale axis
    kw.pop("n", None)
    seed = kw.pop("seed", seed)
    return get_scenario(name, n=n, seed=seed, **kw)


def build_trainer(spec: ExperimentSpec, alg: str, n: int, seed: int,
                  scenario: Optional[Scenario] = None,
                  dtype: Optional[str] = None,
                  batch_pool: Optional[int] = None) -> DecentralizedTrainer:
    """One (algorithm × topology × scenario × scale × seed) trainer.

    ``scenario`` may be passed pre-built (the sweep builds it once per cell
    to read its ``mean_duration_factor`` for budget scaling); otherwise the
    spec's first scenario is instantiated at this seed.
    """
    if scenario is None:
        scenario = build_scenario(spec, spec.scenarios[0], n, seed)
    data = ClassificationData(
        n_workers=n, d=64, partition=spec.partition,
        samples_per_worker=256, seed=spec.data_seed)
    g = build_graph(spec.topology, n, **dict(spec.topology_kw))
    sched_kw = {}
    if alg in _SCHED_SEED_BASE:
        sched_kw["seed"] = _SCHED_SEED_BASE[alg] + 7919 * seed
    if alg == "prague":
        sched_kw["group_size"] = spec.group_size
    if alg in ("ad_psgd", "agp") and spec.horizon:
        sched_kw["horizon"] = spec.horizon
    sched = make_scheduler(alg, g, scenario, **sched_kw)
    return DecentralizedTrainer(
        sched, mlp2nn_loss, mlp2nn_init(),
        lambda w, s: data.batch(w, s, batch_size=32),
        data.eval_batch(1024), eval_fn=mlp2nn_eval,
        eta0=spec.eta0, eta_decay=spec.eta_decay, seed=seed,
        mode=spec.mode, block_size=spec.block_size,
        batch_pool=batch_pool if batch_pool is not None else spec.batch_pool,
        dtype=dtype or spec.dtype,
        telemetry=spec.telemetry, trace=spec.trace, run_log=spec.run_log)
