"""Multi-seed sweep runner: ExperimentSpec → RunRecords → aggregates.

Runs every (scenario × scale × seed × algorithm) cell of a spec on the
configured execution mode (``sparse_scan`` for the paper figures), measuring
the paper's two quantities per run:

- time-to-target-loss on the virtual clock (speedup numerator/denominator,
  Figure 5a) — ``None`` when the run's budget ends above the target, which
  aggregation reports as NaN speedup plus an ``unreached`` count instead of
  a misleading 0.0;
- the loss/accuracy-vs-virtual-time history (Figures 3–4 convergence
  curves), aggregated across seeds as mean ± std at matching eval indices.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.runner import RunResult
from repro.scenarios import Scenario
from repro.xp.builders import build_scenario, build_trainer
from repro.xp.spec import ExperimentSpec


@dataclasses.dataclass
class RunRecord:
    scenario: str
    algorithm: str
    n: int
    seed: int
    dtype: str
    wall_s: float
    t_target: Optional[float]       # virtual time to target loss (None: unreached)
    result: RunResult


@dataclasses.dataclass
class SweepResult:
    spec: ExperimentSpec
    records: List[RunRecord]
    dtype_rows: List[Dict[str, object]]
    scenario_meta: Dict[str, Dict[str, object]]

    def cells(self) -> List[Tuple[str, int]]:
        return sorted({(r.scenario, r.n) for r in self.records})

    def select(self, scenario: str = None, algorithm: str = None,
               n: int = None) -> List[RunRecord]:
        out = self.records
        if scenario is not None:
            out = [r for r in out if r.scenario == scenario]
        if algorithm is not None:
            out = [r for r in out if r.algorithm == algorithm]
        if n is not None:
            out = [r for r in out if r.n == n]
        return out


def _budgets(spec: ExperimentSpec, scenario: Scenario,
             is_reference: bool) -> Tuple[dict, Optional[int]]:
    """(run kwargs, batch_pool) for one cell.

    Pools are sized from the *scaled* time budget: a worker restarts at
    most once per completed computation and every scenario's duration
    factors have a fast tail near 1× base_time, so ``2.5 × scaled budget /
    base_time`` bounds restarts per worker even for a worker that only ever
    draws the fast tail (the runner's wrap warning stays as the backstop).
    """
    ts = scenario.mean_duration_factor() if spec.time_scaled else 1.0
    if spec.max_events is not None:
        run_kw = dict(max_events=spec.max_events,
                      eval_every=spec.ref_eval_every if is_reference
                      else spec.eval_every)
        return run_kw, spec.batch_pool
    if is_reference:
        # the barrier reference is additionally event-bounded: its rounds
        # are n-fold slower on the virtual clock, and its batch pool only
        # needs one draw per round
        run_kw = dict(max_events=spec.ref_max_events,
                      max_time=spec.ref_max_time * ts if spec.ref_max_time
                      else None,
                      eval_every=spec.ref_eval_every)
        pool = spec.batch_pool or spec.ref_max_events
        return run_kw, pool
    run_kw = dict(max_time=spec.max_time * ts, eval_every=spec.eval_every)
    pool = spec.batch_pool or min(
        1024, int(math.ceil(2.5 * spec.max_time * ts / scenario.base_time)))
    return run_kw, pool


def run_cell(spec: ExperimentSpec, scenario_name: str, alg: str, n: int,
             seed: int, log: Callable[[str], None] = lambda s: None,
             dtype: Optional[str] = None, warmup: bool = False) -> RunRecord:
    """Run one (scenario, algorithm, scale, seed) cell and measure it.

    ``warmup=True`` pre-compiles the trainer before the timed run so
    ``wall_s`` measures steady-state throughput, not JIT tracing — the
    sweep's figures live on the *virtual* clock, so only rows that report
    wall-clock rates (the dtype probe) need it.
    """
    scenario = build_scenario(spec, scenario_name, n, seed)
    run_kw, pool = _budgets(spec, scenario, is_reference=alg == spec.reference)
    trainer = build_trainer(spec, alg, n, seed, scenario=scenario,
                            dtype=dtype, batch_pool=pool)
    if warmup:
        trainer.warmup()
    t0 = time.time()
    res = trainer.run(**run_kw)
    wall = time.time() - t0
    t_target = res.time_to_loss(spec.target_loss)
    log(f"[xp] {scenario_name}/{alg}/N{n}/seed{seed}: "
        f"events={res.total_events} vtime={res.total_time:.1f} "
        f"loss={res.final_loss:.3f} "
        f"t_target={'%.2f' % t_target if t_target is not None else 'unreached'} "
        f"wall={wall:.1f}s")
    return RunRecord(scenario=scenario_name, algorithm=alg, n=n, seed=seed,
                     dtype=dtype or spec.dtype, wall_s=wall,
                     t_target=t_target, result=res)


def dtype_probe_rows(spec: ExperimentSpec,
                     log: Callable[[str], None] = lambda s: None
                     ) -> List[Dict[str, object]]:
    """bf16-vs-fp32 comparison row for the artifact (the dtype policy).

    One fixed cell (first scenario, first algorithm, the largest scale ≤ 64
    to keep it cheap) run under both dtype policies with an event budget, so
    the rows compare final loss and simulator throughput like-for-like.
    """
    scen = spec.scenarios[0]
    alg = spec.algorithms[0]
    n = max([s for s in spec.scales if s <= 64] or [min(spec.scales)])
    seed = spec.seeds[0]
    probe = spec.replace(max_events=spec.dtype_probe_events,
                         eval_every=max(1, spec.dtype_probe_events // 4))
    rows = []
    for dtype in ("float32", "bfloat16"):
        rec = run_cell(probe, scen, alg, n, seed, log=log, dtype=dtype,
                       warmup=True)
        rows.append({
            "dtype": dtype, "scenario": scen, "algorithm": alg, "n": n,
            "seed": seed, "events": rec.result.total_events,
            "final_loss": rec.result.final_loss,
            "final_metric": rec.result.final_metric,
            "wall_s": round(rec.wall_s, 3),
            "events_per_s": round(rec.result.total_events
                                  / max(rec.wall_s, 1e-9), 1),
        })
    return rows


def run_spec(spec: ExperimentSpec,
             log: Callable[[str], None] = lambda s: None) -> SweepResult:
    """The full sweep: scenario × scale × seed × (reference + algorithms)."""
    records: List[RunRecord] = []
    scenario_meta: Dict[str, Dict[str, object]] = {}
    for scen in spec.scenarios:
        scenario_meta[scen] = build_scenario(
            spec, scen, max(spec.scales), spec.seeds[0]).describe()
        for n in spec.scales:
            for seed in spec.seeds:
                algs = ((spec.reference,) if spec.reference else ()) \
                    + spec.algorithms
                for alg in algs:
                    records.append(
                        run_cell(spec, scen, alg, n, seed, log=log))
    dtype_rows = dtype_probe_rows(spec, log=log) if spec.dtype_probe else []
    return SweepResult(spec=spec, records=records, dtype_rows=dtype_rows,
                       scenario_meta=scenario_meta)


# ---------------------------------------------------------------------------
# Aggregation (mean ± std across seeds)
# ---------------------------------------------------------------------------

def _mean_std(vals: List[float]) -> Tuple[float, float]:
    arr = np.asarray(vals, dtype=np.float64)
    ok = arr[~np.isnan(arr)]
    if ok.size == 0:
        return float("nan"), float("nan")
    return float(ok.mean()), float(ok.std())


def speedup_rows(sweep: SweepResult) -> List[Dict[str, object]]:
    """Per (scenario, n, algorithm): speedup vs the sync reference.

    Speedup is computed per seed — t_sync(seed) / t_alg(seed) — then
    aggregated; a seed where either run never reached the target
    contributes NaN rather than polluting the mean with a fake 0.0.
    Algorithm and reference misses are counted separately (``unreached``
    vs ``unreached_ref``), and an algorithm's measured times-to-target are
    kept even when the reference's budget fell short, so "the algorithm
    never got there" and "the sync baseline never got there" stay
    distinguishable in the artifact.
    """
    spec = sweep.spec
    rows: List[Dict[str, object]] = []
    if not spec.reference:
        return rows
    for scen, n in sweep.cells():
        ref_by_seed = {r.seed: r.t_target
                       for r in sweep.select(scen, spec.reference, n)}
        for alg in spec.algorithms:
            recs = sweep.select(scen, alg, n)
            if not recs:
                continue
            speeds, t_alg, un_alg, un_ref = [], [], 0, 0
            for r in recs:
                t_ref = ref_by_seed.get(r.seed)
                if r.t_target is not None:
                    t_alg.append(r.t_target)
                else:
                    un_alg += 1
                if t_ref is None:
                    un_ref += 1
                if r.t_target is None or t_ref is None:
                    speeds.append(float("nan"))
                else:
                    speeds.append(t_ref / r.t_target)
            s_mean, s_std = _mean_std(speeds)
            t_mean, _ = _mean_std(t_alg or [float("nan")])
            tr_mean, _ = _mean_std(
                [t for t in ref_by_seed.values() if t is not None]
                or [float("nan")])
            rows.append({
                "scenario": scen, "n": n, "algorithm": alg,
                "speedup_mean": s_mean, "speedup_std": s_std,
                "t_target_mean": t_mean, "t_sync_mean": tr_mean,
                "n_seeds": len(recs), "unreached": un_alg,
                "unreached_ref": un_ref,
            })
    return rows


def telemetry_rows(sweep: SweepResult) -> List[Dict[str, object]]:
    """Per (scenario, n, algorithm): drained device-telemetry summary.

    Only populated when the spec ran with ``telemetry=True`` (each
    ``RunResult.telemetry`` carries ``repro.obs.metrics.metrics_summary``).
    Seed-aggregated: utilization and staleness means average across seeds,
    the staleness histogram and comm totals sum, and the DSGD-AAU
    ``staleness_bound`` monitor reports the worst seed.
    """
    spec = sweep.spec
    rows: List[Dict[str, object]] = []
    algs = ((spec.reference,) if spec.reference else ()) + spec.algorithms
    for scen, n in sweep.cells():
        for alg in algs:
            tels = [r.result.telemetry for r in sweep.select(scen, alg, n)
                    if r.result.telemetry is not None]
            if not tels:
                continue
            hist = np.sum([t["stale_hist"] for t in tels], axis=0)
            row: Dict[str, object] = {
                "scenario": scen, "n": n, "algorithm": alg,
                "n_seeds": len(tels),
                "utilization_mean": round(float(np.mean(
                    [t["utilization_mean"] for t in tels])), 6),
                "utilization_min": round(float(np.min(
                    [min(t["utilization"]) for t in tels])), 6),
                "stale_mean": round(float(np.mean(
                    [t["stale_mean"] for t in tels])), 6),
                "stale_max": int(max(t["stale_max"] for t in tels)),
                "stale_hist": [int(v) for v in hist],
                "comm_copies": int(sum(t["comm_copies"] for t in tels)),
                "grad_steps_total": int(sum(sum(t["grad_steps"])
                                            for t in tels)),
            }
            bounds = [t["staleness_bound"] for t in tels
                      if t.get("staleness_bound") is not None]
            if bounds:
                row["staleness_bound"] = {
                    "bound": bounds[0]["bound"],
                    "observed_max": max(b["observed_max"] for b in bounds),
                    "ok": all(b["ok"] for b in bounds),
                }
            occs = [t["bucket_occupancy"] for t in tels
                    if t.get("bucket_occupancy")]
            if occs:
                agg: Dict[int, Dict[str, float]] = {}
                for occ in occs:
                    for r_ in occ:
                        a = agg.setdefault(int(r_["A"]),
                                           {"events": 0, "lanes": 0.0})
                        a["events"] += int(r_["events"])
                        a["lanes"] += r_["lane_fill"] * r_["events"] * r_["A"]
                row["bucket_occupancy"] = [
                    {"A": A, "events": a["events"],
                     "lane_fill": round(a["lanes"] / (a["events"] * A), 6)}
                    for A, a in sorted(agg.items())]
            rows.append(row)
    return rows


def trace_rows(sweep: SweepResult) -> List[Dict[str, object]]:
    """Per (scenario, n, algorithm): wait-blame / straggler-tax summary.

    Only populated when the spec ran with ``trace=True`` (each
    ``RunResult.trace`` carries
    ``repro.obs.critical_path.straggler_tax``).  Seed-aggregated: the tax,
    critical-path wait fraction and blame concentration (largest single
    worker's share of total blame) average across seeds; ``blame_top`` is
    reported for the first seed, whose stream the recorded artifacts pin.
    """
    spec = sweep.spec
    rows: List[Dict[str, object]] = []
    algs = ((spec.reference,) if spec.reference else ()) + spec.algorithms
    for scen, n in sweep.cells():
        for alg in algs:
            trcs = [r.result.trace for r in sweep.select(scen, alg, n)
                    if r.result.trace is not None]
            if not trcs:
                continue
            conc = [
                (max(t["blame"]) / t["blame_total"])
                if t["blame_total"] > 0 else 0.0
                for t in trcs]
            rows.append({
                "scenario": scen, "n": n, "algorithm": alg,
                "n_seeds": len(trcs),
                "events": int(np.mean([t["events"] for t in trcs])),
                "straggler_tax_mean": round(float(np.mean(
                    [t["straggler_tax"] for t in trcs])), 6),
                "busy_t_mean": round(float(np.mean(
                    [t["busy_t"] for t in trcs])), 6),
                "wait_t_mean": round(float(np.mean(
                    [t["wait_t"] for t in trcs])), 6),
                "blame_total_mean": round(float(np.mean(
                    [t["blame_total"] for t in trcs])), 6),
                "residual_wait_mean": round(float(np.mean(
                    [t["residual_wait"] for t in trcs])), 6),
                "blame_concentration": round(float(np.mean(conc)), 6),
                "blame_top": trcs[0]["blame_top"],
                "cp_wait_frac_mean": round(float(np.mean(
                    [t["critical_path"]["wait_frac"] for t in trcs])), 6),
            })
    return rows


def convergence_rows(sweep: SweepResult,
                     max_points: int = 80) -> List[Dict[str, object]]:
    """Per (scenario, n, algorithm): loss-vs-virtual-time curve, seed-averaged.

    Histories are aligned by eval index (every seed evaluates on the same
    event grid) and truncated to the shortest seed; curves longer than
    ``max_points`` are subsampled evenly so the artifact stays readable.
    """
    spec = sweep.spec
    rows: List[Dict[str, object]] = []
    algs = ((spec.reference,) if spec.reference else ()) + spec.algorithms
    for scen, n in sweep.cells():
        for alg in algs:
            recs = sweep.select(scen, alg, n)
            if not recs:
                continue
            # The runner always appends a final eval point: on the eval
            # grid it duplicates the last grid point; in time-bounded runs
            # it sits off-grid at a per-seed event count.  Trim each seed's
            # duplicate, then aggregate only the prefix where every seed
            # evaluated at the *same* event count — never average one
            # seed's final eval with another's mid-run grid point.
            hists = []
            for r in recs:
                h = r.result.history
                if len(h) >= 2 and h[-1].k == h[-2].k:
                    h = h[:-1]
                hists.append(h)
            L = min(len(h) for h in hists)
            while L and not all(h[L - 1].k == hists[0][L - 1].k
                                for h in hists):
                L -= 1
            if L == 0:
                continue
            idx = np.unique(np.linspace(0, L - 1, min(L, max_points),
                                        dtype=int))
            points = []
            for i in idx:
                losses = [h[i].loss for h in hists]
                metrics = [h[i].metric for h in hists]
                times = [h[i].time for h in hists]
                lm, ls = _mean_std(losses)
                mm, _ = _mean_std(metrics)
                tm, _ = _mean_std(times)
                points.append({
                    "k": hists[0][i].k, "time_mean": round(tm, 4),
                    "loss_mean": round(lm, 5), "loss_std": round(ls, 5),
                    "metric_mean": round(mm, 5),
                })
            rows.append({"scenario": scen, "n": n, "algorithm": alg,
                         "n_seeds": len(recs), "points": points})
    return rows
