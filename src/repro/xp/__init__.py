"""Config-driven experiment harness: paper figures as declarative sweeps.

``ExperimentSpec`` (repro/xp/spec.py) names an algorithm × topology ×
scenario × scale × seeds sweep; ``run_spec`` executes it on the sparse scan
path with mean±std aggregation; ``artifact_payload``/``write_artifact``
emit the ``BENCH_paper_figures.json`` schema and the benchmark CSV rows.
``python -m repro.xp --smoke`` is the CI dry-run tier.
"""
from repro.xp.artifacts import (artifact_payload, csv_rows, load_artifact,
                                write_artifact)
from repro.xp.builders import (build_graph, build_scenario, build_trainer,
                               mlp2nn_eval, mlp2nn_init, mlp2nn_loss)
from repro.xp.presets import get_preset, paper_figures_spec, smoke_spec
from repro.xp.spec import ExperimentSpec
from repro.xp.sweep import (RunRecord, SweepResult, convergence_rows,
                            dtype_probe_rows, run_cell, run_spec,
                            speedup_rows)

__all__ = [
    "ExperimentSpec", "RunRecord", "SweepResult",
    "artifact_payload", "csv_rows", "load_artifact", "write_artifact",
    "build_graph", "build_scenario", "build_trainer",
    "mlp2nn_eval", "mlp2nn_init", "mlp2nn_loss",
    "get_preset", "paper_figures_spec", "smoke_spec",
    "convergence_rows", "dtype_probe_rows", "run_cell", "run_spec",
    "speedup_rows",
]
