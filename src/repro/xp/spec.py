"""Declarative experiment specification.

An :class:`ExperimentSpec` names everything that determines a paper-figure
sweep — algorithm set, topology, scenario set, scales, seeds, budgets,
execution mode and dtype policy — so a recorded artifact
(``BENCH_paper_figures.json``) embeds the spec and is exactly reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple

KNOWN_ALGS = ("dsgd_aau", "dsgd_sync", "ad_psgd", "prague", "agp")


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """algorithm × topology × scenario × scale × seeds, plus budgets.

    Budget semantics: each run is bounded by ``max_events`` if set, else by
    virtual time — ``max_time`` for the asynchronous algorithms and
    ``ref_max_time`` for the synchronous reference (the reference needs far
    more virtual time per iteration: every barrier waits for the slowest of
    n workers).  When ``time_scaled`` is on, time budgets are multiplied by
    the scenario's ``mean_duration_factor()`` so heavy-tailed regimes get
    the same effective number of local computations; batch pools are sized
    from that scaled budget (see ``repro.xp.sweep._budgets``), which bounds
    restarts per worker even for a worker that only draws fast durations.
    """

    name: str = "experiment"
    algorithms: Tuple[str, ...] = ("dsgd_aau", "ad_psgd", "prague", "agp")
    reference: Optional[str] = "dsgd_sync"
    scenarios: Tuple[str, ...] = ("paper_default",)
    scenario_kw: Mapping[str, Mapping[str, object]] = \
        dataclasses.field(default_factory=dict)
    scales: Tuple[int, ...] = (16, 32)
    seeds: Tuple[int, ...] = (0,)
    topology: str = "erdos_renyi"
    topology_kw: Mapping[str, object] = dataclasses.field(default_factory=dict)
    partition: str = "label_shard"
    data_seed: int = 0

    # execution
    mode: str = "sparse_scan"
    dtype: str = "float32"
    block_size: int = 32
    batch_pool: Optional[int] = None     # None → derived from the budget
    group_size: int = 4                  # prague
    horizon: Optional[int] = None        # single-edge event-horizon batching
    telemetry: bool = False              # device-resident per-worker counters
                                         # (repro.obs) recorded per cell
    trace: bool = False                  # event-identity tracing: wait-blame
                                         # / critical-path summary
                                         # (repro.obs.trace) per cell
    run_log: Optional[str] = None        # JSONL structured run-log path

    # budgets
    max_events: Optional[int] = None
    max_time: Optional[float] = 60.0
    ref_max_time: Optional[float] = 400.0
    ref_max_events: int = 160
    time_scaled: bool = True
    eval_every: int = 10
    ref_eval_every: int = 2

    # measurement
    target_loss: float = 0.9
    eta0: float = 0.2
    eta_decay: float = 0.999
    dtype_probe: bool = False            # record a bf16-vs-fp32 artifact row
    dtype_probe_events: int = 200

    def __post_init__(self):
        for field in ("algorithms", "scenarios", "scales", "seeds"):
            if not getattr(self, field):
                raise ValueError(f"spec needs at least one entry in {field}")
        for alg in self.algorithms + ((self.reference,) if self.reference else ()):
            if alg not in KNOWN_ALGS:
                raise KeyError(f"unknown algorithm {alg!r}; have {KNOWN_ALGS}")
        if self.mode not in ("scan", "sparse_scan", "per_event", "auto",
                             "fused"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.mode == "fused" and self.max_time is not None:
            raise ValueError(
                "mode='fused' keeps the virtual clock on device and is "
                "bounded by max_events only; set max_time=None")
        if not (self.max_events or self.max_time):
            raise ValueError("spec needs max_events or max_time")
        if any(n < 2 for n in self.scales):
            raise ValueError("scales must be worker counts >= 2")

    def replace(self, **kw) -> "ExperimentSpec":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["scenario_kw"] = {k: dict(v) for k, v in self.scenario_kw.items()}
        d["topology_kw"] = dict(self.topology_kw)
        return d
