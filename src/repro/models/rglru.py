"""RG-LRU recurrent block (Griffin / RecurrentGemma) [arXiv:2402.19427].

Real-Gated Linear Recurrent Unit:

    r_t = σ(W_a x_t + b_a)            recurrence gate
    i_t = σ(W_x x_t + b_x)            input gate
    a_t = a^{c·r_t},  a = σ(Λ)        per-channel data-gated decay (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

The recurrence is a diagonal first-order linear scan → evaluated with
``jax.lax.associative_scan`` (log-depth, TPU-friendly); the Pallas
``linear_scan`` kernel is the blocked on-chip version of the same operator.
The block wraps the RG-LRU with in/out projections, a short causal conv, and
a GeLU gate branch, as in Griffin.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init, matmul

_C = 8.0  # Griffin's fixed gate sharpness


class RGLRUState(NamedTuple):
    h: jax.Array          # (B, W) recurrent state
    conv: jax.Array       # (B, conv_width-1, W) trailing conv inputs

    @staticmethod
    def zeros(batch: int, cfg, dtype):
        w = cfg.rnn_width
        return RGLRUState(
            h=jnp.zeros((batch, w), jnp.float32),
            conv=jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
        )


def init_rglru_block(key, cfg) -> dict:
    d, w = cfg.d_model, cfg.rnn_width
    ks = jax.random.split(key, 6)
    return {
        "w_in": _dense_init(ks[0], (d, w), cfg.pdtype),
        "w_gate_branch": _dense_init(ks[1], (d, w), cfg.pdtype),
        "conv_kernel": (jax.random.normal(ks[2], (cfg.conv_width, w)) * 0.1
                        ).astype(cfg.pdtype),
        "conv_bias": jnp.zeros((w,), cfg.pdtype),
        "w_a": _dense_init(ks[3], (w, w), cfg.pdtype, scale=0.01),
        "b_a": jnp.zeros((w,), cfg.pdtype),
        "w_x": _dense_init(ks[4], (w, w), cfg.pdtype, scale=0.01),
        "b_x": jnp.zeros((w,), cfg.pdtype),
        "lam": jnp.full((w,), 2.0, cfg.pdtype),  # a = σ(Λ) ≈ 0.88 init
        "w_out": _dense_init(ks[5], (w, d), cfg.pdtype),
    }


def _causal_conv(x, kernel, bias, carry: Optional[jax.Array] = None):
    """Depthwise causal conv over T.  x: (B, T, W); kernel: (cw, W)."""
    cw = kernel.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    out = sum(
        xp[:, i:i + x.shape[1]] * kernel[i][None, None, :]
        for i in range(cw)
    )
    new_carry = xp[:, -(cw - 1):] if cw > 1 else carry
    return out + bias[None, None, :], new_carry


def rglru_scan(a, x_in, chunk: int = 256):
    """Diagonal linear recurrence  h_t = a_t·h_{t-1} + x_t,  h_0 = 0.

    Chunked: sequential ``lax.scan`` over T/chunk chunks carrying only the
    boundary state, log-depth ``associative_scan`` *within* each
    rematerialized chunk.  A single full-length associative scan saves
    O(T·log T) intermediates for backward — measured 132 GiB/device peak on
    the recurrentgemma train_4k dry-run; chunking bounds the live set to one
    chunk's tree (the same blocking the Pallas ``linear_scan`` kernel uses).
    a, x_in: (B, T, W) float32.
    """
    B, T, W = a.shape
    if T <= chunk or T % chunk:
        return _assoc_scan(a, x_in)

    n = T // chunk
    ar = a.reshape(B, n, chunk, W)
    xr = x_in.reshape(B, n, chunk, W)

    @jax.checkpoint
    def one_chunk(h0, ax):
        ac, xc = ax                              # (B, chunk, W)
        h = _assoc_scan(ac, xc)
        # fold the carried boundary state into every step of the chunk
        cum = jnp.exp(jnp.cumsum(jnp.log(jnp.clip(ac, 1e-30, None)), axis=1))
        h = h + cum * h0[:, None, :]
        return h[:, -1], h

    _, hs = jax.lax.scan(one_chunk, jnp.zeros((B, W), a.dtype),
                         (jnp.swapaxes(ar, 0, 1), jnp.swapaxes(xr, 0, 1)))
    return jnp.swapaxes(hs, 0, 1).reshape(B, T, W)


def _assoc_scan(a, x_in):
    def combine(left, right):
        a1, x1 = left
        a2, x2 = right
        return a1 * a2, a2 * x1 + x2

    aT, xT = jnp.swapaxes(a, 0, 1), jnp.swapaxes(x_in, 0, 1)
    _, h = jax.lax.associative_scan(combine, (aT, xT), axis=0)
    return jnp.swapaxes(h, 0, 1)


def apply_rglru_block(params, cfg, x, state: Optional[RGLRUState] = None):
    """x: (B, T, D) -> (out, new_state)."""
    B, T, D = x.shape
    gate = jax.nn.gelu(matmul(x, params["w_gate_branch"]).astype(jnp.float32))
    u = matmul(x, params["w_in"])
    u, conv_carry = _causal_conv(
        u, params["conv_kernel"], params["conv_bias"],
        state.conv if state is not None else None)
    u32 = u.astype(jnp.float32)
    r = jax.nn.sigmoid(matmul(u, params["w_a"]).astype(jnp.float32)
                       + params["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(matmul(u, params["w_x"]).astype(jnp.float32)
                       + params["b_x"].astype(jnp.float32))
    log_a = _C * r * jax.nn.log_sigmoid(params["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)                               # (B, T, W) in (0, 1)
    gated_in = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12, 1.0)) * (i * u32)
    w = params["w_in"].shape[1]
    h0 = state.h if state is not None else jnp.zeros((B, w), jnp.float32)
    if T == 1 and state is not None:
        h = (a[:, 0] * h0 + gated_in[:, 0])[:, None]
    else:
        h = rglru_scan(a, gated_in)
        if state is not None:  # prefill continuing from a state
            # fold h0 into every step: h_t += (prod_{s<=t} a_s)·h0
            cum = jnp.exp(jnp.cumsum(log_a, axis=1))
            h = h + cum * h0[:, None, :]
    y = (h * gate).astype(x.dtype)
    out = matmul(y, params["w_out"])
    new_state = RGLRUState(h=h[:, -1], conv=conv_carry)
    return out, new_state
