"""Stub modality frontends (the brief's one allowed stub).

``[audio]`` / ``[vlm]`` architectures specify the transformer backbone only;
the mel-spectrogram/EnCodec conv stack and the ViT/SigLIP encoder + projector
are *not* implemented.  These helpers produce the precomputed frame/patch
embeddings of the right shape — random for smoke tests, ShapeDtypeStruct for
the dry-run (see launch/shapes.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def prefix_shape(cfg: ModelConfig, batch: int):
    """(B, P, D) shape of the stub frontend's output embeddings."""
    assert cfg.frontend in ("audio", "vision"), cfg.frontend
    return (batch, cfg.n_prefix_tokens, cfg.d_model)


def make_stub_prefix(key, cfg: ModelConfig, batch: int, dtype=None):
    """Random placeholder embeddings standing in for the frozen frontend."""
    shape = prefix_shape(cfg, batch)
    return (jax.random.normal(key, shape) * 0.02).astype(dtype or cfg.cdtype)


def anyres_tile_count(image_hw, tile: int = 336, patches_per_tile: int = 576,
                      max_tiles: int = 4) -> int:
    """LLaVA-NeXT anyres tiling: #patches for an image (base tile + grid tiles).

    Used by examples/serving to size the prefix for a given image resolution;
    the assigned config pins the worst case (4 grid tiles + base = 2880).
    """
    h, w = image_hw
    gh, gw = -(-h // tile), -(-w // tile)
    n_tiles = min(gh * gw, max_tiles) + 1      # +1 global base tile
    return n_tiles * patches_per_tile
