"""Shared model primitives (functional, pure JAX).

Conventions:
  * params are plain dict pytrees; ``init_*`` builds them, ``apply_*`` runs them.
  * activations (B, T, D); attention heads (B, T, H, dh).
  * all matmuls accumulate in float32 (``preferred_element_type``).
  * attention over long sequences uses a blockwise (flash-style) jnp path so
    the 32k/500k shapes lower with O(T·block) live memory — the Pallas
    ``swa_attention`` kernel is the TPU-optimized version of the same math.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def matmul(x, w):
    return jnp.matmul(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta: float):
    """x: (B, T, H, dh); positions: (T,) or (B, T) absolute token positions."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                       # (dh/2,)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]   # (T, dh/2)
        ang = ang[None, :, None, :]                                     # (1,T,1,dh/2)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs          # (B,T,dh/2)
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional qk-norm, optional sliding window)
# ---------------------------------------------------------------------------

# Optional sharding-hint hook: the launch layer installs a callable
# ``hint(x, dims)`` (dims ∈ {"bqhd","bshd","bhqs","bhqd"}) that applies
# ``with_sharding_constraint`` with mesh-appropriate axes.  XLA's sharding
# propagation loses the batch/head partitioning through the flash-attention
# while-loop (measured: attention compute replicated across the data axis);
# these hints pin it.  Default None — single-device tests are unaffected.
_SHARD_HINT = None


def set_attention_shard_hint(fn):
    global _SHARD_HINT
    _SHARD_HINT = fn


def _hint(x, dims: str):
    return _SHARD_HINT(x, dims) if _SHARD_HINT is not None else x

def init_attention(key, cfg) -> dict:
    d, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, H * dh), cfg.pdtype),
        "wk": _dense_init(ks[1], (d, KV * dh), cfg.pdtype),
        "wv": _dense_init(ks[2], (d, KV * dh), cfg.pdtype),
        "wo": _dense_init(ks[3], (H * dh, d), cfg.pdtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(dh, cfg.pdtype)
        p["k_norm"] = init_rmsnorm(dh, cfg.pdtype)
    return p


def _plain_attention(q, k, v, positions_q, positions_k, window):
    """Materialized-scores path for short sequences / decode.

    q: (B, Tq, H, dh); k, v: (B, Tk, KV, dh).  GQA via head-group reshape.
    """
    B, Tq, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    # grouped (KV, G) einsum: decode reads the cache at its stored KV width —
    # repeating k/v to H heads here (as blockwise_attention does for sharding)
    # was measured to 5× the decode memory term by materializing G× the cache.
    qg = q.reshape(B, Tq, KV, G, dh)
    scores = jnp.einsum("btkgd,bskd->bkgts", qg, k,
                        preferred_element_type=jnp.float32) / np.sqrt(dh)
    valid = positions_k[:, None, :] >= 0 if positions_k.ndim == 2 else (positions_k >= 0)[None, None, :]
    pq = positions_q[:, :, None] if positions_q.ndim == 2 else positions_q[None, :, None]
    pk = positions_k[:, None, :] if positions_k.ndim == 2 else positions_k[None, None, :]
    mask = (pk <= pq) & valid                                  # causal + slot validity
    if window is not None:
        mask = mask & (pk > pq - window)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v, preferred_element_type=jnp.float32)
    return out.reshape(B, Tq, H, dh).astype(q.dtype)


def blockwise_attention(q, k, v, *, window: Optional[int] = None,
                        block_q: int = 512, block_k: int = 512):
    """Flash-style causal attention in pure jnp (self-attention, same length).

    Never materializes the (T, T) score matrix: scans q-blocks, and for each
    scans only the k-blocks that can be unmasked — for sliding-window
    attention that is the diagonal band of ``1 + ceil(window/block_k)``
    blocks, making compute O(T·window) instead of O(T²).
    """
    B, T0, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    # pad T up to a block multiple; padded keys sit at future positions the
    # causal mask excludes, padded query rows are sliced away at the end.
    lcm = int(np.lcm(block_q, block_k))
    T = -(-T0 // lcm) * lcm
    if T != T0:
        pad = ((0, 0), (0, T - T0), (0, 0), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    nq, nk = T // block_q, T // block_k
    scale = 1.0 / np.sqrt(dh)

    if window is not None:
        n_band = 1 + int(np.ceil((window + block_q - 1) / block_k))
    else:
        n_band = None

    # GQA: repeat the (small) k/v blocks up to full heads inside each step so
    # every einsum keeps a single whole head axis H — shardable H-ways over
    # the mesh ``model`` axis (a grouped (KV, G) layout caps head-sharding at
    # KV ways and replicates attention compute G× per device).
    qr = q.reshape(B, nq, block_q, H, dh)
    kr = k.reshape(B, nk, block_k, KV, dh)
    vr = v.reshape(B, nk, block_k, KV, dh)

    def q_block(qi, qb):
        # qb: (B, block_q, H, dh)
        qb = _hint(qb, "bqhd")
        pos_q = qi * block_q + jnp.arange(block_q)

        def kv_step(carry, kj):
            acc, m, l = carry
            kb = jax.lax.dynamic_index_in_dim(kr, kj, axis=1, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vr, kj, axis=1, keepdims=False)
            kb = _hint(jnp.repeat(kb, G, axis=2), "bshd")   # (B, bk, H, dh)
            vb = _hint(jnp.repeat(vb, G, axis=2), "bshd")
            pos_k = kj * block_k + jnp.arange(block_k)
            s = jnp.einsum("bqhd,bshd->bhqs", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            s = _hint(s, "bhqs")
            mask = pos_k[None, :] <= pos_q[:, None]
            if window is not None:
                mask = mask & (pos_k[None, :] > pos_q[:, None] - window)
            s = jnp.where(mask[None, None, :, :], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhqs,bshd->bhqd", p, vb.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (acc_new, m_new, l_new), None

        # derive the scan carries from qb (not jnp.zeros) so the SPMD
        # propagator has a sharding edge into the while-loop state — opaque
        # zero-init carries otherwise replicate the whole attention loop
        # across the data axis
        qT = jnp.swapaxes(qb, 1, 2).astype(jnp.float32)   # (B, H, bq, dh)
        acc0 = _hint(qT * 0.0, "bhqd")
        m0 = qT[..., 0] * 0.0 - 1e30
        l0 = qT[..., 0] * 0.0

        if n_band is None:
            kjs = jnp.arange(nk)
            # visit blocks 0..qi_max; fully-masked future blocks contribute 0
            # but we bound work by scanning only up to the causal frontier.
            limit = (qi * block_q + block_q - 1) // block_k + 1

            def body(c, kj):
                c2, _ = jax.lax.cond(
                    kj < limit, lambda c: kv_step(c, kj), lambda c: (c, None), c)
                return c2, None
            (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), kjs)
        else:
            hi = (qi * block_q + block_q - 1) // block_k     # diagonal block
            offs = jnp.arange(n_band)

            def body(c, off):
                kj = jnp.maximum(hi - off, 0)
                take = (hi - off) >= 0
                c2, _ = jax.lax.cond(take, lambda c: kv_step(c, kj),
                                     lambda c: (c, None), c)
                return c2, None
            (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), offs)

        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out  # (B, H, block_q, dh)

    # Rematerialize each q-block in the backward pass (flash-attention
    # semantics): without this, training saves every (bq, bk) score block —
    # O(T^2) activation memory.
    q_block_r = jax.checkpoint(q_block)
    outs = jax.lax.map(lambda qi: q_block_r(qi, qr[:, qi]), jnp.arange(nq))
    # outs: (nq, B, H, block_q, dh) -> (B, T, H, dh)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, T, H, dh)
    return out[:, :T0].astype(q.dtype)


@dataclasses.dataclass
class KVCache:
    """Rolling KV cache: ``size`` slots; absolute positions tracked per slot."""
    k: jax.Array          # (B, size, KV, dh)
    v: jax.Array          # (B, size, KV, dh)
    positions: jax.Array  # (size,) int32 absolute position of each slot (-1 empty)

    @staticmethod
    def empty(batch, size, kv_heads, d_head, dtype):
        return KVCache(
            k=jnp.zeros((batch, size, kv_heads, d_head), dtype),
            v=jnp.zeros((batch, size, kv_heads, d_head), dtype),
            positions=jnp.full((size,), -1, jnp.int32),
        )


jax.tree_util.register_dataclass(KVCache, data_fields=["k", "v", "positions"],
                                 meta_fields=[])


def build_cache_from_kv(k, v, positions, size: int) -> KVCache:
    """Rolling cache holding the last ``size`` positions of a prefilled k/v."""
    B, T, KV, dh = k.shape
    n = min(T, size)
    ks, vs = k[:, T - n:], v[:, T - n:]
    pos_tail = positions[T - n:].astype(jnp.int32)
    slots = pos_tail % size
    ck = jnp.zeros((B, size, KV, dh), k.dtype).at[:, slots].set(ks)
    cv = jnp.zeros((B, size, KV, dh), v.dtype).at[:, slots].set(vs)
    cpos = jnp.full((size,), -1, jnp.int32).at[slots].set(pos_tail)
    return KVCache(k=ck, v=cv, positions=cpos)


def apply_attention(params, cfg, x, positions, *, cache: Optional[KVCache] = None,
                    window: Optional[int] = None, block_size: int = 512,
                    build_cache: Optional[int] = None):
    """Self-attention forward.

    Prefill/train: ``cache is None`` — full-sequence causal attention
    (blockwise when T > 2·block_size); with ``build_cache=size`` also returns
    a rolling KVCache of the last ``size`` positions.
    Decode: ``cache`` given and T == 1 — appends the token at slot
    ``positions[0] % size`` (rolling) and attends over the cache.
    Returns (out, new_cache).
    """
    B, T, d = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = matmul(x, params["wq"]).reshape(B, T, H, dh)
    k = matmul(x, params["wk"]).reshape(B, T, KV, dh)
    v = matmul(x, params["wv"]).reshape(B, T, KV, dh)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        if T > 2 * block_size:
            out = blockwise_attention(q, k, v, window=window,
                                      block_q=block_size, block_k=block_size)
        else:
            pos = positions if positions.ndim == 1 else positions[0]
            out = _plain_attention(q, k, v, pos, pos, window)
        new_cache = None
        if build_cache is not None:
            pos1 = positions if positions.ndim == 1 else positions[0]
            new_cache = build_cache_from_kv(k, v, pos1, build_cache)
    else:
        assert T == 1, "cache path is single-token decode"
        size = cache.k.shape[1]
        pos = positions[0] if positions.ndim == 1 else positions[0, 0]
        slot = (pos % size).astype(jnp.int32)
        ck = jax.lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))
        cpos = jax.lax.dynamic_update_slice(cache.positions,
                                            pos[None].astype(jnp.int32), (slot,))
        out = _plain_attention(q, ck, cv, pos[None], cpos, window)
        new_cache = KVCache(k=ck, v=cv, positions=cpos)
    out = out.reshape(B, T, H * dh)
    return matmul(out, params["wo"]), new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, f: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(k1, (d, f), dtype),
        "w_up": _dense_init(k2, (d, f), dtype),
        "w_down": _dense_init(k3, (f, d), dtype),
    }


def apply_mlp(params, x):
    g = jax.nn.silu(matmul(x, params["w_gate"]).astype(jnp.float32)).astype(x.dtype)
    u = matmul(x, params["w_up"])
    return matmul(g * u, params["w_down"])


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x):
    return jnp.matmul(x, params["table"].T, preferred_element_type=jnp.float32)
