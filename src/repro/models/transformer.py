"""Composable decoder model covering all six assigned families.

One functional model with per-family block wiring:

  dense / audio / vlm : [RMSNorm → GQA-attn → +] [RMSNorm → SwiGLU → +]   × L (scan)
  moe                 : same with MoE FFN (+ optional dense residual)      × L (scan)
  ssm (rwkv6)         : [LN → time-mix → +] [LN → channel-mix → +]         × L (scan)
  hybrid (griffin)    : pattern ("rec","rec","attn") — RG-LRU / local-attn   (unrolled)

Homogeneous stacks scan over layer-stacked parameters (compact HLO for the
95-layer dry-runs); the 26-layer hybrid pattern is unrolled.  ``audio`` and
``vlm`` consume stub-frontend prefix embeddings prepended to the token stream
(the brief's one allowed stub).  Decode state is a per-layer pytree: KVCache
(attention), RWKVState (ssm), or (RGLRUState | KVCache) for hybrid.

Three entry points share one layer-runner:
  * ``forward``    — train/eval full-sequence logits (+ chunked-CE ``lm_loss``)
  * ``prefill``    — full sequence, returns last-token logits + decode state
  * ``decode_step``— one token against the decode state (serve_step)
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import rwkv as RW
from repro.models.layers import KVCache


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------

def _init_attn_layer(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "attn": L.init_attention(k1, cfg),
        "ln2": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
    }
    if cfg.family == "moe":
        p["ffn"] = MOE.init_moe(k2, cfg)
    else:
        p["ffn"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.pdtype)
    return p


def _apply_attn_layer(p, cfg, x, positions, state, window, build_cache=None):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn_out, new_state = L.apply_attention(
        p["attn"], cfg, h, positions, cache=state, window=window,
        build_cache=build_cache)
    x = x + attn_out
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.family == "moe":
        ffn_out, aux = MOE.apply_moe(p["ffn"], cfg, h)
    else:
        ffn_out, aux = L.apply_mlp(p["ffn"], h), jnp.float32(0)
    return x + ffn_out, new_state, aux


def _init_rwkv_layer(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "time_mix": RW.init_time_mix(k1, cfg),
        "ln2": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "channel_mix": RW.init_channel_mix(k2, cfg),
    }


def _apply_rwkv_layer(p, cfg, x, state: Optional[RW.RWKVState]):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    tm_out, S_new, last_tm = RW.apply_time_mix(p["time_mix"], cfg, h, state)
    x = x + tm_out
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    cm_out, last_cm = RW.apply_channel_mix(
        p["channel_mix"], h, state.shift_cm if state is not None else None)
    x = x + cm_out
    new_state = RW.RWKVState(shift_tm=last_tm, shift_cm=last_cm, S=S_new)
    return x, new_state, jnp.float32(0)


def _init_rec_layer(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "rec": RG.init_rglru_block(k1, cfg),
        "ln2": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "ffn": L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.pdtype),
    }


def _apply_rec_layer(p, cfg, x, state: Optional[RG.RGLRUState]):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    rec_out, new_state = RG.apply_rglru_block(p["rec"], cfg, h, state)
    x = x + rec_out
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + L.apply_mlp(p["ffn"], h)
    return x, new_state, jnp.float32(0)


_INIT = {"attn": _init_attn_layer, "rwkv": _init_rwkv_layer, "rec": _init_rec_layer}


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------

def block_pattern(cfg: ModelConfig) -> Tuple[str, ...]:
    if cfg.family == "ssm":
        return tuple(["rwkv"] * cfg.n_layers)
    return cfg._pattern_expanded()


def _stacked(pattern) -> bool:
    return len(set(pattern)) == 1


def init_model(key, cfg: ModelConfig) -> dict:
    ke, kl, kh = jax.random.split(key, 3)
    params: dict = {"embed": L.init_embedding(ke, cfg.vocab_size, cfg.d_model,
                                              cfg.pdtype)}
    pattern = block_pattern(cfg)
    keys = jax.random.split(kl, cfg.n_layers)
    if _stacked(pattern):
        init_one = _INIT[pattern[0]]
        params["layers"] = jax.vmap(lambda k: init_one(k, cfg))(keys)
    else:
        params["layers"] = tuple(
            _INIT[pt](k, cfg) for pt, k in zip(pattern, keys))
    params["final_norm"] = L.init_rmsnorm(cfg.d_model, cfg.pdtype)
    if not cfg.tie_embeddings:
        params["head"] = {"w": L._dense_init(kh, (cfg.d_model, cfg.vocab_size),
                                             cfg.pdtype, scale=0.02)}
    return params


def param_count(cfg: ModelConfig) -> int:
    """Exact parameter count from abstract init (no allocation)."""
    import numpy as np
    shapes = jax.eval_shape(lambda k: init_model(k, cfg), jax.random.PRNGKey(0))
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(shapes)))


def active_param_count(cfg: ModelConfig) -> int:
    """Per-token active parameters (MoE counts only top-k experts)."""
    total = param_count(cfg)
    if cfg.family != "moe":
        return total
    per_expert = 3 * cfg.d_model * cfg.d_ff
    inactive = cfg.n_layers * (cfg.n_experts - cfg.top_k) * per_expert
    return total - inactive


# ---------------------------------------------------------------------------
# Unified layer runner
# ---------------------------------------------------------------------------

def _run_layers(params, cfg: ModelConfig, x, positions, *, states=None,
                build_cache: Optional[int] = None, remat: bool = False):
    """Run all blocks.  Returns (x, aux, new_states_or_None).

    states given           → decode / continued prefill (per-layer state in/out)
    build_cache = size     → prefill: construct decode states
    neither                → plain training forward
    """
    window = cfg.attn_window
    pattern = block_pattern(cfg)
    collect = (states is not None) or (build_cache is not None)

    def run_one(kind, lp, x, st):
        if kind == "attn":
            bc = build_cache if states is None else None
            if bc is not None and window:
                bc = min(bc, window)
            return _apply_attn_layer(lp, cfg, x, positions, st, window, bc)
        if kind == "rwkv":
            return _apply_rwkv_layer(lp, cfg, x, st)
        return _apply_rec_layer(lp, cfg, x, st)

    if _stacked(pattern):
        kind = pattern[0]
        if states is None:
            def body(x, lp):
                x, st2, aux = run_one(kind, lp, x, None)
                return x, (aux, st2) if collect else aux
            if remat:
                body = jax.checkpoint(body)
            x, ys = jax.lax.scan(body, x, params["layers"])
            auxs, new_states = ys if collect else (ys, None)
        else:
            def body(x, xs):
                lp, st = xs
                x, st2, aux = run_one(kind, lp, x, st)
                return x, (aux, st2)
            if remat:
                body = jax.checkpoint(body)
            x, (auxs, new_states) = jax.lax.scan(body, x, (params["layers"], states))
        return x, jnp.sum(auxs), new_states

    # unrolled hybrid pattern
    aux = jnp.float32(0)
    new_states = []
    for i, (pt, lp) in enumerate(zip(pattern, params["layers"])):
        st = states[i] if states is not None else None
        fn = (lambda x, st, pt=pt, lp=lp: run_one(pt, lp, x, st))
        if remat:
            fn = jax.checkpoint(fn)
        x, st2, a = fn(x, st)
        aux = aux + a
        new_states.append(st2)
    return x, aux, (tuple(new_states) if collect else None)


def _logits(params, cfg, x):
    if cfg.tie_embeddings:
        return L.unembed(params["embed"], x)
    return jnp.matmul(x, params["head"]["w"], preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, tokens, prefix_embeds=None,
            remat: bool = False):
    """tokens: (B, T) int32; prefix_embeds: (B, P, D) or None.

    Returns (logits (B, T_text, V), aux_loss) — logits cover text positions
    only (prefix positions are conditioning, not predicted).
    """
    x = L.embed(params["embed"], tokens).astype(cfg.cdtype)
    n_prefix = 0
    if prefix_embeds is not None:
        n_prefix = prefix_embeds.shape[1]
        x = jnp.concatenate([prefix_embeds.astype(cfg.cdtype), x], axis=1)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, aux, _ = _run_layers(params, cfg, x, positions, remat=remat)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if n_prefix:
        x = x[:, n_prefix:]
    return _logits(params, cfg, x), aux


def lm_loss(params, cfg: ModelConfig, batch, remat: bool = False,
            aux_weight: float = 0.01, logit_chunk: Optional[int] = None):
    """Next-token cross-entropy.  batch: {tokens (B,T), [prefix (B,P,D)]}.

    ``logit_chunk`` computes the unembed + CE in rematerialized sequence
    chunks so the (B, T, vocab) logits tensor is never alive at once — the
    standard memory fix for 100k+ vocabularies at 4k sequence length.
    """
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens).astype(cfg.cdtype)
    n_prefix = 0
    if batch.get("prefix") is not None:
        n_prefix = batch["prefix"].shape[1]
        x = jnp.concatenate([batch["prefix"].astype(cfg.cdtype), x], axis=1)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, aux, _ = _run_layers(params, cfg, x, positions, remat=remat)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if n_prefix:
        x = x[:, n_prefix:]
    # shift: predict token t+1 from hidden t
    x = x[:, :-1]
    targets = tokens[:, 1:]

    def ce(xc, tc):
        logits = L._hint(_logits(params, cfg, xc), "bqv")  # chunk dim shardable
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, tc[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        return nll.sum()

    Tm1 = x.shape[1]
    if logit_chunk and Tm1 > logit_chunk:
        # full chunks via a rematerialized scan + one remainder chunk, so the
        # (B, T, vocab) logits tensor never exists whole (T−1 is never a
        # multiple of the chunk — the shift costs one token)
        nc, rem = divmod(Tm1, logit_chunk)
        ce_r = jax.checkpoint(ce)
        xr = x[:, :nc * logit_chunk].reshape(
            x.shape[0], nc, logit_chunk, x.shape[-1])
        tr = targets[:, :nc * logit_chunk].reshape(
            targets.shape[0], nc, logit_chunk)

        def chunk_body(tot, i):
            return tot + ce_r(xr[:, i], tr[:, i]), None
        total, _ = jax.lax.scan(chunk_body, jnp.float32(0), jnp.arange(nc))
        if rem:
            total = total + ce_r(x[:, nc * logit_chunk:],
                                 targets[:, nc * logit_chunk:])
    else:
        total = ce(x, targets)
    n_tok = targets.shape[0] * targets.shape[1]
    return total / n_tok + aux_weight * aux


def prefill(params, cfg: ModelConfig, tokens, cache_len: int,
            prefix_embeds=None):
    """Full-sequence prefill.  Returns (last-token logits (B, V), decode state)."""
    x = L.embed(params["embed"], tokens).astype(cfg.cdtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.cdtype), x], axis=1)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, _, states = _run_layers(params, cfg, x, positions, build_cache=cache_len)
    x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    return _logits(params, cfg, x)[:, 0], states


def init_decode_state(cfg: ModelConfig, batch: int, cache_len: int,
                      filled: bool = False):
    """Per-layer decode state sized for a KV history of ``cache_len``.

    For sliding-window archs the attention cache is ``min(window, cache_len)``
    slots (rolling) — the memory saving that makes long_500k feasible.
    ``filled`` marks slots as holding positions [cache_len − size, cache_len).
    """
    window = cfg.attn_window
    attn_len = min(window, cache_len) if window else cache_len
    dt = cfg.cdtype

    def attn_state():
        c = KVCache.empty(batch, attn_len, cfg.n_kv_heads, cfg.d_head, dt)
        if filled:
            pos = jnp.arange(cache_len - attn_len, cache_len, dtype=jnp.int32)
            slots = pos % attn_len
            c = KVCache(k=c.k, v=c.v,
                        positions=jnp.zeros((attn_len,), jnp.int32
                                            ).at[slots].set(pos))
        return c

    pattern = block_pattern(cfg)
    if cfg.family == "ssm":
        st = RW.RWKVState.zeros(batch, cfg, dt)
        return jax.tree.map(lambda x: jnp.stack([x] * cfg.n_layers), st)
    if _stacked(pattern):
        sts = [attn_state() for _ in range(cfg.n_layers)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *sts)
    return tuple(attn_state() if pt == "attn" else RG.RGLRUState.zeros(batch, cfg, dt)
                 for pt in pattern)


def decode_step(params, cfg: ModelConfig, token, state, pos):
    """One decode step (serve_step).  token: (B,); pos: () absolute position.

    Returns (logits (B, V), new_state).
    """
    x = L.embed(params["embed"], token[:, None]).astype(cfg.cdtype)
    positions = pos[None].astype(jnp.int32)
    x, _, new_state = _run_layers(params, cfg, x, positions, states=state)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, x)[:, 0], new_state
