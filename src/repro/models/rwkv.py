"""RWKV6 ("Finch") blocks — attention-free with data-dependent decay [arXiv:2404.05892].

Per head (dims K = V = head size), with receptance r, key k, value v, decay w
and bonus u, the recurrence is

    y_t = r_tᵀ (S_{t-1} + diag(u) k_t v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

The decay w_t = exp(-exp(w0 + lora(x_t))) is *data-dependent* — Finch's
headline feature.  Training/prefill uses a chunked parallel form (intra-chunk
matmuls on the MXU + inter-chunk state carry — the same tiling realized by the
Pallas ``linear_scan`` kernel), decode is the O(1) single-step update.  The
recurrent state is an activation, never gossiped (DESIGN.md §6).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init, init_rmsnorm, matmul, rmsnorm


class RWKVState(NamedTuple):
    """Decode-time state: last token embedding shifts + per-head matrix state."""
    shift_tm: jax.Array   # (B, D) previous token's input to time-mix
    shift_cm: jax.Array   # (B, D) previous token's input to channel-mix
    S: jax.Array          # (B, H, K, V) matrix state

    @staticmethod
    def zeros(batch: int, cfg, dtype):
        H = cfg.d_model // cfg.rwkv_head_dim
        K = cfg.rwkv_head_dim
        return RWKVState(
            shift_tm=jnp.zeros((batch, cfg.d_model), dtype),
            shift_cm=jnp.zeros((batch, cfg.d_model), dtype),
            S=jnp.zeros((batch, H, K, K), jnp.float32),
        )


def init_time_mix(key, cfg) -> dict:
    d = cfg.d_model
    K = cfg.rwkv_head_dim
    H = d // K
    lora = max(32, d // 32)
    ks = jax.random.split(key, 10)
    return {
        "mu_r": jnp.full((d,), 0.5, cfg.pdtype),
        "mu_k": jnp.full((d,), 0.5, cfg.pdtype),
        "mu_v": jnp.full((d,), 0.5, cfg.pdtype),
        "mu_g": jnp.full((d,), 0.5, cfg.pdtype),
        "mu_w": jnp.full((d,), 0.5, cfg.pdtype),
        "w_r": _dense_init(ks[0], (d, d), cfg.pdtype),
        "w_k": _dense_init(ks[1], (d, d), cfg.pdtype),
        "w_v": _dense_init(ks[2], (d, d), cfg.pdtype),
        "w_g": _dense_init(ks[3], (d, d), cfg.pdtype),
        "w_o": _dense_init(ks[4], (d, d), cfg.pdtype),
        # data-dependent decay: w_t = exp(-exp(w0 + B·tanh(A·x)))
        "decay_w0": jnp.full((d,), -2.0, cfg.pdtype),
        "decay_A": _dense_init(ks[5], (d, lora), cfg.pdtype, scale=0.01),
        "decay_B": _dense_init(ks[6], (lora, d), cfg.pdtype, scale=0.01),
        "bonus_u": (jax.random.normal(ks[7], (H, K)) * 0.05).astype(cfg.pdtype),
        "out_norm": init_rmsnorm(d, cfg.pdtype),
    }


def init_channel_mix(key, cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, cfg.pdtype),
        "mu_r": jnp.full((d,), 0.5, cfg.pdtype),
        "w_k": _dense_init(ks[0], (d, f), cfg.pdtype),
        "w_v": _dense_init(ks[1], (f, d), cfg.pdtype),
        "w_r": _dense_init(ks[2], (d, d), cfg.pdtype),
    }


def _token_shift(x, x_prev_last: Optional[jax.Array] = None):
    """x_{t-1} per position; position 0 sees ``x_prev_last`` (decode carry) or 0."""
    prev = jnp.roll(x, 1, axis=1)
    first = jnp.zeros_like(x[:, :1]) if x_prev_last is None else x_prev_last[:, None]
    return prev.at[:, :1].set(first)


def _lerp(mu, x, x_prev):
    return x + (x_prev - x) * mu.astype(x.dtype)


def chunked_rwkv(r, k, v, w, u, S0, chunk: int = 64):
    """Chunked parallel evaluation of the RWKV6 recurrence.

    r/k/w: (B, H, T, K); v: (B, H, T, V); u: (H, K); S0: (B, H, K, V).
    Returns (y (B, H, T, V), S_T).  All math in float32.
    """
    B, H, T, K = r.shape
    V = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    n = T // chunk
    f32 = jnp.float32
    r, k, v, w = (a.astype(f32) for a in (r, k, v, w))
    rc = r.reshape(B, H, n, chunk, K)
    kc = k.reshape(B, H, n, chunk, K)
    vc = v.reshape(B, H, n, chunk, V)
    wc = w.reshape(B, H, n, chunk, K)
    logw = jnp.log(jnp.clip(wc, 1e-6, 1.0))
    logA = jnp.cumsum(logw, axis=3)                  # inclusive cumulative log-decay
    A = jnp.exp(logA)                                # prod_{s<=t} w_s
    Aprev = jnp.exp(logA - logw)                     # prod_{s<t}  w_s
    kscaled = kc / jnp.clip(A, 1e-20, None)          # k_s / A_s

    # strictly-lower-triangular intra-chunk interaction
    tri = jnp.tril(jnp.ones((chunk, chunk), f32), k=-1)

    def step(S, ci):
        rcb, kcb, vcb, Ab, Apb, ksb = (
            rc[:, :, ci], kc[:, :, ci], vc[:, :, ci], A[:, :, ci],
            Aprev[:, :, ci], kscaled[:, :, ci])
        rA = rcb * Apb                               # (B,H,c,K)
        # cross-chunk contribution: (r_t ⊙ A_{t-1})ᵀ S0
        y_cross = jnp.einsum("bhtk,bhkv->bhtv", rA, S)
        # intra-chunk: Σ_{s<t} ((r_t⊙A_{t-1})·(k_s/A_s)) v_s
        qk = jnp.einsum("bhtk,bhsk->bhts", rA, ksb) * tri[None, None]
        y_intra = jnp.einsum("bhts,bhsv->bhtv", qk, vcb)
        # current-token bonus: u·(r_t·k_t) v_t
        bonus = jnp.einsum("bhtk,bhtk->bht", rcb * u[None, :, None, :], kcb)
        y_self = bonus[..., None] * vcb
        y = y_cross + y_intra + y_self
        # carry: S' = diag(A_c) S + Σ_s diag(A_c/A_s) k_s v_sᵀ
        Ac = Ab[:, :, -1]                            # (B,H,K)
        kAc = ksb * Ac[:, :, None, :]
        S_new = Ac[..., None] * S + jnp.einsum("bhsk,bhsv->bhkv", kAc, vcb)
        return S_new, y

    S_T, ys = jax.lax.scan(step, S0.astype(f32), jnp.arange(n))
    y = jnp.moveaxis(ys, 0, 2).reshape(B, H, T, V)
    return y, S_T


def rwkv_step(r, k, v, w, u, S):
    """Single decode step: r/k/w (B, H, K); v (B, H, V); S (B, H, K, V)."""
    f32 = jnp.float32
    r, k, v, w = (a.astype(f32) for a in (r, k, v, w))
    kv = k[..., :, None] * v[..., None, :]           # (B,H,K,V)
    y = jnp.einsum("bhk,bhkv->bhv", r, S + u[None, :, :, None] * kv)
    S_new = w[..., None] * S + kv
    return y, S_new


def _decay(params, xw):
    dd = jnp.tanh(matmul(xw, params["decay_A"]))
    dd = matmul(dd, params["decay_B"])
    return jnp.exp(-jnp.exp(
        params["decay_w0"].astype(jnp.float32) + dd.astype(jnp.float32)))


def apply_time_mix(params, cfg, x, state: Optional[RWKVState] = None, chunk: int = 64):
    """Time-mix over a sequence (training/prefill) or one step (decode).

    x: (B, T, D).  Returns (out, new_S, last_x) where new_S/last_x feed decode.
    """
    B, T, D = x.shape
    K = cfg.rwkv_head_dim
    H = D // K
    prev = _token_shift(x, state.shift_tm if state is not None else None)
    xr = _lerp(params["mu_r"], x, prev)
    xk = _lerp(params["mu_k"], x, prev)
    xv = _lerp(params["mu_v"], x, prev)
    xg = _lerp(params["mu_g"], x, prev)
    xw = _lerp(params["mu_w"], x, prev)
    r = matmul(xr, params["w_r"]).reshape(B, T, H, K).transpose(0, 2, 1, 3)
    k = matmul(xk, params["w_k"]).reshape(B, T, H, K).transpose(0, 2, 1, 3)
    v = matmul(xv, params["w_v"]).reshape(B, T, H, K).transpose(0, 2, 1, 3)
    g = jax.nn.silu(matmul(xg, params["w_g"]).astype(jnp.float32)).astype(x.dtype)
    w = _decay(params, xw).reshape(B, T, H, K).transpose(0, 2, 1, 3)
    u = params["bonus_u"].astype(jnp.float32)
    S0 = (state.S if state is not None
          else jnp.zeros((B, H, K, K), jnp.float32))
    if T == 1:
        y, S_new = rwkv_step(r[:, :, 0], k[:, :, 0], v[:, :, 0], w[:, :, 0], u, S0)
        y = y[:, :, None]                            # (B,H,1,V)
    else:
        c = chunk if T % chunk == 0 else (T if T < chunk else 1)
        y, S_new = chunked_rwkv(r, k, v, w, u, S0, chunk=c)
    y = y.transpose(0, 2, 1, 3).reshape(B, T, D).astype(x.dtype)
    y = rmsnorm(params["out_norm"], y, cfg.norm_eps) * g
    out = matmul(y, params["w_o"])
    return out, S_new, x[:, -1]


def apply_channel_mix(params, x, state_prev: Optional[jax.Array] = None):
    prev = _token_shift(x, state_prev)
    xk = _lerp(params["mu_k"], x, prev)
    xr = _lerp(params["mu_r"], x, prev)
    kk = matmul(xk, params["w_k"])
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    rr = jax.nn.sigmoid(matmul(xr, params["w_r"]).astype(jnp.float32)).astype(x.dtype)
    return rr * matmul(kk, params["w_v"]), x[:, -1]
