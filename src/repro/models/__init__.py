from repro.models import layers, moe, multimodal, rglru, rwkv, transformer
from repro.models.transformer import (
    active_param_count,
    block_pattern,
    decode_step,
    forward,
    init_decode_state,
    init_model,
    lm_loss,
    param_count,
)
