"""Mixture-of-Experts FFN (grok-1: 8e top-2; arctic: 128e top-2 + dense residual).

Capacity-based dispatch (Switch-style) implemented with scatter/gather rather
than one-hot dispatch tensors: the (tokens × experts × capacity) einsum
formulation costs O(N·E·C) memory — infeasible at arctic scale (1M tokens ×
128 experts) — whereas scatter-add dispatch + gather combine is O(E·C·d + N·k·d).
Compute is O(top_k · T · d · f): MoE FLOPs in the roofline are *active* FLOPs.
Expert weights carry a leading E axis sharded over the mesh ``model`` axis
(expert parallelism); dispatch/combine lower to all-to-all / collective
scatter-gather under pjit.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init, matmul


def init_moe(key, cfg) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, E), cfg.pdtype, scale=0.02),
        "w_gate": _dense_init(ks[1], (E, d, f), cfg.pdtype),
        "w_up": _dense_init(ks[2], (E, d, f), cfg.pdtype),
        "w_down": _dense_init(ks[3], (E, f, d), cfg.pdtype),
    }
    if cfg.dense_residual_ff:
        from repro.models.layers import init_mlp
        p["dense_residual"] = init_mlp(ks[4], d, cfg.dense_residual_ff, cfg.pdtype)
    return p


def _top_k_gating(logits: jax.Array, top_k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (gates (N, k) renormalized, expert_idx (N, k), aux load-balance loss)."""
    N, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)
    gates = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    # Switch/GShard load-balance loss: E · Σ_e fraction_e · mean_prob_e
    me = probs.mean(axis=0)                                   # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (N * top_k))
    aux = E * jnp.sum(me * ce)
    return gates, expert_idx, aux


def apply_moe(params, cfg, x, *, capacity_factor: float = None):
    """x: (B, T, d) -> (out (B, T, d), aux_loss scalar).

    ``cfg.moe_groups > 1`` splits tokens into G independent dispatch groups
    (GShard): gating/position bookkeeping is local to a group (aligned with
    the mesh data shards), and the grouped (G, E, C, d) expert buffers give
    the partitioner a clean G↔E all-to-all instead of a global scatter
    across the data axis.
    """
    B, T, d = x.shape
    G = max(1, cfg.moe_groups)
    N = B * T
    if G > 1 and N % G == 0 and N // G >= cfg.n_experts:
        xg = x.reshape(G, N // G, d)
        outs, auxs = jax.vmap(
            lambda xt: _moe_tokens(params, cfg, xt, capacity_factor))(xg)
        return outs.reshape(B, T, d), jnp.mean(auxs)
    out, aux = _moe_tokens(params, cfg, x.reshape(N, d), capacity_factor)
    return out.reshape(B, T, d), aux


def _moe_tokens(params, cfg, xt, capacity_factor=None):
    """Dispatch/compute/combine for a flat (N, d) token group."""
    N, d = xt.shape
    E, k = cfg.n_experts, cfg.top_k
    logits = matmul(xt, params["router"])                     # (N, E)
    gates, expert_idx, aux = _top_k_gating(logits, k)
    cf = cfg.moe_capacity_factor if capacity_factor is None else capacity_factor
    capacity = max(4, int(cf * k * N / E))

    # position of each (token, choice) within its expert's capacity buffer,
    # via a cumulative count of earlier routings to the same expert.
    onehot = jax.nn.one_hot(expert_idx.reshape(N * k), E, dtype=jnp.int32)
    pos_flat = (jnp.cumsum(onehot, axis=0) - onehot)
    pos = jnp.take_along_axis(
        pos_flat, expert_idx.reshape(N * k, 1), axis=1).reshape(N, k)
    keep = pos < capacity
    gates = gates * keep.astype(gates.dtype)
    safe_pos = jnp.where(keep, pos, capacity - 1)

    # dispatch: scatter-add tokens into (E, C, d) expert buffers
    vals = xt[:, None, :] * keep[..., None].astype(xt.dtype)  # (N, k, d)
    expert_in = jnp.zeros((E, capacity, d), xt.dtype).at[
        expert_idx, safe_pos].add(vals, mode="drop")

    # expert FFN (SwiGLU) batched over E
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"],
                               preferred_element_type=jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"],
                   preferred_element_type=jnp.float32)
    h = (g * u).astype(xt.dtype)
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["w_down"],
                            preferred_element_type=jnp.float32).astype(xt.dtype)

    # combine: gather each token's expert outputs back, weight by gates
    gathered = expert_out[expert_idx, safe_pos]               # (N, k, d)
    out = jnp.sum(gathered * gates[..., None].astype(xt.dtype), axis=1)

    if cfg.dense_residual_ff:
        from repro.models.layers import apply_mlp
        out = out + apply_mlp(params["dense_residual"], xt[None])[0]
    return out, aux
