"""repro: straggler-resilient decentralized learning (DSGD-AAU) in JAX.

Layers: core (the paper's algorithm + baselines), scenarios (TimeModel
protocol + named straggler regimes), xp (declarative experiment harness →
paper-figure artifacts), models (assigned arch zoo), data / optim /
checkpoint substrates, kernels (Pallas TPU), launch (mesh, dry-run,
train/serve drivers), configs (architecture registry).
"""
__version__ = "1.0.0"
