"""Scenario subsystem: named worker compute-time regimes.

``get_scenario("heavy_tail", n=256)`` returns a frozen
:class:`~repro.scenarios.base.Scenario` that any scheduler accepts wherever
it previously took a :class:`~repro.core.straggler.StragglerModel` (both
satisfy the :class:`~repro.scenarios.base.TimeModelSpec` protocol).  The
``paper_default`` scenario is bit-exact with the historical
``StragglerModel`` streams; the rest open the heterogeneity regimes the
related straggler literature studies (see scenarios/library.py).
"""
from repro.scenarios.base import (FactorSampler, Scenario, TimeModel,
                                  TimeModelSpec, get_scenario,
                                  register_scenario, scenario_names)
from repro.scenarios.library import (BimodalScenario, ChurnScenario,
                                     DiurnalScenario, HeavyTailScenario,
                                     PaperDefaultScenario)

__all__ = [
    "FactorSampler", "Scenario", "TimeModel", "TimeModelSpec",
    "get_scenario", "register_scenario", "scenario_names",
    "PaperDefaultScenario", "HeavyTailScenario", "BimodalScenario",
    "DiurnalScenario", "ChurnScenario",
]
