"""Scenario layer: the TimeModel protocol and the scenario registry.

The schedulers (core/scheduler.py, core/baselines.py) simulate worker
timelines through a *sampler* object they obtain from whatever was passed as
their ``straggler`` argument.  Historically that argument was always a
:class:`repro.core.straggler.StragglerModel` and the sampler always a
:class:`~repro.core.straggler.TimeSampler`; this module generalizes the pair
into two small protocols so heterogeneity regimes beyond the paper's
iid-Bernoulli straggler protocol (heavy-tailed service times, hardware
clusters, diurnal straggling, worker churn — see scenarios/library.py) plug
into every scheduler unchanged:

- :class:`TimeModel` is the *sampler* contract: ``sample`` /
  ``sample_batch`` / ``sample_horizon`` / ``sample_all`` plus the per-worker
  ``base``-time array.  These are exactly the methods the sparse-native
  generators and the opt-in ``horizon=K`` batcher already call on
  ``TimeSampler``, so any conforming object drops into the scheduler hot
  loops with zero changes there.
- :class:`TimeModelSpec` is the *factory* contract (``n`` +
  ``make_sampler()``) that ``Scheduler.__init__`` consumes.  Both
  ``StragglerModel`` and every :class:`Scenario` satisfy it.

Stream-compatibility contract (pinned by tests/test_scenarios.py): for every
scenario, driving a fresh sampler through repeated ``sample(w)`` calls and
driving another fresh sampler through the equivalent ``sample_batch([w])``
calls must consume the RNG stream identically — the same guarantee
``TimeSampler`` documents for its m == 1 case, which is what lets schedulers
mix the two call styles without forking realizations.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, ClassVar, Dict, Type

import numpy as np

from typing import Protocol, runtime_checkable


@runtime_checkable
class TimeModel(Protocol):
    """Sampler contract every scheduler consumes (duck-typed at runtime).

    ``base`` is the (n,) per-worker base-time array: the horizon batcher
    multiplies its pre-drawn factors by ``base[worker]``, and the runner
    sizes ``max_time``-bounded batch pools from ``base.min()``.
    """

    base: np.ndarray

    def sample(self, worker: int) -> float:
        """Duration of one local gradient computation of ``worker``."""
        ...

    def sample_batch(self, workers) -> np.ndarray:
        """Vectorized draw for a worker index array (restart batches)."""
        ...

    def sample_horizon(self, k: int) -> np.ndarray:
        """K future duration *factors* (multiply ``base[worker]``) at once."""
        ...

    def sample_all(self) -> np.ndarray:
        """One draw for every worker (sync barriers, heap initialization)."""
        ...


@runtime_checkable
class TimeModelSpec(Protocol):
    """Factory contract ``Scheduler.__init__`` accepts.

    ``base_time`` is the mean local-computation scale in virtual seconds —
    AD-PSGD sizes its atomic-averaging lock hold (``avg_time``) relative to
    it, so every spec carries one.
    """

    n: int
    base_time: float

    def make_sampler(self) -> TimeModel:
        ...


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, parameterized compute-time regime (a ``TimeModelSpec``).

    Subclasses add their distribution parameters as dataclass fields, set a
    ``name`` ClassVar, and implement :meth:`make_sampler`.  Scenarios are
    frozen so an experiment record (``ExperimentSpec`` / the bench artifact)
    can embed ``describe()`` and fully determine the realized streams.
    """

    n: int
    seed: int = 0
    base_time: float = 1.0

    name: ClassVar[str] = "base"

    def make_sampler(self) -> TimeModel:
        raise NotImplementedError

    def mean_duration_factor(self) -> float:
        """Analytic E[duration] / base_time — the virtual-clock stretch.

        The experiment harness scales virtual-time budgets by this factor so
        a heavy-tailed scenario gets the same *effective* number of local
        computations as the paper-default one; the distribution sanity tests
        pin the empirical mean against it.
        """
        return 1.0

    def describe(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["scenario"] = self.name
        d["mean_duration_factor"] = self.mean_duration_factor()
        return d


class FactorSampler:
    """Shared TimeModel machinery: ``duration = base[worker] · factor``.

    Subclasses implement the factor draw.  Two hooks cover the two scenario
    shapes:

    - iid scenarios (factors independent of worker identity and history)
      implement :meth:`_factors_iid`; the default :meth:`_factors_for`
      forwards to it, and :meth:`sample_horizon` reuses it directly, so the
      horizon stream is distributionally identical to the per-event one.
    - worker/history-dependent scenarios (e.g. diurnal phases) override
      :meth:`_factors_for` (and usually :meth:`sample_horizon`, since the
      horizon batcher assigns factors to workers only after drawing them —
      the same different-realization caveat the batcher already documents).

    ``sample`` delegates to ``sample_batch`` of a singleton, which *is* the
    stream-compatibility contract of scenarios/base.py — the two call styles
    cannot diverge by construction.
    """

    #: rng-order sampler surface (repro.check): the factor hooks are the
    #: only draw sites; scenario subclasses shadow this when they override
    #: other methods with draws (e.g. a history-dependent sample_horizon).
    rng_methods = ("_factors_iid", "_factors_for")

    def __init__(self, scenario: Scenario, base: np.ndarray):
        self.scenario = scenario
        self.n = scenario.n
        self.base = np.asarray(base, dtype=np.float64)
        self._rng = np.random.default_rng(scenario.seed)

    # -- hooks -------------------------------------------------------------
    def _factors_iid(self, k: int) -> np.ndarray:
        raise NotImplementedError

    def _factors_for(self, workers: np.ndarray) -> np.ndarray:
        return self._factors_iid(len(workers))

    @property
    def iid_horizon(self) -> bool:
        """Whether factor draws are exchangeable across workers and events.

        True exactly when the subclass kept the default ``_factors_for`` /
        ``sample_horizon`` (pure ``_factors_iid`` scenarios): a pre-drawn
        flat factor stream can then be assigned to workers in any order
        without changing the process law — the gate for the fused on-device
        generator (core/fused.py).  Worker/history-dependent overrides
        (diurnal) report False and keep the host paths.
        """
        return (type(self)._factors_for is FactorSampler._factors_for
                and type(self).sample_horizon is FactorSampler.sample_horizon)

    # -- TimeModel ---------------------------------------------------------
    def sample_batch(self, workers) -> np.ndarray:
        workers = np.asarray(workers, dtype=np.intp)
        return self.base[workers] * self._factors_for(workers)

    def sample(self, worker: int) -> float:
        return float(self.sample_batch(np.array([worker], dtype=np.intp))[0])

    def sample_horizon(self, k: int) -> np.ndarray:
        return self._factors_iid(k)

    def sample_all(self) -> np.ndarray:
        return self.sample_batch(np.arange(self.n, dtype=np.intp))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

SCENARIOS: Dict[str, Type[Scenario]] = {}


def register_scenario(cls: Type[Scenario]) -> Type[Scenario]:
    """Class decorator: add a Scenario subclass to the named registry."""
    name = cls.name
    if name in SCENARIOS and SCENARIOS[name] is not cls:
        raise ValueError(f"scenario {name!r} already registered")
    SCENARIOS[name] = cls
    return cls


def scenario_names() -> tuple:
    return tuple(sorted(SCENARIOS))


def get_scenario(name: str, n: int, seed: int = 0, **overrides) -> Scenario:
    """Instantiate a registered scenario at worker count ``n``.

    ``overrides`` set distribution parameters (dataclass fields) of the
    chosen scenario; unknown names raise, so experiment specs can't silently
    typo a knob.
    """
    if name not in SCENARIOS:
        raise KeyError(
            f"unknown scenario {name!r}; registered: {list(scenario_names())}")
    cls = SCENARIOS[name]
    fields = {f.name for f in dataclasses.fields(cls)}
    bad = set(overrides) - fields
    if bad:
        raise TypeError(
            f"scenario {name!r} has no parameter(s) {sorted(bad)}; "
            f"available: {sorted(fields - {'n', 'seed'})}")
    return cls(n=n, seed=seed, **overrides)
