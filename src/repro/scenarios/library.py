"""The registered scenario library.

Five named compute-time regimes (plus whatever downstream code registers):

- ``paper_default`` — the paper's §6 protocol, *bit-exact* with the seed
  repo's ``StragglerModel``/``TimeSampler`` streams: ``make_sampler`` returns
  a real :class:`~repro.core.straggler.TimeSampler`, so every recorded run
  replays unchanged (tests/test_scenarios.py pins all five schedulers).
- ``heavy_tail`` — Pareto service times: the AD-PSGD/Hop line of work
  observes that real clusters show heavy-tailed (not Bernoulli) slowdowns;
  with tail index α ≤ 2 the variance is infinite and "the straggler" is a
  different worker every few hundred events.
- ``bimodal`` — two persistent hardware clusters (fast/slow machines), the
  Hop paper's heterogeneous-cluster regime: a fixed random subset of workers
  is ``slow_factor``× slower *forever*, instead of transiently.
- ``diurnal`` — time-varying stragglers: each worker's straggler probability
  follows a sinusoid in its local-computation count (a deterministic proxy
  for virtual time — draws are exactly the worker's successive computations),
  with phases spread across workers, so the slow set drifts around the
  cluster like a load wave.
- ``churn`` — temporary worker dropout: with small probability a completed
  computation is followed by an offline period (exponential, mean
  ``downtime`` base-times) before the worker re-enters.  Re-entry is
  scheduler-safe by construction: a churned worker is simply a very late
  completion on the event heap — the same path stragglers and isolated
  workers already exercise — so no scheduler ever blocks on it (AD-PSGD's
  averaging lock, in particular, is only held at completion, never across
  the downtime).
"""
from __future__ import annotations

import dataclasses
import math
from typing import ClassVar

import numpy as np

from repro.core.straggler import StragglerModel, TimeSampler
from repro.scenarios.base import (FactorSampler, Scenario, TimeModel,
                                  register_scenario)


@register_scenario
@dataclasses.dataclass(frozen=True)
class PaperDefaultScenario(Scenario):
    """The paper's straggler protocol (§6 + appendix D), unchanged.

    A thin factory over :class:`StragglerModel`: the sampler *is* a
    ``TimeSampler`` seeded identically, so the event streams of every
    scheduler are bit-exact with the pre-scenario-engine repo state.
    """

    straggler_prob: float = 0.10
    slowdown: float = 10.0
    heterogeneity: float = 0.0
    jitter: float = 0.05

    name: ClassVar[str] = "paper_default"

    def make_sampler(self) -> TimeModel:
        return TimeSampler(StragglerModel(
            n=self.n, straggler_prob=self.straggler_prob,
            slowdown=self.slowdown, base_time=self.base_time,
            heterogeneity=self.heterogeneity, jitter=self.jitter,
            seed=self.seed))

    def mean_duration_factor(self) -> float:
        mix = 1.0 + self.straggler_prob * (self.slowdown - 1.0)
        return (mix * math.exp(self.jitter ** 2 / 2)
                * math.exp(self.heterogeneity ** 2 / 2))


class _HeavyTailSampler(FactorSampler):
    rng_methods = ("_factors_iid",)

    def _factors_iid(self, k: int) -> np.ndarray:
        # Pareto with x_m = 1: the fastest computation is the base time, the
        # tail P[factor > x] = x^{-α} produces occasional enormous stragglers.
        return 1.0 + self._rng.pareto(self.scenario.alpha, size=k)


@register_scenario
@dataclasses.dataclass(frozen=True)
class HeavyTailScenario(Scenario):
    """Pareto(α) service times, x_m = base_time; α ≤ 2 ⇒ infinite variance."""

    alpha: float = 1.5

    name: ClassVar[str] = "heavy_tail"

    def make_sampler(self) -> TimeModel:
        return _HeavyTailSampler(self, np.full(self.n, self.base_time))

    def mean_duration_factor(self) -> float:
        a = self.alpha
        # E[1 + Lomax(α)] = α/(α−1); below α ≈ 1 the mean diverges — return a
        # finite surrogate so budget scaling stays usable.
        return a / (a - 1.0) if a > 1.05 else 20.0


class _BimodalSampler(FactorSampler):
    rng_methods = ("_factors_iid",)

    def __init__(self, scenario: "BimodalScenario"):
        n = scenario.n
        rng = np.random.default_rng(scenario.seed)
        n_slow = int(round(scenario.slow_frac * n))
        slow = rng.choice(n, size=n_slow, replace=False)
        base = np.full(n, scenario.base_time)
        base[slow] *= scenario.slow_factor
        super().__init__(scenario, base)
        # the cluster split consumed draws from a separate construction-time
        # stream; per-draw factors start from the scenario seed offset by one
        # so the split and the jitter streams never alias
        self._rng = np.random.default_rng(scenario.seed + 1)
        self.slow_workers = np.sort(slow)

    def _factors_iid(self, k: int) -> np.ndarray:
        j = self.scenario.jitter
        if j <= 0:
            return np.ones(k)
        return self._rng.lognormal(mean=0.0, sigma=j, size=k)


@register_scenario
@dataclasses.dataclass(frozen=True)
class BimodalScenario(Scenario):
    """Two persistent hardware clusters: slow_frac of workers slow_factor× slower."""

    slow_frac: float = 0.25
    slow_factor: float = 5.0
    jitter: float = 0.05

    name: ClassVar[str] = "bimodal"

    def make_sampler(self) -> TimeModel:
        return _BimodalSampler(self)

    def mean_duration_factor(self) -> float:
        frac = round(self.slow_frac * self.n) / max(self.n, 1)
        return ((1.0 + frac * (self.slow_factor - 1.0))
                * math.exp(self.jitter ** 2 / 2))


class _DiurnalSampler(FactorSampler):
    # phase-dependent draws live in the worker-aware hook and the horizon
    # override, not _factors_iid (there is no iid law to forward to)
    rng_methods = ("_factors_for", "sample_horizon")

    def __init__(self, scenario: "DiurnalScenario"):
        super().__init__(scenario, np.full(scenario.n, scenario.base_time))
        # phase offsets spread deterministically across the ring of workers:
        # the straggling "load wave" travels through the cluster
        self._phase = np.arange(scenario.n) / max(scenario.n, 1)
        self._count = np.zeros(scenario.n, dtype=np.int64)
        self._gcount = 0

    def _prob_at(self, cycles: np.ndarray) -> np.ndarray:
        p = self.scenario.straggler_prob
        return p * 0.5 * (1.0 + np.sin(2.0 * np.pi * cycles))

    def _factors_for(self, workers: np.ndarray) -> np.ndarray:
        sc = self.scenario
        f = (self._rng.lognormal(mean=0.0, sigma=sc.jitter, size=len(workers))
             if sc.jitter > 0 else np.ones(len(workers)))
        cycles = (self._count[workers] / sc.period) + self._phase[workers]
        p = self._prob_at(cycles)
        f = np.where(self._rng.random(len(workers)) < p, f * sc.slowdown, f)
        np.add.at(self._count, workers, 1)
        return f

    def sample_horizon(self, k: int) -> np.ndarray:
        # The horizon batcher assigns factors to workers only *after* the
        # draw, so per-worker phases are unknowable here; a global draw
        # counter stands in for the phase.  Like the batcher itself this is
        # a different-but-deterministic realization of the same marginal
        # straggler intensity.
        sc = self.scenario
        f = (self._rng.lognormal(mean=0.0, sigma=sc.jitter, size=k)
             if sc.jitter > 0 else np.ones(k))
        cycles = (self._gcount + np.arange(k)) / sc.period
        p = self._prob_at(cycles)
        f = np.where(self._rng.random(k) < p, f * sc.slowdown, f)
        self._gcount += k
        return f


@register_scenario
@dataclasses.dataclass(frozen=True)
class DiurnalScenario(Scenario):
    """Time-varying stragglers: sinusoidal straggler intensity per worker.

    Worker w's s-th local computation straggles with probability
    ``straggler_prob · ½(1 + sin 2π(s/period + w/n))`` — peak intensity
    ``straggler_prob``, trough 0, phase-shifted around the cluster.  The
    draw counter s is the per-worker virtual-time proxy: draws are exactly
    the worker's successive computations, so one ``period`` spans about
    ``period · base_time · mean_factor`` virtual seconds.
    """

    straggler_prob: float = 0.3
    slowdown: float = 10.0
    period: float = 64.0
    jitter: float = 0.05

    name: ClassVar[str] = "diurnal"

    def make_sampler(self) -> TimeModel:
        return _DiurnalSampler(self)

    def mean_duration_factor(self) -> float:
        # phase-averaged straggler probability is straggler_prob / 2
        return ((1.0 + 0.5 * self.straggler_prob * (self.slowdown - 1.0))
                * math.exp(self.jitter ** 2 / 2))


class _ChurnSampler(FactorSampler):
    rng_methods = ("_factors_iid",)

    def _factors_iid(self, k: int) -> np.ndarray:
        sc = self.scenario
        f = (self._rng.lognormal(mean=0.0, sigma=sc.jitter, size=k)
             if sc.jitter > 0 else np.ones(k))
        # the downtime vector is drawn unconditionally so scalar and batched
        # call styles consume the stream identically (the base contract)
        down = self._rng.random(k) < sc.churn_prob
        off = self._rng.exponential(sc.downtime, size=k)
        return f + np.where(down, off, 0.0)


@register_scenario
@dataclasses.dataclass(frozen=True)
class ChurnScenario(Scenario):
    """Temporary worker dropout: rare exponential offline periods.

    With probability ``churn_prob`` a worker's completed computation is
    followed by an offline period of mean ``downtime`` base-times before it
    rejoins.  Because the downtime is folded into the completion interval,
    re-entry rides the existing late-completion paths: asynchronous
    schedulers keep making progress without the worker (exactly like a
    straggler), and on its return DSGD-AAU's Pathsearch folds its
    information back into the spanning structure.
    """

    churn_prob: float = 0.02
    downtime: float = 25.0
    jitter: float = 0.05

    name: ClassVar[str] = "churn"

    def make_sampler(self) -> TimeModel:
        return _ChurnSampler(self, np.full(self.n, self.base_time))

    def mean_duration_factor(self) -> float:
        return (math.exp(self.jitter ** 2 / 2)
                + self.churn_prob * self.downtime)
