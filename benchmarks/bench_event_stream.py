"""Events/sec of the event-stream execution modes, at paper worker counts.

Three consumers share one scheduler stream, for each of the paper's async
algorithms with distinct active-set shapes (AD-PSGD: constant A=2 pairs;
DSGD-AAU: heavy-tailed finished cliques, the bucketed-ladder stress case;
Prague: constant group-size cliques):

- ``per_event``: one XLA dispatch + host batch refresh per event (legacy);
- ``scan``: block-compiled dense scan — one dispatch per ``block_size``
  events, but every event still pays the O(n²·D) dense mix and O(n·D)
  gradients;
- ``sparse_scan``: the active-set gather-compute-scatter scan — O(A²·D)
  mix and O(A·D) gradients at the scheduler's lane-width ladder.  For
  DSGD-AAU that ladder is multi-rung (``Scheduler.active_buckets``), and
  the row records the static single-bucket throughput next to the
  bucketed one so the ladder's win is in the artifact, not just the docs.

Each row also records the measured per-bucket occupancy of the stream
(``BucketedSparseEventBatch.occupancy``): events per rung and lane fill —
the padding-waste numbers that motivated bucketing (a DSGD-AAU stream at
N=256 packed to the static bound sits under 4% lane fill).

Event *generation* (host-side numpy) is timed separately: it bounds every
consumer from above.  The opt-in event-horizon batcher is timed for the
single-edge schedulers only (the others don't accept ``horizon=``; their
rows record ``gen_horizon_eps: null`` — the number-or-null metric schema
enforced by ``common.write_bench_json``, which also normalizes the legacy
``"unsupported"`` string older recordings carried).

Two further columns record the device-resident streaming pipeline:

- ``e2e_eps``: the sparse path at its *defaults* — array-native packed
  generation plus the event-blocked scan (K conflict-free events merged
  per ``lax.scan`` step) — timed generation+consumption together.
  ``sparse_eps`` stays measured with ``native_generation=False,
  events_per_step=1`` so it remains comparable with earlier recordings of
  the one-event-per-step object path.
- ``fused_eps``: ``mode="fused"`` for the single-edge schedulers — event
  generation and consumption fused into one compiled scan, host work
  reduced to two vectorized RNG draws per block (a different-but-
  deterministic RNG-order realization; see core/fused.py).

Telemetry overhead (``repro.obs``): ``fused_tel_eps`` re-times the fused
path with ``telemetry=True`` (the scan streams each event's identity out
as extra outputs; the run folds once at drain — ``fused_metrics_fold``)
and ``telemetry_overhead`` records the with/without ratio; ``--smoke``
asserts it stays under 1.10 — the device-resident-telemetry contract is
that counters never cost a host sync or per-event scatter on the fused
path.  ``e2e_tel_eps`` records the same pair for the DSGD-AAU sparse
stream (the bucketed ladder, worst case for extra carries).

Trace overhead (``repro.obs.trace``): ``e2e_trace_eps`` /
``trace_overhead`` re-time the DSGD-AAU stream with ``trace=True`` —
host-side event-identity recording per block plus the end-of-run
wait-blame attribution — and ``--smoke`` asserts the same < 1.10x bound
(tracing must never sync mid-run); ``fused_trace_eps`` records the fused
pair, whose whole-run payload is fetched with a single ``jax.device_get``
at drain.

  python -m benchmarks.bench_event_stream [--paper-scale] [--xl] [--smoke]
      # writes BENCH_event_stream.json

All trainers are warmed up first (``DecentralizedTrainer.warmup`` compiles
via a no-op dispatch), so the numbers compare steady-state throughput, not
compile time.  ``per_event`` is skipped above N=64 and the dense scan above
N=256 (each would dominate the wall clock without adding information —
above those scales the sparse path is the only contender, which is the
point of the bench).
"""
from __future__ import annotations

import argparse
import itertools
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import bench_sizes, csv_row, write_bench_json
from repro.core import topology
from repro.core.baselines import make_scheduler
from repro.core.runner import DecentralizedTrainer
from repro.core.scheduler import BucketedSparseEventBatch
from repro.core.straggler import StragglerModel
from repro.data.synthetic import ClassificationData

ALGS = ("ad_psgd", "dsgd_aau", "prague")
BLOCK_SIZE = 128
D_IN, D_H, BATCH = 16, 16, 4
PER_EVENT_MAX_N = 64     # legacy interpreter is noise above this scale
SCAN_MAX_N = 256         # dense O(n²·D) mix: wall-clock filler above this
HORIZON_ALGS = ("ad_psgd", "agp")   # single-edge scheds accept horizon=
FUSED_ALGS = ("ad_psgd",)           # single-edge member of ALGS (agp's
                                    # fused path is the same code; its
                                    # equivalence lives in the test suite)

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_event_stream.json")


def _loss(params, batch):
    h = jax.nn.relu(batch["x"] @ params["w1"])
    logp = jax.nn.log_softmax(h @ params["w2"])
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], axis=1))


def _init(key):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (D_IN, D_H)) * 0.1,
            "w2": jax.random.normal(k2, (D_H, 10)) * 0.1}


def _events_for(n: int, smoke: bool) -> int:
    if smoke:
        return 64  # a few blocks: proves the paths run, not their speed
    return {128: 384, 256: 256, 512: 192, 1024: 128}.get(n, 1024)


def _make_sched(alg: str, n: int, **kw):
    g = topology.erdos_renyi(n, max(0.15, 4.0 / n), seed=1)
    sm = StragglerModel(n=n, straggler_prob=0.1, slowdown=10.0, seed=0)
    return make_scheduler(alg, g, sm, **kw)


def _make_trainer(alg: str, mode: str, n: int, block_size: int,
                  trainer_kw=None, **sched_kw) -> DecentralizedTrainer:
    data = ClassificationData(n_workers=n, d=D_IN, samples_per_worker=64,
                              seed=0)
    # warmup() builds the pool before run() can size it, so pass an explicit
    # pool covering the observed worst-case restarts/worker of the event
    # bounds used here (~81 at N=16); bigger pools measurably slow the
    # per-step gather on CPU, which would pollute the dispatch comparison.
    kw = ({"block_size": block_size, "batch_pool": 96}
          if mode in ("scan", "sparse_scan", "fused") else {})
    kw.update(trainer_kw or {})
    return DecentralizedTrainer(
        _make_sched(alg, n, **sched_kw), _loss, _init,
        lambda w, s: data.batch(w, s, batch_size=BATCH),
        data.eval_batch(256), eta0=0.2, seed=0, mode=mode, **kw)


def _events_per_sec(alg: str, mode: str, n: int, events: int,
                    block_size: int, trainer_kw=None, repeats: int = 1,
                    **sched_kw) -> float:
    tr = _make_trainer(alg, mode, n, block_size, trainer_kw, **sched_kw)
    tr.warmup()
    best = 0.0
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = tr.run(max_events=events, eval_every=10 ** 9)
        jax.block_until_ready(tr.y)
        wall = time.perf_counter() - t0
        best = max(best, res.total_events / wall)
    return best


def _flag_overhead_pair(alg: str, mode: str, n: int, events: int,
                        block_size: int, flag: str = "telemetry",
                        repeats: int = 3, **sched_kw):
    """(base_eps, flag_on_eps) for ``mode``, measured interleaved.

    ``flag`` names the trainer observability switch under test
    (``telemetry`` — the MetricsCarry of device accumulators — or
    ``trace`` — event-identity recording plus the end-of-run wait-blame
    attribution).  The with/without timings alternate run-by-run (best-of
    ``repeats`` each) so background load drift hits both sides equally —
    a sequential pair can fake a ±20% "overhead" on a busy host.
    """
    trs = {on: _make_trainer(alg, mode, n, block_size,
                             {flag: on}, **sched_kw)
           for on in (False, True)}
    for tr in trs.values():
        tr.warmup()
        tr.run(max_events=block_size, eval_every=10 ** 9)  # steady state
    best = {False: 0.0, True: 0.0}
    for _ in range(repeats):
        for tel, tr in trs.items():
            t0 = time.perf_counter()
            res = tr.run(max_events=events, eval_every=10 ** 9)
            jax.block_until_ready(tr.y)
            wall = time.perf_counter() - t0
            best[tel] = max(best[tel], res.total_events / wall)
    return best[False], best[True]


def _generation_events_per_sec(alg: str, n: int, events: int,
                               horizon=None) -> float:
    """Host-side scheduler throughput alone: the event loop + event build."""
    sched = _make_sched(alg, n, **({"horizon": horizon} if horizon else {}))
    stream = sched.events()
    next(stream)  # exclude generator setup / first-draw warmup
    t0 = time.perf_counter()
    for _ in itertools.islice(stream, events):
        pass
    return events / (time.perf_counter() - t0)


def _bucket_occupancy(alg: str, n: int, events: int):
    """Measured lane-width ladder + per-rung packing stats of the stream."""
    sched = _make_sched(alg, n)
    buckets = sched.active_buckets()
    evs = list(itertools.islice(sched.events(), events))
    occ = BucketedSparseEventBatch.from_events(evs, buckets=buckets,
                                               edge_bound=sched.edge_bound())
    return list(map(int, buckets)), occ.occupancy()


def run(paper_scale: bool = False, smoke: bool = False, xl: bool = False):
    sizes = bench_sizes(paper_scale, smoke, xl)
    results = []
    for n, alg in itertools.product(sizes, ALGS):
        events = _events_for(n, smoke)
        block = min(BLOCK_SIZE, events)
        gen = _generation_events_per_sec(alg, n, events)
        buckets, occupancy = _bucket_occupancy(alg, n, events)
        # PR6-comparable configuration: object-path generation, one event
        # per scan step — the pre-streaming sparse path.
        sparse = _events_per_sec(
            alg, "sparse_scan", n, events, block,
            trainer_kw=dict(native_generation=False, events_per_step=1))
        # The streaming defaults: native packed generation + event-blocked
        # scan, generation and consumption timed together.
        e2e = _events_per_sec(alg, "sparse_scan", n, events, block)
        row = {
            "n": n, "alg": alg, "events": events, "block_size": block,
            "gen_eps": gen, "sparse_eps": sparse, "e2e_eps": e2e,
            "buckets": buckets, "occupancy": occupancy,
        }
        yield csv_row(f"event_stream_gen_{alg}_n{n}", 1e6 / gen,
                      f"{gen:.0f} events/s generation")
        if alg in HORIZON_ALGS:
            gen_h = _generation_events_per_sec(alg, n, events, horizon=256)
            row["gen_horizon_eps"] = gen_h
            yield csv_row(f"event_stream_gen_horizon_{alg}_n{n}",
                          1e6 / gen_h, f"{gen_h:.0f} events/s horizon gen")
        else:
            # multi-worker restart sets consume the RNG in event order —
            # the horizon batcher's flat pre-draw doesn't apply (null, per
            # the number-or-null metric schema; see common.write_bench_json)
            row["gen_horizon_eps"] = None
        if alg in FUSED_ALGS:
            # Telemetry overhead: the same fused config with a MetricsCarry
            # of device accumulators riding the block.  Smoke asserts the
            # < 10% contract on a longer interleaved timing so CI load
            # drift can't fake a regression.
            tel_events = max(events, 2048) if smoke else events
            tel_block = min(BLOCK_SIZE, tel_events)
            fused, fused_tel = _flag_overhead_pair(
                alg, "fused", n, tel_events, tel_block,
                repeats=4 if smoke else 2)
            overhead = fused / fused_tel
            row["fused_eps"] = fused
            row["fused_tel_eps"] = fused_tel
            row["telemetry_overhead"] = overhead
            yield csv_row(f"event_stream_fused_{alg}_n{n}", 1e6 / fused,
                          f"{fused:.0f} events/s fused gen+consume")
            yield csv_row(f"event_stream_fused_tel_{alg}_n{n}",
                          1e6 / fused_tel,
                          f"{fused_tel:.0f} events/s with telemetry "
                          f"({overhead:.3f}x overhead)")
            if smoke:
                assert overhead < 1.10, (
                    f"device-resident telemetry cost {overhead:.3f}x on the "
                    f"fused path (contract: < 1.10x)")
            # The trace rides the same widened scan outputs and pays one
            # jax.device_get over the whole run's payload at drain
            # (repro.obs.trace.drain_fused_payload) — recorded so the
            # drain-once design has a number; the asserted contract row is
            # the streaming pair below.
            # always >= 2048 events: the drain's fixed cost (one device_get
            # + attribution) on a ~30 ms run otherwise reads as a fake
            # 10-20% "overhead"
            fused_tr_events = max(events, 2048)
            fused_tr_base, fused_trace = _flag_overhead_pair(
                alg, "fused", n, fused_tr_events,
                min(BLOCK_SIZE, fused_tr_events), flag="trace", repeats=4)
            row["fused_trace_eps"] = fused_trace
            row["fused_trace_overhead"] = fused_tr_base / fused_trace
            yield csv_row(f"event_stream_fused_trace_{alg}_n{n}",
                          1e6 / fused_trace,
                          f"{fused_trace:.0f} events/s with trace "
                          f"({fused_tr_base / fused_trace:.3f}x overhead)")
        if n <= PER_EVENT_MAX_N:
            per_event = _events_per_sec(alg, "per_event", n, events, block)
            row["per_event_eps"] = per_event
            yield csv_row(f"event_stream_per_event_{alg}_n{n}",
                          1e6 / per_event, f"{per_event:.0f} events/s")
        if n <= SCAN_MAX_N:
            scan = _events_per_sec(alg, "scan", n, events, block)
            row["scan_eps"] = scan
            row["sparse_speedup"] = sparse / scan
            yield csv_row(f"event_stream_scan_{alg}_n{n}", 1e6 / scan,
                          f"{scan:.0f} events/s")
        if len(buckets) > 1 and n <= SCAN_MAX_N:
            # the pre-ladder sparse path: every event padded to A=n.  Kept
            # in the artifact so the bucketing win is a recorded number
            # (measured at the same PR6-comparable settings as sparse_eps).
            static = _events_per_sec(
                alg, "sparse_scan", n, events, block,
                trainer_kw=dict(native_generation=False, events_per_step=1),
                buckets=(n,))
            row["sparse_static_eps"] = static
            row["bucket_speedup"] = sparse / static
            yield csv_row(
                f"event_stream_sparse_static_{alg}_n{n}", 1e6 / static,
                f"{static:.0f} events/s (single-bucket A={n} padding)")
        vs = (f" ({row['sparse_speedup']:.1f}x vs dense scan)"
              if "sparse_speedup" in row else "")
        yield csv_row(f"event_stream_sparse_{alg}_n{n}", 1e6 / sparse,
                      f"{sparse:.0f} events/s{vs}")
        yield csv_row(f"event_stream_e2e_{alg}_n{n}", 1e6 / e2e,
                      f"{e2e:.0f} events/s streaming defaults "
                      f"({e2e / sparse:.1f}x vs one-event-per-step)")
        if alg == "dsgd_aau":
            # sparse-path telemetry cost on the bucketed ladder (the most
            # carries per event of any mode); recorded, not asserted — the
            # contract row is the fused pair above.  Measured interleaved
            # (its own base, not e2e_eps: a separately-timed pair under
            # host generation noise can fake a large ratio).
            e2e_base, e2e_tel = _flag_overhead_pair(
                alg, "sparse_scan", n, events, block,
                repeats=2 if smoke else 3)
            row["e2e_tel_eps"] = e2e_tel
            row["e2e_tel_overhead"] = e2e_base / e2e_tel
            yield csv_row(f"event_stream_e2e_tel_{alg}_n{n}", 1e6 / e2e_tel,
                          f"{e2e_tel:.0f} events/s streaming with telemetry "
                          f"({e2e_base / e2e_tel:.3f}x overhead)")
            # Trace cost on the same worst-case stream: host-side identity
            # recording per block plus the end-of-run wait-blame pass
            # (repro.obs.critical_path) — the contract is that tracing
            # never syncs mid-run, so the asserted bound matches the
            # telemetry one.  Longer runs in smoke: a 64-event run is all
            # fixed cost and would fake any ratio.
            trace_events = max(events, 2048) if smoke else events
            trace_block = min(BLOCK_SIZE, trace_events)
            trace_base, e2e_trace = _flag_overhead_pair(
                alg, "sparse_scan", n, trace_events, trace_block,
                flag="trace", repeats=3)
            row["e2e_trace_eps"] = e2e_trace
            row["trace_overhead"] = trace_base / e2e_trace
            yield csv_row(f"event_stream_e2e_trace_{alg}_n{n}",
                          1e6 / e2e_trace,
                          f"{e2e_trace:.0f} events/s streaming with trace "
                          f"({trace_base / e2e_trace:.3f}x overhead)")
            if smoke:
                assert row["trace_overhead"] < 1.10, (
                    f"virtual-time tracing cost {row['trace_overhead']:.3f}x "
                    f"on the streaming path (contract: < 1.10x)")
        results.append(row)
    payload = {
        "bench": "event_stream",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "results": results,
    }
    if not smoke:  # smoke checks runnability; don't clobber measured rows
        write_bench_json(os.path.abspath(_JSON_PATH), payload)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--xl", action="store_true",
                    help="add N∈{512, 1024} (sparse path only)")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(paper_scale=args.paper_scale, smoke=args.smoke,
                   xl=args.xl):
        print(row)
    if not args.smoke:
        print(f"# wrote {os.path.abspath(_JSON_PATH)}")


if __name__ == "__main__":
    main()
