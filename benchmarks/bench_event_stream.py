"""Events/sec of the legacy per-event trainer vs the block-compiled scan.

The per-event path pays one XLA dispatch, one host-device sync, and one
host-side batch refresh per ScheduleEvent; the scan path amortizes one
dispatch over ``block_size`` events with the batch refresh on device.  The
workload is deliberately *dispatch-bound* (a tiny 2-layer net, AD-PSGD's
one-event-per-worker-finish stream — the longest of the paper's baselines):
it isolates the per-event overhead that caps stream throughput at paper
scale, which is exactly what the block-compiled path removes.

  python -m benchmarks.bench_event_stream          # writes BENCH_event_stream.json

Both trainers are warmed up first (``DecentralizedTrainer.warmup`` compiles
via a no-op dispatch), so the numbers compare steady-state throughput, not
compile time.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core import topology
from repro.core.baselines import make_scheduler
from repro.core.runner import DecentralizedTrainer
from repro.core.straggler import StragglerModel
from repro.data.synthetic import ClassificationData

ALG = "ad_psgd"          # longest event stream of the paper's baselines
EVENTS = 1024
BLOCK_SIZE = 128
D_IN, D_H, BATCH = 16, 16, 4

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_event_stream.json")


def _loss(params, batch):
    h = jax.nn.relu(batch["x"] @ params["w1"])
    logp = jax.nn.log_softmax(h @ params["w2"])
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], axis=1))


def _init(key):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (D_IN, D_H)) * 0.1,
            "w2": jax.random.normal(k2, (D_H, 10)) * 0.1}


def _make_trainer(mode: str, n: int) -> DecentralizedTrainer:
    data = ClassificationData(n_workers=n, d=D_IN, samples_per_worker=64,
                              seed=0)
    g = topology.erdos_renyi(n, max(0.15, 4.0 / n), seed=1)
    sm = StragglerModel(n=n, straggler_prob=0.1, slowdown=10.0, seed=0)
    sched = make_scheduler(ALG, g, sm)
    # warmup() builds the pool before run() can size it, so pass an explicit
    # pool covering the observed worst-case restarts/worker of the EVENTS
    # bound (~81 at N=16); bigger pools measurably slow the per-step gather
    # on CPU, which would pollute the dispatch-overhead comparison.
    kw = ({"block_size": BLOCK_SIZE, "batch_pool": 96}
          if mode == "scan" else {})
    return DecentralizedTrainer(
        sched, _loss, _init,
        lambda w, s: data.batch(w, s, batch_size=BATCH),
        data.eval_batch(256), eta0=0.2, seed=0, mode=mode, **kw)


def _events_per_sec(mode: str, n: int, events: int) -> float:
    tr = _make_trainer(mode, n)
    tr.warmup()
    t0 = time.perf_counter()
    res = tr.run(max_events=events, eval_every=10 ** 9)
    jax.block_until_ready(tr.y)
    wall = time.perf_counter() - t0
    return res.total_events / wall


def run(paper_scale: bool = False):
    sizes = (16, 64, 128) if paper_scale else (16, 64)
    events = EVENTS * (2 if paper_scale else 1)
    results = []
    for n in sizes:
        per_event = _events_per_sec("per_event", n, events)
        scan = _events_per_sec("scan", n, events)
        results.append({
            "n": n, "alg": ALG, "events": events, "block_size": BLOCK_SIZE,
            "per_event_eps": per_event, "scan_eps": scan,
            "speedup": scan / per_event,
        })
        yield csv_row(f"event_stream_per_event_n{n}", 1e6 / per_event,
                      f"{per_event:.0f} events/s")
        yield csv_row(f"event_stream_scan_n{n}", 1e6 / scan,
                      f"{scan:.0f} events/s ({scan / per_event:.1f}x)")
    payload = {
        "bench": "event_stream",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "results": results,
    }
    with open(os.path.abspath(_JSON_PATH), "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")


def main():
    print("name,us_per_call,derived")
    for row in run():
        print(row)
    print(f"# wrote {os.path.abspath(_JSON_PATH)}")


if __name__ == "__main__":
    main()
