"""Events/sec of the event-stream execution modes, at paper worker counts.

Three consumers share one scheduler stream (AD-PSGD — the longest of the
paper's baselines, one event per worker-finish):

- ``per_event``: one XLA dispatch + host batch refresh per event (legacy);
- ``scan``: block-compiled dense scan — one dispatch per ``block_size``
  events, but every event still pays the O(n²·D) dense mix and O(n·D)
  gradients;
- ``sparse_scan``: the active-set gather-compute-scatter scan — O(A²·D)
  mix and O(A·D) gradients with A=2 for AD-PSGD, the path that makes
  N∈{128, 256} (paper Figures 3–5 worker counts) run in CI time.

Event *generation* (host-side numpy) is timed separately: it bounds every
consumer from above.  Two generator variants are measured: the default
sparse-native per-event stream (bit-exact with recorded runs — no dense
``np.eye(n)`` per event, O(1) host work for single-edge schedulers), and
the opt-in event-horizon batcher (``horizon=K``: vectorized K-draw RNG
chunks + an argmin reorder buffer — deterministic but a different RNG-order
realization, see core/baselines.py).

  python -m benchmarks.bench_event_stream [--paper-scale] [--smoke]
      # writes BENCH_event_stream.json

All trainers are warmed up first (``DecentralizedTrainer.warmup`` compiles
via a no-op dispatch), so the numbers compare steady-state throughput, not
compile time.  ``per_event`` is skipped above N=64 (it would dominate the
wall clock without adding information — the scan paths are the contenders).
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import bench_sizes, csv_row
from repro.core import topology
from repro.core.baselines import make_scheduler
from repro.core.runner import DecentralizedTrainer
from repro.core.straggler import StragglerModel
from repro.data.synthetic import ClassificationData

ALG = "ad_psgd"          # longest event stream of the paper's baselines
BLOCK_SIZE = 128
D_IN, D_H, BATCH = 16, 16, 4
PER_EVENT_MAX_N = 64     # legacy interpreter is noise above this scale

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_event_stream.json")


def _loss(params, batch):
    h = jax.nn.relu(batch["x"] @ params["w1"])
    logp = jax.nn.log_softmax(h @ params["w2"])
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], axis=1))


def _init(key):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (D_IN, D_H)) * 0.1,
            "w2": jax.random.normal(k2, (D_H, 10)) * 0.1}


def _events_for(n: int, smoke: bool) -> int:
    if smoke:
        return 64  # a few blocks: proves the paths run, not their speed
    return {128: 384, 256: 256}.get(n, 1024)


def _make_sched(n: int, **kw):
    g = topology.erdos_renyi(n, max(0.15, 4.0 / n), seed=1)
    sm = StragglerModel(n=n, straggler_prob=0.1, slowdown=10.0, seed=0)
    return make_scheduler(ALG, g, sm, **kw)


def _make_trainer(mode: str, n: int, block_size: int) -> DecentralizedTrainer:
    data = ClassificationData(n_workers=n, d=D_IN, samples_per_worker=64,
                              seed=0)
    # warmup() builds the pool before run() can size it, so pass an explicit
    # pool covering the observed worst-case restarts/worker of the event
    # bounds used here (~81 at N=16); bigger pools measurably slow the
    # per-step gather on CPU, which would pollute the dispatch comparison.
    kw = ({"block_size": block_size, "batch_pool": 96}
          if mode in ("scan", "sparse_scan") else {})
    return DecentralizedTrainer(
        _make_sched(n), _loss, _init,
        lambda w, s: data.batch(w, s, batch_size=BATCH),
        data.eval_batch(256), eta0=0.2, seed=0, mode=mode, **kw)


def _events_per_sec(mode: str, n: int, events: int, block_size: int) -> float:
    tr = _make_trainer(mode, n, block_size)
    tr.warmup()
    t0 = time.perf_counter()
    res = tr.run(max_events=events, eval_every=10 ** 9)
    jax.block_until_ready(tr.y)
    wall = time.perf_counter() - t0
    return res.total_events / wall


def _generation_events_per_sec(n: int, events: int,
                               horizon=None) -> float:
    """Host-side scheduler throughput alone: the event loop + event build."""
    sched = _make_sched(n, horizon=horizon)
    stream = sched.events()
    next(stream)  # exclude generator setup / first-draw warmup
    t0 = time.perf_counter()
    for _ in itertools.islice(stream, events):
        pass
    return events / (time.perf_counter() - t0)


def run(paper_scale: bool = False, smoke: bool = False):
    sizes = bench_sizes(paper_scale, smoke)
    results = []
    for n in sizes:
        events = _events_for(n, smoke)
        block = min(BLOCK_SIZE, events)
        gen = _generation_events_per_sec(n, events)
        gen_horizon = _generation_events_per_sec(n, events, horizon=256)
        scan = _events_per_sec("scan", n, events, block)
        sparse = _events_per_sec("sparse_scan", n, events, block)
        row = {
            "n": n, "alg": ALG, "events": events, "block_size": block,
            "gen_eps": gen, "gen_horizon_eps": gen_horizon,
            "scan_eps": scan, "sparse_eps": sparse,
            "sparse_speedup": sparse / scan,
        }
        yield csv_row(f"event_stream_gen_n{n}", 1e6 / gen,
                      f"{gen:.0f} events/s generation")
        yield csv_row(f"event_stream_gen_horizon_n{n}", 1e6 / gen_horizon,
                      f"{gen_horizon:.0f} events/s horizon generation")
        if n <= PER_EVENT_MAX_N:
            per_event = _events_per_sec("per_event", n, events, block)
            row["per_event_eps"] = per_event
            row["speedup"] = scan / per_event
            yield csv_row(f"event_stream_per_event_n{n}", 1e6 / per_event,
                          f"{per_event:.0f} events/s")
        yield csv_row(f"event_stream_scan_n{n}", 1e6 / scan,
                      f"{scan:.0f} events/s")
        yield csv_row(
            f"event_stream_sparse_n{n}", 1e6 / sparse,
            f"{sparse:.0f} events/s ({sparse / scan:.1f}x vs dense scan)")
        results.append(row)
    payload = {
        "bench": "event_stream",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "results": results,
    }
    if not smoke:  # smoke checks runnability; don't clobber measured rows
        with open(os.path.abspath(_JSON_PATH), "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(paper_scale=args.paper_scale, smoke=args.smoke):
        print(row)
    if not args.smoke:
        print(f"# wrote {os.path.abspath(_JSON_PATH)}")


if __name__ == "__main__":
    main()
