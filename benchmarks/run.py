"""Benchmark harness: one module per paper table/figure.

  python -m benchmarks.run [--paper-scale] [--xl] [--smoke]
      [--only convergence,roofline] [--profile]

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).
Default scale finishes on CPU in minutes; --paper-scale reproduces the
paper's N∈{128, 256} settings (slow); --xl adds N∈{512, 1024} to the
benches that support it (sparse path only); --smoke runs every bench at
N=16 for a few blocks — a fast importable-and-runnable check to pair with
the tier-1 pytest suite (it never overwrites recorded BENCH_*.json
results).

--profile wraps each selected bench in ``jax.profiler.trace`` and prints
the per-bench trace directory (open with TensorBoard or Perfetto).  Pair
it with ``--only`` and ``--smoke`` to keep traces small: a full bench
traces every dispatch, and the trace grows with wall time.
"""
import argparse
import contextlib
import inspect
import os
import sys
import tempfile
import time

MODULES = ("convergence", "walltime", "speedup", "communication",
           "ablation", "kernels", "roofline", "event_stream")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--xl", action="store_true",
                    help="add N∈{512, 1024} where a bench supports it")
    ap.add_argument("--smoke", action="store_true",
                    help="N=16, a few blocks per bench: fast CI check")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    ap.add_argument("--profile", action="store_true",
                    help="wrap each bench in jax.profiler.trace and print "
                         "the trace directory")
    args = ap.parse_args()
    chosen = args.only.split(",") if args.only else list(MODULES)

    trace_root = None
    if args.profile:
        # repo-local (and git-ignored) so traces survive the run and are
        # easy to find; one fresh subdir per invocation
        os.makedirs("bench-traces", exist_ok=True)
        trace_root = tempfile.mkdtemp(prefix="run-", dir="bench-traces")

    print("name,us_per_call,derived")
    failures = 0
    for name in chosen:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        params = inspect.signature(mod.run).parameters
        kw = {"paper_scale": args.paper_scale}
        if "smoke" in params:
            kw["smoke"] = args.smoke
        elif args.smoke:
            print(f"# bench_{name} has no smoke mode; running at default "
                  "scale", file=sys.stderr)
        if "xl" in params:
            kw["xl"] = args.xl
        elif args.xl:
            print(f"# bench_{name} has no xl scale; running at default "
                  "scale", file=sys.stderr)
        profiling = contextlib.nullcontext()
        if trace_root is not None:
            import jax  # deferred: keep --help / arg errors jax-free
            trace_dir = os.path.join(trace_root, name)
            profiling = jax.profiler.trace(trace_dir)
            print(f"# profiling bench_{name} -> {trace_dir}",
                  file=sys.stderr)
        t0 = time.time()
        try:
            with profiling:
                for row in mod.run(**kw):
                    print(row)
        except Exception as e:  # a failing table is a bug, not a skip
            failures += 1
            print(f"{name},0.0,ERROR={e!r}")
        print(f"# bench_{name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if trace_root is not None:
        print(f"# traces under {trace_root}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
