"""Benchmark harness: one module per paper table/figure.

  python -m benchmarks.run [--paper-scale] [--smoke] [--only convergence,roofline]

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).
Default scale finishes on CPU in minutes; --paper-scale reproduces the
paper's N∈{128, 256} settings (slow); --smoke runs every bench at N=16 for
a few blocks — a fast importable-and-runnable check to pair with the tier-1
pytest suite (it never overwrites recorded BENCH_*.json results).
"""
import argparse
import inspect
import sys
import time

MODULES = ("convergence", "walltime", "speedup", "communication",
           "ablation", "kernels", "roofline", "event_stream")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="N=16, a few blocks per bench: fast CI check")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    args = ap.parse_args()
    chosen = args.only.split(",") if args.only else list(MODULES)

    print("name,us_per_call,derived")
    failures = 0
    for name in chosen:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        kw = {"paper_scale": args.paper_scale}
        if "smoke" in inspect.signature(mod.run).parameters:
            kw["smoke"] = args.smoke
        elif args.smoke:
            print(f"# bench_{name} has no smoke mode; running at default "
                  "scale", file=sys.stderr)
        t0 = time.time()
        try:
            for row in mod.run(**kw):
                print(row)
        except Exception as e:  # a failing table is a bug, not a skip
            failures += 1
            print(f"{name},0.0,ERROR={e!r}")
        print(f"# bench_{name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
