"""Benchmark harness: one module per paper table/figure.

  python -m benchmarks.run [--paper-scale] [--xl] [--smoke]
      [--only convergence,roofline] [--profile]
  python -m benchmarks.run --compare OLD.json NEW.json

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).
Default scale finishes on CPU in minutes; --paper-scale reproduces the
paper's N∈{128, 256} settings (slow); --xl adds N∈{512, 1024} to the
benches that support it (sparse path only); --smoke runs every bench at
N=16 for a few blocks — a fast importable-and-runnable check to pair with
the tier-1 pytest suite (it never overwrites recorded BENCH_*.json
results).

--profile wraps each selected bench in ``jax.profiler.trace`` and prints
the per-bench trace directory (open with TensorBoard or Perfetto).  Pair
it with ``--only`` and ``--smoke`` to keep traces small: a full bench
traces every dispatch, and the trace grows with wall time.

--compare is the trend gate: a per-row delta report between two recorded
``BENCH_*.json`` files of the same bench (rows matched on their identity
fields, metrics on shared numeric keys; higher is better for ``*_eps`` /
``*_speedup`` throughputs, lower for ``*_overhead`` ratios).  It is a
*soft* CI gate — timing on shared runners drifts — warning at a >= 10%
regression on any metric and failing (exit 1) only at >= 30% on the
pinned throughput metrics.  Readers are tolerant of legacy files: a
``null``, a legacy ``"unsupported"`` string, or a missing key simply
drops that metric from the comparison.
"""
import argparse
import contextlib
import inspect
import json
import os
import sys
import tempfile
import time

MODULES = ("convergence", "walltime", "speedup", "communication",
           "ablation", "kernels", "roofline", "event_stream")

# Hard-gate metrics: the recorded throughputs each PR's perf story rests
# on.  Everything else (overheads, speedup ratios, occupancy) only warns.
PINNED_METRICS = ("gen_eps", "sparse_eps", "e2e_eps", "fused_eps",
                  "scan_eps", "per_event_eps")
WARN_AT, FAIL_AT = 0.10, 0.30

# Row-identity fields, in display order; whatever subset a row carries
# forms its key (the event-stream bench uses n/alg, roofline-style tables
# arch/shape).
_ID_FIELDS = ("n", "alg", "algorithm", "arch", "shape", "scenario", "name")
# run configuration, not measurements — a delta here means the benches
# aren't comparable, not that performance moved
_CONFIG_FIELDS = ("events", "block_size", "buckets", "occupancy")


def _load_rows(path):
    with open(path) as f:
        data = json.load(f)
    rows = data.get("results") if isinstance(data, dict) else data
    if not isinstance(rows, list):
        raise SystemExit(f"{path}: expected a bench artifact with a "
                         "'results' list (or a bare row list)")
    keyed = {}
    for r in rows:
        if not isinstance(r, dict):
            continue
        key = tuple((f, r[f]) for f in _ID_FIELDS if f in r)
        keyed[key] = r
    return keyed


def _regression(metric, old, new):
    """Signed regression fraction: positive = worse, negative = better."""
    if metric.endswith("_overhead"):
        return new / old - 1.0   # ratios: lower is better
    return 1.0 - new / old       # throughputs/speedups: higher is better


def compare(old_path: str, new_path: str) -> int:
    from benchmarks.common import as_metric
    old_rows, new_rows = _load_rows(old_path), _load_rows(new_path)
    warns = fails = 0
    for key in old_rows:
        if key not in new_rows:
            print(f"# {_fmt_key(key)}: only in {old_path}", file=sys.stderr)
    for key, new in new_rows.items():
        old = old_rows.get(key)
        if old is None:
            print(f"# {_fmt_key(key)}: only in {new_path}", file=sys.stderr)
            continue
        for metric in sorted(set(old) & set(new)):
            if metric in _CONFIG_FIELDS or any(f == metric for f, _ in key):
                if as_metric(old[metric]) != as_metric(new[metric]):
                    print(f"# {_fmt_key(key)}: config field {metric} "
                          f"differs ({old[metric]!r} -> {new[metric]!r})",
                          file=sys.stderr)
                continue
            ov, nv = as_metric(old[metric]), as_metric(new[metric])
            if ov is None or nv is None or ov == 0:
                continue  # null / legacy "unsupported" / non-numeric
            reg = _regression(metric, ov, nv)
            flag = ""
            if reg >= FAIL_AT and metric in PINNED_METRICS:
                flag, fails = " FAIL", fails + 1
            elif reg >= WARN_AT:
                flag, warns = " WARN", warns + 1
            print(f"{_fmt_key(key)} {metric}: {ov:g} -> {nv:g} "
                  f"({0.0 - 100 * reg:+.1f}%){flag}")
    print(f"# compare: {fails} fail(s), {warns} warning(s) "
          f"(warn >= {WARN_AT:.0%}, fail >= {FAIL_AT:.0%} on pinned rows)",
          file=sys.stderr)
    return 1 if fails else 0


def _fmt_key(key):
    return "/".join(f"{f}={v}" for f, v in key) or "(row)"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--xl", action="store_true",
                    help="add N∈{512, 1024} where a bench supports it")
    ap.add_argument("--smoke", action="store_true",
                    help="N=16, a few blocks per bench: fast CI check")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    ap.add_argument("--profile", action="store_true",
                    help="wrap each bench in jax.profiler.trace and print "
                         "the trace directory")
    ap.add_argument("--compare", nargs=2, metavar=("OLD.json", "NEW.json"),
                    help="trend gate: per-row metric deltas between two "
                         "recorded bench artifacts (warn >= 10%% "
                         "regression, exit 1 at >= 30%% on pinned rows)")
    args = ap.parse_args()
    if args.compare:
        return compare(*args.compare)
    chosen = args.only.split(",") if args.only else list(MODULES)

    trace_root = None
    if args.profile:
        # repo-local (and git-ignored) so traces survive the run and are
        # easy to find; one fresh subdir per invocation
        os.makedirs("bench-traces", exist_ok=True)
        trace_root = tempfile.mkdtemp(prefix="run-", dir="bench-traces")

    print("name,us_per_call,derived")
    failures = 0
    for name in chosen:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        params = inspect.signature(mod.run).parameters
        kw = {"paper_scale": args.paper_scale}
        if "smoke" in params:
            kw["smoke"] = args.smoke
        elif args.smoke:
            print(f"# bench_{name} has no smoke mode; running at default "
                  "scale", file=sys.stderr)
        if "xl" in params:
            kw["xl"] = args.xl
        elif args.xl:
            print(f"# bench_{name} has no xl scale; running at default "
                  "scale", file=sys.stderr)
        profiling = contextlib.nullcontext()
        if trace_root is not None:
            import jax  # deferred: keep --help / arg errors jax-free
            trace_dir = os.path.join(trace_root, name)
            profiling = jax.profiler.trace(trace_dir)
            print(f"# profiling bench_{name} -> {trace_dir}",
                  file=sys.stderr)
        t0 = time.time()
        try:
            with profiling:
                for row in mod.run(**kw):
                    print(row)
        except Exception as e:  # a failing table is a bug, not a skip
            failures += 1
            print(f"{name},0.0,ERROR={e!r}")
        print(f"# bench_{name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if trace_root is not None:
        print(f"# traces under {trace_root}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
