"""Benchmark harness: one module per paper table/figure.

  python -m benchmarks.run [--paper-scale] [--only convergence,roofline]

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).
Default scale finishes on CPU in minutes; --paper-scale reproduces the
paper's N=128 settings (slow).
"""
import argparse
import sys
import time

MODULES = ("convergence", "walltime", "speedup", "communication",
           "ablation", "kernels", "roofline", "event_stream")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    args = ap.parse_args()
    chosen = args.only.split(",") if args.only else list(MODULES)

    print("name,us_per_call,derived")
    failures = 0
    for name in chosen:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        try:
            for row in mod.run(paper_scale=args.paper_scale):
                print(row)
        except Exception as e:  # a failing table is a bug, not a skip
            failures += 1
            print(f"{name},0.0,ERROR={e!r}")
        print(f"# bench_{name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
