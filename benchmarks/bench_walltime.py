"""Paper Figure 4 / Table 2: accuracy for a fixed (virtual) wall-clock budget."""
from benchmarks.common import ALGS, csv_row, make_classification_trainer, timed_run


def run(paper_scale: bool = False, smoke: bool = False):
    n = 128 if paper_scale else 16
    budget = 50.0  # the paper trains ResNet-18 for 50 (real) seconds
    if smoke:
        n, budget = 16, 8.0
    rows = []
    for alg in ALGS:
        res, wall = timed_run(make_classification_trainer(alg, n),
                              max_time=budget, eval_every=200)
        rows.append(csv_row(
            f"walltime/2nn/{alg}", 1e6 * wall / max(res.total_events, 1),
            f"acc@t{budget:.0f}={res.final_metric:.4f};loss={res.final_loss:.4f};"
            f"iters={res.total_events}"))
    return rows
