"""Kernel micro-benchmarks (TPU-adaptation layer).

On this CPU container Pallas kernels run in interpret mode (a Python-level
executor), so wall-clock numbers are reported for the pure-jnp oracles — the
quantity that is meaningful on this host — while each kernel's output is
verified against its oracle in the same sweep.  ``derived`` records the
max-abs error.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.core.consensus import metropolis_matrix
from repro.kernels.gossip_mix import gossip_mix, gossip_mix_ref
from repro.kernels.linear_scan import linear_scan, linear_scan_ref
from repro.kernels.swa_attention import swa_attention, swa_attention_ref


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return 1e6 * (time.time() - t0) / reps


def run(paper_scale: bool = False, smoke: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)

    # gossip_mix: N workers × D params
    for n, d in ((16, 1 << 12),) if smoke else ((16, 1 << 16), (32, 1 << 18)):
        W = jax.random.normal(key, (n, d))
        P = jnp.asarray(metropolis_matrix(
            n, [(i, (i + 1) % n) for i in range(n)]), jnp.float32)
        # per-(n, d) jit is deliberate: each config compiles once anyway
        ref = jax.jit(gossip_mix_ref)  # repro: disable=jit-in-loop
        us = _time(ref, W, P)
        err = float(jnp.max(jnp.abs(gossip_mix(W, P) - ref(W, P))))
        rows.append(csv_row(f"kernel/gossip_mix/N{n}_D{d}", us,
                            f"maxerr_vs_ref={err:.2e}"))

    # sparse_gossip: active-set mix (AD-PSGD A=2 lanes out of N workers)
    from repro.kernels.sparse_gossip import (sparse_gossip_apply,
                                             sparse_gossip_apply_ref)
    for n, d in ((16, 1 << 12),) if smoke else ((64, 1 << 16), (256, 1 << 16)):
        W = jax.random.normal(key, (n, d))
        G = jax.random.normal(jax.random.PRNGKey(2), (2, d))
        P_sub = jnp.full((2, 2), 0.5, jnp.float32)
        mask = jnp.asarray([0.1, 0.0], jnp.float32)
        workers = jnp.asarray([1, n - 1], jnp.int32)
        ref = jax.jit(sparse_gossip_apply_ref)  # repro: disable=jit-in-loop
        us = _time(ref, W, G, P_sub, mask, workers)
        err = float(jnp.max(jnp.abs(
            sparse_gossip_apply(W, G, P_sub, mask, workers)
            - ref(W, G, P_sub, mask, workers))))
        rows.append(csv_row(f"kernel/sparse_gossip/N{n}_D{d}_A2", us,
                            f"maxerr_vs_ref={err:.2e}"))

    # linear_scan
    B, T, D = (1, 128, 64) if smoke else (2, 512, 256)
    a = jax.nn.sigmoid(jax.random.normal(key, (B, T, D)))
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, D))
    ref = jax.jit(linear_scan_ref)
    us = _time(ref, a, x)
    err = float(jnp.max(jnp.abs(linear_scan(a, x) - ref(a, x))))
    rows.append(csv_row(f"kernel/linear_scan/B{B}_T{T}_D{D}", us,
                        f"maxerr_vs_ref={err:.2e}"))

    # swa_attention
    B, T, H, KV, dh, w = (1, 256, 4, 2, 64, 128) if smoke else \
        (1, 512, 4, 2, 64, 128)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, T, H, dh))
    k = jax.random.normal(ks[1], (B, T, KV, dh))
    v = jax.random.normal(ks[2], (B, T, KV, dh))

    def ref_fn(q, k, v):
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, T, dh)
        kf = k.transpose(0, 2, 1, 3).reshape(B * KV, T, dh)
        vf = v.transpose(0, 2, 1, 3).reshape(B * KV, T, dh)
        o = swa_attention_ref(qf, kf, vf, window=w, n_groups=H // KV)
        return o.reshape(B, H, T, dh).transpose(0, 2, 1, 3)

    refj = jax.jit(ref_fn)
    us = _time(refj, q, k, v)
    out = swa_attention(q, k, v, window=w, block_q=128, block_k=128)
    err = float(jnp.max(jnp.abs(out - refj(q, k, v))))
    rows.append(csv_row(f"kernel/swa_attention/T{T}_w{w}", us,
                        f"maxerr_vs_ref={err:.2e}"))
    return rows
