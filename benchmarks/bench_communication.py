"""Paper Figure 5b: network transmission for training, per algorithm.

Counts parameter-vector copies moved over the network until a fixed virtual
time; DSGD-AAU must achieve its speedup at no extra communication.
"""
from benchmarks.common import ALGS, csv_row, make_classification_trainer


def run(paper_scale: bool = False, smoke: bool = False):
    n = 128 if paper_scale else 16
    budget = 50.0
    if smoke:
        n, budget = 16, 8.0
    rows = []
    for alg in ALGS:
        res = make_classification_trainer(alg, n).run(max_time=budget,
                                                      eval_every=10**6)
        gb = res.comm_bytes() / 2**30
        rows.append(csv_row(
            f"communication/{alg}", 0.0,
            f"param_copies={res.total_comm_copies};GiB={gb:.3f};"
            f"acc={res.final_metric:.4f}"))
    return rows
