"""Paper Figure 5a: speedup vs #workers.

Speedup of each algorithm = (virtual time for synchronous DSGD with full
worker updates to reach the target loss) / (virtual time for the algorithm),
per worker count — the paper's definition with DSGD as the reference.
"""
from benchmarks.common import csv_row, make_classification_trainer

TARGET = 0.9  # training-loss target (2-NN synthetic reaches ~0.4 at plateau)


def run(paper_scale: bool = False, smoke: bool = False):
    ns = (32, 64, 128, 256) if paper_scale else (8, 16, 32)
    budget = 400.0
    if smoke:
        ns, budget = (16,), 40.0
    rows = []
    for n in ns:
        ref = make_classification_trainer("dsgd_sync", n).run(
            max_time=budget, eval_every=5)
        t_ref = ref.time_to_loss(TARGET) or float("inf")
        for alg in ("dsgd_aau", "ad_psgd", "prague", "agp"):
            res = make_classification_trainer(alg, n).run(
                max_time=budget, eval_every=20)
            t = res.time_to_loss(TARGET)
            speedup = (t_ref / t) if t else 0.0
            rows.append(csv_row(
                f"speedup/N{n}/{alg}", 0.0,
                f"speedup_vs_sync={speedup:.2f};t_target={t if t else -1:.1f};"
                f"t_sync={t_ref:.1f}"))
    return rows
