"""Paper Figure 5a: speedup vs #workers, through the experiment harness.

Speedup of each algorithm = (virtual time for synchronous DSGD to reach the
target loss) / (virtual time for the algorithm), per worker count — the
paper's definition with DSGD as the reference.  Runs ride the sparse
active-set path (``mode="sparse_scan"``); ``--paper-scale`` sweeps the
paper's N ∈ {32, 64, 128, 256}.

A run whose budget ends above the target loss reports ``speedup_vs_sync=nan``
and ``t_target=unreached`` — never 0.0, which used to be indistinguishable
from "no speedup" in the recorded artifact.
"""
from repro.xp import ExperimentSpec, artifact_payload, csv_rows, run_spec
from repro.xp.sweep import SweepResult

TARGET = 0.9  # training-loss target (2-NN synthetic reaches ~0.4 at plateau)


def _spec(ns, budget: float) -> ExperimentSpec:
    return ExperimentSpec(
        name="bench_speedup",
        algorithms=("dsgd_aau", "ad_psgd", "prague", "agp"),
        reference="dsgd_sync",
        scenarios=("paper_default",),
        scales=tuple(ns),
        seeds=(0,),
        mode="sparse_scan",
        max_time=budget,
        ref_max_time=max(400.0, 10 * budget),
        target_loss=TARGET,
    )


def run(paper_scale: bool = False, smoke: bool = False):
    ns = (32, 64, 128, 256) if paper_scale else (8, 16, 32)
    budget = 30.0
    if smoke:
        ns, budget = (16,), 20.0
    sweep: SweepResult = run_spec(_spec(ns, budget))
    rows = []
    for r in csv_rows(artifact_payload(sweep)):
        # keep this table under its historical name prefix
        rows.append(r.replace("paper_figures/speedup/", "speedup/", 1))
    return rows
