"""§Roofline: per (arch × shape × mesh) terms from the dry-run artifacts.

Reads ``experiments/dryrun_{single,multi}.json`` written by
``python -m repro.launch.dryrun --all [--multipod] --out experiments`` and
emits one CSV row per pair.  If the artifacts are missing (fresh clone), a
reduced-scale dry-run is executed inline via subprocess so the benchmark is
self-contained.
"""
import json
import os
import subprocess
import sys

from benchmarks.common import csv_row

ART = os.path.join(os.path.dirname(__file__), "..", "experiments")


def _ensure(tag: str):
    path = os.path.join(ART, f"dryrun_{tag}.json")
    if os.path.exists(path):
        return path
    # self-contained fallback: run two representative pairs only (compile
    # cost of the full 40-pair sweep belongs to the dryrun CLI, not here)
    os.makedirs(ART, exist_ok=True)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3-8b",
           "--shape", "train_4k", "--out", ART]
    if tag == "multi":
        cmd.append("--multipod")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    subprocess.run(cmd + ["--all"][:0], env=env, check=False,
                   capture_output=True)
    return path if os.path.exists(path) else None


def run(paper_scale: bool = False):
    rows = []
    for tag in ("single", "multi"):
        path = _ensure(tag)
        if path is None:
            rows.append(csv_row(f"roofline/{tag}", 0.0, "missing_artifacts"))
            continue
        data = json.load(open(path))
        for r in data:
            if "error" in r:
                rows.append(csv_row(
                    f"roofline/{tag}/{r['arch']}/{r['shape']}", 0.0,
                    f"ERROR={r['error'][:60]}"))
                continue
            bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
            rows.append(csv_row(
                f"roofline/{tag}/{r['arch']}/{r['shape']}",
                1e6 * bound,  # roofline-bound step latency
                f"dom={r['dominant']};comp_ms={r['compute_s']*1e3:.2f};"
                f"mem_ms={r['memory_s']*1e3:.2f};"
                f"coll_ms={r['collective_s']*1e3:.2f};"
                f"useful={r['useful_flops_ratio']:.3f};"
                f"peak_GiB={r['peak_bytes_per_device']/2**30:.2f}"))
    return rows
