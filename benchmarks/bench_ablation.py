"""Paper Figures 9/10: ablations on straggler probability and slow-down."""
from benchmarks.common import csv_row, make_classification_trainer


def run(paper_scale: bool = False, smoke: bool = False):
    n = 128 if paper_scale else 16
    budget = 50.0
    rows = []
    algs = ("dsgd_aau", "ad_psgd", "prague") if not paper_scale else \
        ("dsgd_aau", "dsgd_sync", "ad_psgd", "prague", "agp")
    probs, slows = (0.05, 0.1, 0.2, 0.4), (5.0, 10.0, 20.0, 40.0)
    if smoke:
        n, budget = 16, 8.0
        algs, probs, slows = ("dsgd_aau",), (0.1,), (10.0,)
    for prob in probs:
        for alg in algs:
            res = make_classification_trainer(
                alg, n, straggler_prob=prob).run(max_time=budget,
                                                 eval_every=10**6)
            rows.append(csv_row(
                f"ablation/prob{int(prob*100)}/{alg}", 0.0,
                f"acc={res.final_metric:.4f};loss={res.final_loss:.4f}"))
    for slow in slows:
        for alg in algs:
            res = make_classification_trainer(
                alg, n, slowdown=slow).run(max_time=budget, eval_every=10**6)
            rows.append(csv_row(
                f"ablation/slow{int(slow)}x/{alg}", 0.0,
                f"acc={res.final_metric:.4f};loss={res.final_loss:.4f}"))
    return rows
