"""Paper Table 1 / Figure 3: loss & accuracy per iteration budget, 4+ algorithms."""
from benchmarks.common import ALGS, csv_row, make_classification_trainer, \
    make_charlm_trainer, timed_run


def run(paper_scale: bool = False, smoke: bool = False):
    n = 128 if paper_scale else 16
    events = 600 if paper_scale else 120
    if smoke:
        n, events = 16, 24
    rows = []
    for alg in ALGS:
        res, wall = timed_run(make_classification_trainer(alg, n),
                              max_events=events, eval_every=events)
        rows.append(csv_row(
            f"convergence/2nn/{alg}", 1e6 * wall / max(res.total_events, 1),
            f"loss={res.final_loss:.4f};acc={res.final_metric:.4f};iters={res.total_events}"))
    for alg in ALGS:
        res, wall = timed_run(make_charlm_trainer(alg, max(8, n // 2)),
                              max_events=events // 2, eval_every=events // 2)
        rows.append(csv_row(
            f"convergence/charlm/{alg}", 1e6 * wall / max(res.total_events, 1),
            f"loss={res.final_loss:.4f};iters={res.total_events}"))
    return rows
