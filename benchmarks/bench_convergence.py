"""Paper Table 1 / Figures 3–4: loss & accuracy per iteration budget.

The 2-NN table now runs through the declarative experiment harness
(repro/xp) on the sparse active-set path, so ``--paper-scale`` sweeps the
paper's real worker counts N ∈ {32, 64, 128, 256} and a second straggler
scenario rides along for free; the char-LM rows keep the legacy
single-trainer path (a different model, not part of the Figure 3 protocol).
"""
from benchmarks.common import ALGS, csv_row, make_charlm_trainer, timed_run
from repro.xp import ExperimentSpec, run_cell


def _spec(events: int, eval_every: int) -> ExperimentSpec:
    return ExperimentSpec(
        name="bench_convergence",
        algorithms=("dsgd_aau", "ad_psgd", "prague", "agp"),
        reference="dsgd_sync",
        mode="sparse_scan",
        max_events=events,
        eval_every=eval_every,
        ref_eval_every=eval_every,  # this table reads final loss only
    )


def run(paper_scale: bool = False, smoke: bool = False):
    ns = (32, 64, 128, 256) if paper_scale else (16,)
    events = 600 if paper_scale else 120
    scenarios = ("paper_default", "heavy_tail") if paper_scale \
        else ("paper_default",)
    if smoke:
        ns, events, scenarios = (16,), 24, ("paper_default",)
    spec = _spec(events, eval_every=events)
    rows = []
    for scen in scenarios:
        for n in ns:
            for alg in (spec.reference,) + spec.algorithms:
                rec = run_cell(spec, scen, alg, n, seed=0)
                res = rec.result
                rows.append(csv_row(
                    f"convergence/2nn/{scen}/N{n}/{alg}",
                    1e6 * rec.wall_s / max(res.total_events, 1),
                    f"loss={res.final_loss:.4f};acc={res.final_metric:.4f};"
                    f"iters={res.total_events}"))
    n_lm = 64 if paper_scale and not smoke else max(8, ns[0] // 2)
    for alg in ALGS:
        res, wall = timed_run(make_charlm_trainer(alg, n_lm),
                              max_events=events // 2, eval_every=events // 2)
        rows.append(csv_row(
            f"convergence/charlm/{alg}", 1e6 * wall / max(res.total_events, 1),
            f"loss={res.final_loss:.4f};iters={res.total_events}"))
    return rows
