"""Shared experiment harness for the paper-table benchmarks.

Builds (algorithm × model × data × straggler) trainers at a configurable
scale.  The paper runs N ∈ {32, 64, 128, 256} workers on GPUs; the default
benchmark scale is N=16/32 so the whole suite runs on CPU in minutes — pass
``--paper-scale`` to ``benchmarks.run`` for N=128 (slow).
"""
from __future__ import annotations

import time

from repro.configs import get_config
from repro.core.baselines import make_scheduler
from repro.core.runner import DecentralizedTrainer
from repro.core.straggler import StragglerModel
from repro.data import CharLMData, ClassificationData
from repro.models import init_model, lm_loss
# The paper's 2-NN now lives with the experiment harness (repro/xp) so the
# declarative sweeps and these legacy helpers build byte-identical trainers;
# re-exported here for the benches and examples that import it from common.
from repro.xp.builders import (build_graph, mlp2nn_eval,  # noqa: F401
                               mlp2nn_init, mlp2nn_loss)

ALGS = ("dsgd_aau", "dsgd_sync", "ad_psgd", "prague", "agp")

# Worker-count presets: the CPU-friendly default suite, the paper's
# N ∈ {128, 256} scale (Figures 3–5 at real worker counts — affordable via
# the sparse active-set scan path), the beyond-paper XL tier the bucketed
# lane-width ladder unlocks (sparse path only — the dense modes are skipped
# there by the benches that honor SCAN-style caps), and a --smoke tier that
# only proves the whole suite still imports and runs.
SCALES_SMOKE = (16,)
SCALES_DEFAULT = (16, 64)
SCALES_PAPER = (128, 256)
SCALES_XL = (512, 1024)


def bench_sizes(paper_scale: bool = False, smoke: bool = False,
                xl: bool = False):
    """Worker counts a bench should sweep under the harness flags."""
    if smoke:
        return SCALES_SMOKE
    sizes = SCALES_DEFAULT
    if paper_scale or xl:
        sizes = sizes + SCALES_PAPER
    if xl:
        sizes = sizes + SCALES_XL
    return sizes


def make_classification_trainer(alg: str, n: int, *, straggler_prob=0.1,
                                slowdown=10.0, seed=0, partition="label_shard",
                                eta0=0.2, **trainer_kw) -> DecentralizedTrainer:
    data = ClassificationData(n_workers=n, d=64, partition=partition,
                              samples_per_worker=256, seed=0)
    g = build_graph("erdos_renyi", n)
    sm = StragglerModel(n=n, straggler_prob=straggler_prob,
                        slowdown=slowdown, seed=seed)
    sched = make_scheduler(alg, g, sm)
    return DecentralizedTrainer(
        sched, mlp2nn_loss, mlp2nn_init(),
        lambda w, s: data.batch(w, s, batch_size=32),
        data.eval_batch(1024), eval_fn=mlp2nn_eval,
        eta0=eta0, eta_decay=0.999, seed=seed, **trainer_kw)


def make_charlm_trainer(alg: str, n: int, *, straggler_prob=0.1,
                        slowdown=10.0, seed=0) -> DecentralizedTrainer:
    cfg = get_config("paper-char-lm").reduced()
    data = CharLMData(n_workers=n, vocab=cfg.vocab_size, seq_len=32, seed=0)
    g = build_graph("erdos_renyi", n)
    sm = StragglerModel(n=n, straggler_prob=straggler_prob,
                        slowdown=slowdown, seed=seed)
    sched = make_scheduler(alg, g, sm)
    return DecentralizedTrainer(
        sched, lambda p, b: lm_loss(p, cfg, b),
        lambda k: init_model(k, cfg),
        lambda w, s: data.batch(w, s, batch_size=8),
        data.eval_batch(16), eta0=0.5, eta_decay=0.999, seed=seed)


def timed_run(trainer: DecentralizedTrainer, **run_kw):
    t0 = time.time()
    res = trainer.run(**run_kw)
    wall = time.time() - t0
    return res, wall


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
