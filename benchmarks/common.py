"""Shared experiment harness for the paper-table benchmarks.

Builds (algorithm × model × data × straggler) trainers at a configurable
scale.  The paper runs N ∈ {32, 64, 128, 256} workers on GPUs; the default
benchmark scale is N=16/32 so the whole suite runs on CPU in minutes — pass
``--paper-scale`` to ``benchmarks.run`` for N=128 (slow).
"""
from __future__ import annotations

import json
import math
import time

from repro.configs import get_config
from repro.core.baselines import make_scheduler
from repro.core.runner import DecentralizedTrainer
from repro.core.straggler import StragglerModel
from repro.data import CharLMData, ClassificationData
from repro.models import init_model, lm_loss
# The paper's 2-NN now lives with the experiment harness (repro/xp) so the
# declarative sweeps and these legacy helpers build byte-identical trainers;
# re-exported here for the benches and examples that import it from common.
from repro.xp.builders import (build_graph, mlp2nn_eval,  # noqa: F401
                               mlp2nn_init, mlp2nn_loss)

ALGS = ("dsgd_aau", "dsgd_sync", "ad_psgd", "prague", "agp")

# Worker-count presets: the CPU-friendly default suite, the paper's
# N ∈ {128, 256} scale (Figures 3–5 at real worker counts — affordable via
# the sparse active-set scan path), the beyond-paper XL tier the bucketed
# lane-width ladder unlocks (sparse path only — the dense modes are skipped
# there by the benches that honor SCAN-style caps), and a --smoke tier that
# only proves the whole suite still imports and runs.
SCALES_SMOKE = (16,)
SCALES_DEFAULT = (16, 64)
SCALES_PAPER = (128, 256)
SCALES_XL = (512, 1024)


def bench_sizes(paper_scale: bool = False, smoke: bool = False,
                xl: bool = False):
    """Worker counts a bench should sweep under the harness flags."""
    if smoke:
        return SCALES_SMOKE
    sizes = SCALES_DEFAULT
    if paper_scale or xl:
        sizes = sizes + SCALES_PAPER
    if xl:
        sizes = sizes + SCALES_XL
    return sizes


def make_classification_trainer(alg: str, n: int, *, straggler_prob=0.1,
                                slowdown=10.0, seed=0, partition="label_shard",
                                eta0=0.2, **trainer_kw) -> DecentralizedTrainer:
    data = ClassificationData(n_workers=n, d=64, partition=partition,
                              samples_per_worker=256, seed=0)
    g = build_graph("erdos_renyi", n)
    sm = StragglerModel(n=n, straggler_prob=straggler_prob,
                        slowdown=slowdown, seed=seed)
    sched = make_scheduler(alg, g, sm)
    return DecentralizedTrainer(
        sched, mlp2nn_loss, mlp2nn_init(),
        lambda w, s: data.batch(w, s, batch_size=32),
        data.eval_batch(1024), eval_fn=mlp2nn_eval,
        eta0=eta0, eta_decay=0.999, seed=seed, **trainer_kw)


def make_charlm_trainer(alg: str, n: int, *, straggler_prob=0.1,
                        slowdown=10.0, seed=0) -> DecentralizedTrainer:
    cfg = get_config("paper-char-lm").reduced()
    data = CharLMData(n_workers=n, vocab=cfg.vocab_size, seq_len=32, seed=0)
    g = build_graph("erdos_renyi", n)
    sm = StragglerModel(n=n, straggler_prob=straggler_prob,
                        slowdown=slowdown, seed=seed)
    sched = make_scheduler(alg, g, sm)
    return DecentralizedTrainer(
        sched, lambda p, b: lm_loss(p, cfg, b),
        lambda k: init_model(k, cfg),
        lambda w, s: data.batch(w, s, batch_size=8),
        data.eval_batch(16), eta0=0.5, eta_decay=0.999, seed=seed)


def timed_run(trainer: DecentralizedTrainer, **run_kw):
    t0 = time.time()
    res = trainer.run(**run_kw)
    wall = time.time() - t0
    return res, wall


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def _bench_json_safe(v, key=""):
    if isinstance(v, str):
        # legacy sentinel for "this metric doesn't apply to this row" —
        # normalize to null so numeric readers never meet a string
        return None if v == "unsupported" else v
    if v is None or isinstance(v, (bool, int)):
        return v
    if isinstance(v, float):  # accepts np.float64 (a float subclass)
        if math.isnan(v) or math.isinf(v):
            raise ValueError(f"non-finite metric {v!r} at {key!r} — a bench "
                             "row must record numbers or null")
        return v
    if isinstance(v, dict):
        return {str(k): _bench_json_safe(x, f"{key}.{k}") for k, x in
                v.items()}
    if isinstance(v, (list, tuple)):
        return [_bench_json_safe(x, f"{key}[{i}]") for i, x in enumerate(v)]
    raise TypeError(
        f"non-JSON value {v!r} ({type(v).__name__}) at {key!r} — convert "
        "numpy scalars with float()/int() before recording")


def write_bench_json(path: str, payload: dict) -> None:
    """Typed writer for ``BENCH_*.json`` — schema discipline at the write.

    Earlier recordings marked an inapplicable metric with the *string*
    ``"unsupported"``, which silently breaks numeric readers.  The schema
    is now "number or null": this helper maps the legacy sentinel to
    ``None``, rejects NaN/Inf and non-JSON scalars (numpy int32/float32
    must be converted at the call site), and is the single write path for
    every bench artifact.  Readers stay tolerant of legacy files via
    :func:`as_metric`.
    """
    with open(path, "w") as f:
        json.dump(_bench_json_safe(payload), f, indent=2, allow_nan=False)
        f.write("\n")


def as_metric(v):
    """Read a bench metric tolerantly: float, or None when inapplicable.

    Accepts the current schema (number | null), the legacy
    ``"unsupported"`` string, the xp artifacts' ``"nan"``/``"inf"``
    strings, and anything non-numeric — everything that isn't a finite
    number comes back as None.
    """
    if isinstance(v, bool) or not isinstance(v, (int, float, str)):
        return None
    try:
        f = float(v)
    except ValueError:
        return None
    return None if (math.isnan(f) or math.isinf(f)) else f
